//! End-to-end driver (EXPERIMENTS.md §E2E): a real TSQR factorization of
//! a 2M x 128 synthetic matrix through the full three-layer stack —
//! Rust decentralized executors -> PJRT -> AOT-compiled JAX/Pallas
//! kernels — verified numerically (Q·R = A, QᵀQ = I) and compared
//! against the stateless numpywren baseline on the same inputs.
//!
//! ```bash
//! make artifacts && cargo run --release --example tsqr_end_to_end
//! ```

use std::sync::Arc;
use std::time::Duration;

use wukong::engine::compute::seed_inputs;
use wukong::engine::{run_real_numpywren, run_real_wukong, RealConfig};
use wukong::runtime::{default_artifact_dir, SharedRuntime};
use wukong::storage::real_kvs::RealKvs;
use wukong::util::stats::human_bytes;
use wukong::workloads::tsqr;

fn main() -> anyhow::Result<()> {
    let p = tsqr::TsqrParams {
        rows: 1 << 19, // 512k rows (keeps the demo ~a minute)
        cols: 128,
        block_rows: 1024,
        with_q: false, // R-factor benchmark shape (fig14/16 pairing)
    };
    // A smaller explicit-Q problem for the numeric verification pass.
    let pq = tsqr::TsqrParams {
        rows: 8192,
        cols: 128,
        block_rows: 1024,
        with_q: true,
    };

    let rt = SharedRuntime::load(&default_artifact_dir())?;
    println!("compiling {} artifacts...", rt.op_names().len());
    rt.warmup()?;

    // ---- correctness: explicit-Q TSQR, verified ----
    let dag = tsqr::dag(pq);
    let kvs = RealKvs::new(16, 0.0, 0.0);
    let seeded = seed_inputs(&dag, &kvs, 7);
    let cfg = RealConfig {
        invoke_latency: Duration::from_millis(1),
        ..RealConfig::default()
    };
    let rep = run_real_wukong(&dag, Arc::clone(&rt), kvs, cfg.clone())?;
    println!(
        "verify: TSQR {}x{} ({} tasks, {} executors) in {:?}",
        pq.rows, pq.cols, rep.tasks_executed, rep.executors_used, rep.makespan
    );
    // Q·R = A spot check over every block.
    let r = rep
        .outputs
        .iter()
        .find(|(n, _)| n.starts_with("r_l") || n.starts_with("merge_l"))
        .map(|(_, o)| o.last().unwrap().clone())
        .expect("root R");
    let mut worst = 0f32;
    for blk in 0..pq.nb() {
        let q = &rep.outputs[&format!("applyq_{blk}")][0];
        let a = &seeded
            .iter()
            .find(|(k, _)| k == &format!("in:qr_{blk}"))
            .unwrap()
            .1[0];
        for &(i, j) in &[(0usize, 0usize), (500, 60), (1023, 127)] {
            let mut qr = 0f32;
            for k in 0..128 {
                qr += q.data[i * 128 + k] * r.data[k * 128 + j];
            }
            worst = worst.max((qr - a.data[i * 128 + j]).abs());
        }
    }
    println!("verify: max |Q·R - A| at sampled entries = {worst:.2e}");
    assert!(worst < 2e-2, "factorization drifted");

    // ---- performance shape: Wukong vs stateless numpywren ----
    let dag = tsqr::dag(p);
    println!(
        "\nbenchmark: TSQR {}x{} — {} tasks over {} leaf blocks",
        p.rows,
        p.cols,
        dag.len(),
        p.nb()
    );
    // The benchmark KVS models a real Redis wire (0.5 ms/op + 300 MB/s):
    // the paper's latencies are what decentralized locality buys back.
    let wire = |seed| {
        let kvs = RealKvs::new(16, 0.0005, 300e6);
        seed_inputs(&dag, &kvs, seed);
        kvs
    };
    let kvs = wire(23);
    let base = kvs.bytes_written.load(std::sync::atomic::Ordering::SeqCst);
    let wk = run_real_wukong(&dag, Arc::clone(&rt), kvs, cfg.clone())?;

    let np = run_real_numpywren(&dag, rt, wire(23), cfg)?;

    let wk_w = wk.kvs_bytes_written - base;
    let np_w = np.kvs_bytes_written - base;
    println!(
        "wukong:    {:>10.2?}  intermediates written {:>10}",
        wk.makespan,
        human_bytes(wk_w as f64)
    );
    println!(
        "numpywren: {:>10.2?}  intermediates written {:>10}",
        np.makespan,
        human_bytes(np_w as f64)
    );
    println!(
        "=> {:.1}x less data written, {:.2}x faster (paper: orders of \
         magnitude / up to 68x on AWS-scale latencies)",
        np_w as f64 / wk_w.max(1) as f64,
        np.makespan.as_secs_f64() / wk.makespan.as_secs_f64()
    );
    Ok(())
}
