//! Elastic-scaling sweep (Fig. 21 at full paper scale): strong, weak, and
//! serverless scaling of Wukong vs (Num)PyWren on the simulator, printed
//! as the same series the paper plots.
//!
//! ```bash
//! cargo run --release --example scaling_sweep
//! ```

use wukong::baselines::run_pywren;
use wukong::config::Config;
use wukong::coordinator::run_wukong;
use wukong::sim::secs;
use wukong::util::table::Table;
use wukong::workloads::micro;

fn main() {
    let base = Config::default();
    let mut t = Table::new(vec![
        "mode",
        "delay (ms)",
        "lambdas",
        "wukong (s)",
        "pywren (s)",
        "speedup",
    ]);
    for &delay_ms in &[0u64, 100, 250, 500] {
        let dur = secs(delay_ms as f64 / 1000.0);
        // strong: 10k tasks over N executors
        for &n in &[500usize, 1_000, 2_000, 5_000] {
            let dag = micro::strong(10_000, n, dur);
            row(&mut t, &base, "strong", delay_ms, n, &dag);
        }
        // weak: 10 tasks per executor
        for &n in &[250usize, 500, 750, 1_000] {
            let dag = micro::weak(n, 10, dur);
            row(&mut t, &base, "weak", delay_ms, n, &dag);
        }
        // serverless: N tasks on N executors
        for &n in &[1_000usize, 2_500, 5_000, 10_000] {
            let dag = micro::serverless(n, dur);
            row(&mut t, &base, "serverless", delay_ms, n, &dag);
        }
    }
    println!("{}", t.render());
}

fn row(
    t: &mut Table,
    base: &Config,
    mode: &str,
    delay_ms: u64,
    n: usize,
    dag: &wukong::dag::Dag,
) {
    let mut cfg = base.clone();
    cfg.lambda.concurrency_limit = cfg.lambda.concurrency_limit.max(n);
    let wk = run_wukong(dag, &cfg, cfg.seed).metrics.makespan_s;
    let pw = run_pywren(dag, &cfg, n, cfg.seed).makespan_s;
    t.row(vec![
        mode.to_string(),
        delay_ms.to_string(),
        n.to_string(),
        format!("{wk:.2}"),
        format!("{pw:.2}"),
        format!("{:.1}x", pw / wk.max(1e-9)),
    ]);
}
