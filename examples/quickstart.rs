//! Quickstart: build a workload DAG, run it on three engines, compare.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use wukong::baselines::{run_dask, run_numpywren};
use wukong::config::{Config, DaskConfig};
use wukong::coordinator::run_wukong;
use wukong::util::stats::{human_bytes, human_secs};
use wukong::util::table::Table;
use wukong::workloads::{svd, tr};

fn main() {
    let cfg = Config::default();

    // 1. A DAG from the paper: tree reduction with 250 ms tasks (Fig. 9's
    //    crossover point, where Wukong overtakes Dask-1000).
    let tr_dag = tr::dag(tr::TrParams {
        n: 1024,
        chunk: 1,
        delay: Some(wukong::sim::secs(0.25)),
    });
    // 2. And a heavier one: SVD2 on a 50k x 50k matrix.
    let mut svd_cfg = cfg.clone();
    svd_cfg.wukong.clustering_threshold = 1 << 20; // the `t` knob
    let svd_dag = svd::svd2(svd::Svd2Params::paper(50));

    let mut t = Table::new(vec![
        "workload",
        "engine",
        "makespan",
        "executors",
        "KVS written",
        "cost",
    ]);
    for (name, dag, c) in [("TR-1024 (250ms)", &tr_dag, &cfg), ("SVD2 50k", &svd_dag, &svd_cfg)]
    {
        let wk = run_wukong(dag, c, c.seed).metrics;
        let np = run_numpywren(dag, c, c.seed);
        let dk = run_dask(dag, c, &DaskConfig::workers_1000(), c.seed);
        for (engine, m) in [("wukong", wk), ("numpywren", np), ("dask-1000", dk)] {
            t.row(vec![
                name.to_string(),
                engine.to_string(),
                human_secs(m.makespan_s),
                m.executors_used.to_string(),
                human_bytes(m.kvs.bytes_written as f64),
                format!("${:.4}", m.dollars()),
            ]);
        }
    }
    println!("{}", t.render());
    println!(
        "(decentralized scheduling + clustering + delayed I/O; see \
         `wukong figure all` for the full paper reproduction)"
    );
}
