//! Real blocked GEMM through the PJRT kernels with a correctness check
//! against a plain-Rust reference, plus the Wukong-vs-stateless I/O
//! comparison on the same job (the Fig. 13/15 story at laptop scale).
//!
//! ```bash
//! make artifacts && cargo run --release --example gemm_locality
//! ```

use std::sync::Arc;
use std::time::Duration;

use wukong::engine::compute::seed_inputs;
use wukong::engine::{run_real_numpywren, run_real_wukong, RealConfig};
use wukong::runtime::{default_artifact_dir, SharedRuntime, Tensor};
use wukong::storage::real_kvs::RealKvs;
use wukong::util::stats::human_bytes;
use wukong::workloads::gemm;

fn matmul_ref(a: &Tensor, b: &Tensor) -> Vec<f32> {
    let (m, k) = (a.shape[0], a.shape[1]);
    let n = b.shape[1];
    let mut c = vec![0f32; m * n];
    for i in 0..m {
        for kk in 0..k {
            let av = a.data[i * k + kk];
            for j in 0..n {
                c[i * n + j] += av * b.data[kk * n + j];
            }
        }
    }
    c
}

fn main() -> anyhow::Result<()> {
    let p = gemm::GemmParams { n: 1024, block: 256 }; // 4x4 blocks
    let dag = gemm::dag(p);
    println!(
        "GEMM {}x{} ({} blocked tasks: {} multiplies + {} adds)",
        p.n,
        p.n,
        dag.len(),
        p.nb().pow(3),
        dag.len() - p.nb().pow(3)
    );

    let rt = SharedRuntime::load(&default_artifact_dir())?;
    rt.warmup()?;
    let cfg = RealConfig {
        invoke_latency: Duration::from_millis(1),
        ..RealConfig::default()
    };

    let kvs = RealKvs::new(16, 0.0, 0.0);
    let seeded = seed_inputs(&dag, &kvs, 99);
    let base = kvs.bytes_written.load(std::sync::atomic::Ordering::SeqCst);
    let wk = run_real_wukong(&dag, Arc::clone(&rt), kvs, cfg.clone())?;
    println!(
        "wukong: {:?}, {} executors, intermediates {}",
        wk.makespan,
        wk.executors_used,
        human_bytes((wk.kvs_bytes_written - base) as f64)
    );

    // Verify C[0,1] = Σ_k A[0,k]·B[k,1] against the naive reference.
    let nb = p.nb();
    let mut want = vec![0f32; 256 * 256];
    for k in 0..nb {
        let bundle = &seeded
            .iter()
            .find(|(key, _)| key == &format!("in:mul_0_1_{k}"))
            .unwrap()
            .1;
        let partial = matmul_ref(&bundle[0], &bundle[1]);
        for (w, x) in want.iter_mut().zip(partial) {
            *w += x;
        }
    }
    let got = wk
        .outputs
        .iter()
        .find(|(name, _)| name.starts_with("acc_0_1"))
        .map(|(_, o)| &o[0])
        .expect("C[0,1]");
    let mut worst = 0f32;
    for i in (0..want.len()).step_by(997) {
        worst = worst.max((got.data[i] - want[i]).abs() / (1.0 + want[i].abs()));
    }
    println!("verify: worst relative error on C[0,1] samples = {worst:.2e}");
    assert!(worst < 1e-3);

    let kvs = RealKvs::new(16, 0.0, 0.0);
    seed_inputs(&dag, &kvs, 99);
    let np = run_real_numpywren(&dag, rt, kvs, cfg)?;
    println!(
        "numpywren: {:?}, intermediates {}",
        np.makespan,
        human_bytes((np.kvs_bytes_written - base) as f64)
    );
    println!(
        "=> wukong moves {:.1}x less intermediate data (paper Fig. 15: \
         45-85% less)",
        (np.kvs_bytes_written - base) as f64
            / (wk.kvs_bytes_written - base).max(1) as f64
    );
    Ok(())
}
