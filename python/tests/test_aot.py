"""AOT pipeline checks: lowering emits parseable HLO text + sane manifest."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_smoke():
    lowered = jax.jit(model.tr_add).lower(
        jax.ShapeDtypeStruct((64,), jnp.float32),
        jax.ShapeDtypeStruct((64,), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text


def test_hlo_text_has_no_custom_calls():
    # The Rust-side xla_extension runtime has no jaxlib custom-call registry;
    # every artifact op must lower to plain HLO.
    for name, (fn, specs, _) in aot.ARTIFACTS.items():
        lowered = jax.jit(fn).lower(*specs)
        text = aot.to_hlo_text(lowered)
        assert "custom-call" not in text, f"{name} lowers to a custom call"


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
    reason="run `make artifacts` first",
)
class TestManifest:
    def setup_method(self):
        with open(os.path.join(ART_DIR, "manifest.json")) as f:
            self.manifest = json.load(f)

    def test_all_ops_present(self):
        assert set(self.manifest["ops"]) == set(aot.ARTIFACTS)

    def test_files_exist_and_nonempty(self):
        for name, entry in self.manifest["ops"].items():
            path = os.path.join(ART_DIR, entry["file"])
            assert os.path.exists(path), name
            assert os.path.getsize(path) > 100, name

    def test_shapes_match_specs(self):
        for name, entry in self.manifest["ops"].items():
            _, specs, _ = aot.ARTIFACTS[name]
            got = [tuple(i["shape"]) for i in entry["inputs"]]
            want = [tuple(s.shape) for s in specs]
            assert got == want, name

    def test_flops_positive(self):
        for name, entry in self.manifest["ops"].items():
            assert entry["flops"] > 0, name
