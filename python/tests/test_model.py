"""Layer-2 task-op correctness: QR, Jacobi eig, SVD/SVC steps vs numpy."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model

RNG = np.random.default_rng(7)


def arr(*shape):
    return jnp.asarray(RNG.standard_normal(shape), jnp.float32)


class TestHouseholderQR:
    @settings(max_examples=10, deadline=None)
    @given(
        m=st.integers(min_value=8, max_value=300),
        n=st.integers(min_value=1, max_value=48),
    )
    def test_reconstruction_and_orthogonality(self, m, n):
        if m < n:
            m = n
        a = arr(m, n)
        q, r = model.householder_qr(a)
        np.testing.assert_allclose(np.asarray(q @ r), np.asarray(a), atol=5e-4)
        np.testing.assert_allclose(
            np.asarray(q.T @ q), np.eye(n), atol=5e-4
        )

    def test_r_upper_triangular(self):
        a = arr(64, 16)
        _, r = model.householder_qr(a)
        np.testing.assert_array_equal(
            np.asarray(jnp.tril(r, -1)), np.zeros((16, 16))
        )

    def test_matches_numpy_abs(self):
        # QR is unique up to column signs; compare |R| and |Q|.
        a = arr(128, 32)
        q, r = model.householder_qr(a)
        qn, rn = np.linalg.qr(np.asarray(a))
        np.testing.assert_allclose(np.abs(r), np.abs(rn), atol=5e-4)
        np.testing.assert_allclose(np.abs(q), np.abs(qn), atol=5e-4)

    def test_rank_deficient_does_not_nan(self):
        a = jnp.zeros((32, 8), jnp.float32)
        q, r = model.householder_qr(a)
        assert not bool(jnp.any(jnp.isnan(q))) and not bool(jnp.any(jnp.isnan(r)))

    def test_paper_block_shape(self):
        a = arr(1024, 128)
        q, r = model.qr_factor(a)
        assert q.shape == (1024, 128) and r.shape == (128, 128)
        np.testing.assert_allclose(np.asarray(q @ r), np.asarray(a), atol=2e-3)


class TestQRMerge:
    def test_merge_reconstructs_stack(self):
        r1, r2 = jnp.triu(arr(32, 32)), jnp.triu(arr(32, 32))
        q, r = model.qr_merge(r1, r2)
        stacked = jnp.concatenate([r1, r2], axis=0)
        np.testing.assert_allclose(np.asarray(q @ r), np.asarray(stacked), atol=5e-4)

    def test_tsqr_two_level_identity(self):
        # Full TSQR over 2 blocks == QR of the concatenated matrix.
        a1, a2 = arr(128, 16), arr(128, 16)
        q1, r1 = model.qr_factor(a1)
        q2, r2 = model.qr_factor(a2)
        qm, r = model.qr_merge(r1, r2)
        gq1 = model.q_apply(qm[:16, :], q1)
        gq2 = model.q_apply(qm[16:, :], q2)
        a = np.concatenate([np.asarray(a1), np.asarray(a2)], axis=0)
        gq = np.concatenate([np.asarray(gq1), np.asarray(gq2)], axis=0)
        np.testing.assert_allclose(gq @ np.asarray(r), a, atol=5e-4)
        np.testing.assert_allclose(gq.T @ gq, np.eye(16), atol=5e-4)


class TestJacobiEigh:
    @settings(max_examples=8, deadline=None)
    @given(n=st.integers(min_value=2, max_value=24))
    def test_eigendecomposition(self, n):
        s = np.asarray(RNG.standard_normal((n, n)), np.float32)
        s = jnp.asarray(s + s.T)
        w, v = model.jacobi_eigh(s)
        np.testing.assert_allclose(
            np.asarray(v @ jnp.diag(w) @ v.T), np.asarray(s), atol=2e-3
        )

    def test_matches_numpy_eigvals(self):
        s = np.asarray(RNG.standard_normal((32, 32)), np.float32)
        s = s + s.T
        w, _ = model.jacobi_eigh(jnp.asarray(s))
        wn = np.sort(np.linalg.eigvalsh(s))[::-1]
        np.testing.assert_allclose(np.asarray(w), wn, atol=2e-3)

    def test_sorted_descending(self):
        s = np.asarray(RNG.standard_normal((16, 16)), np.float32)
        w, _ = model.jacobi_eigh(jnp.asarray(s + s.T))
        w = np.asarray(w)
        assert np.all(np.diff(w) <= 1e-6)


class TestSVD1:
    def test_singular_values_match_numpy(self):
        a = arr(512, 32)
        g = model.gram(a)
        sv, _ = model.svd1_finish(g)
        sn = np.linalg.svd(np.asarray(a), compute_uv=False)
        np.testing.assert_allclose(np.asarray(sv), sn, rtol=1e-2, atol=1e-2)


class TestSVC:
    def test_partial_grad_matches_autodiff(self):
        xb, yb, w = arr(64, 8), arr(64), arr(8)

        def loss(w):
            z = xb @ w
            return jnp.sum(
                jnp.logaddexp(0.0, z) - yb * z
            )

        g_auto = jax.grad(loss)(w)
        g_ours = model.svc_partial_grad(xb, yb, w)
        np.testing.assert_allclose(
            np.asarray(g_ours), np.asarray(g_auto), rtol=1e-3, atol=1e-3
        )

    def test_update_step(self):
        w, g = arr(16), arr(16)
        lr = jnp.asarray([0.1], jnp.float32)
        out = model.svc_update(w, g, lr)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(w) - 0.1 * np.asarray(g),
            rtol=1e-5, atol=1e-6,
        )
