"""Pallas kernel vs pure-jnp oracle — the CORE correctness signal.

Hypothesis sweeps shapes (including non-128-multiples, exercising the
divisor-clipping tile logic) and checks allclose against ref.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

RNG = np.random.default_rng(1234)


def arr(*shape, dtype=np.float32):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


dims = st.integers(min_value=1, max_value=160)
small_dims = st.integers(min_value=1, max_value=96)


class TestMatmul:
    @settings(max_examples=25, deadline=None)
    @given(m=small_dims, k=small_dims, n=small_dims)
    def test_matches_ref(self, m, k, n):
        x, y = arr(m, k), arr(k, n)
        np.testing.assert_allclose(
            kernels.matmul(x, y), ref.matmul(x, y), rtol=1e-4, atol=1e-4
        )

    def test_mxu_shaped_blocks(self):
        x, y = arr(256, 384), arr(384, 128)
        np.testing.assert_allclose(
            kernels.matmul(x, y), ref.matmul(x, y), rtol=1e-4, atol=1e-4
        )

    def test_k_sweep_accumulates_in_order(self):
        # grid K axis must accumulate, not overwrite
        x, y = arr(64, 512), arr(512, 64)
        out = kernels.matmul(x, y, bm=64, bk=128, bn=64)
        np.testing.assert_allclose(out, ref.matmul(x, y), rtol=1e-4, atol=1e-4)

    def test_identity(self):
        x = arr(128, 128)
        eye = jnp.eye(128, dtype=jnp.float32)
        np.testing.assert_allclose(kernels.matmul(x, eye), x, rtol=1e-6)

    def test_rectangular_tiles(self):
        x, y = arr(96, 64), arr(64, 160)
        np.testing.assert_allclose(
            kernels.matmul(x, y, bm=32, bk=32, bn=32),
            ref.matmul(x, y),
            rtol=1e-4,
            atol=1e-4,
        )

    def test_mismatched_inner_dims_raises(self):
        with pytest.raises(AssertionError):
            kernels.matmul(arr(4, 5), arr(6, 4))


class TestMatmulAcc:
    @settings(max_examples=15, deadline=None)
    @given(m=small_dims, k=small_dims, n=small_dims)
    def test_matches_ref(self, m, k, n):
        c, x, y = arr(m, n), arr(m, k), arr(k, n)
        np.testing.assert_allclose(
            kernels.matmul_acc(c, x, y),
            ref.matmul_acc(c, x, y),
            rtol=1e-4,
            atol=1e-4,
        )

    def test_zero_c_equals_matmul(self):
        x, y = arr(64, 64), arr(64, 64)
        z = jnp.zeros((64, 64), jnp.float32)
        np.testing.assert_allclose(
            kernels.matmul_acc(z, x, y), kernels.matmul(x, y), rtol=1e-5
        )


class TestAdd:
    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(min_value=1, max_value=10_000))
    def test_matches_ref(self, n):
        x, y = arr(n), arr(n)
        np.testing.assert_allclose(kernels.add(x, y), ref.add(x, y), rtol=1e-6)

    def test_exact_for_integers_in_float(self):
        x = jnp.arange(4096, dtype=jnp.float32)
        y = jnp.ones(4096, jnp.float32)
        np.testing.assert_array_equal(kernels.add(x, y), x + 1.0)


class TestScaleAdd:
    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(min_value=1, max_value=5_000))
    def test_matches_ref(self, n):
        a, x, y = arr(1), arr(n), arr(n)
        np.testing.assert_allclose(
            kernels.scale_add(a, x, y), ref.scale_add(a, x, y),
            rtol=1e-5, atol=1e-5,
        )


class TestReduce:
    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(min_value=1, max_value=20_000))
    def test_total_sum(self, n):
        x = arr(n)
        np.testing.assert_allclose(
            kernels.total_sum(x), ref.total_sum(x), rtol=1e-3, atol=1e-3
        )

    @settings(max_examples=15, deadline=None)
    @given(m=small_dims, n=small_dims)
    def test_row_sum(self, m, n):
        x = arr(m, n)
        np.testing.assert_allclose(
            kernels.row_sum(x), ref.row_sum(x), rtol=1e-4, atol=1e-4
        )

    def test_total_sum_cross_block_accumulation(self):
        # multiple grid steps must accumulate into the same (1,) output
        x = jnp.ones(8192, jnp.float32)
        assert float(kernels.total_sum(x, block=1024)[0]) == 8192.0
