"""Tiled matmul Pallas kernel — the GEMM-task hot spot (Layer 1).

The paper's GEMM / TSQR / SVD tasks bottom out in dense block matmuls that
numpywren ran through BLAS on Lambda vCPUs. On TPU the same insight
(cache-block the operands) becomes: keep one (bm, bk) tile of A, one
(bk, bn) tile of B and the (bm, bn) accumulator resident in VMEM, sweep the
K dimension in the innermost grid axis so the accumulator is revisited
before eviction, and shape the tiles 128x128 to feed the MXU systolic
array. The ``BlockSpec`` index maps below express exactly the HBM->VMEM
schedule a CUDA kernel would express with threadblock tiling.

VMEM footprint per grid step (f32, 128-tiles):
    A tile + B tile + C tile = 3 * 128*128*4 B = 192 KiB
which leaves ample headroom in a 16 MiB VMEM for double buffering.
MXU work per step: bm*bn*bk = 2^21 MACs.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref):
    """Grid point (i, j, k): o[i,j] += x[i,k] @ y[k,j], zero-init at k==0."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=o_ref.dtype
    )


def _matmul_acc_kernel(c_ref, x_ref, y_ref, o_ref):
    """Grid point (i, j, k): o[i,j] = c[i,j] + sum_k x[i,k] @ y[k,j]."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = c_ref[...]

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=o_ref.dtype
    )


def _block(dim: int, want: int) -> int:
    """Largest tile <= ``want`` that divides ``dim`` (tiles must tile evenly)."""
    b = min(dim, want)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def matmul(x, y, *, bm: int = 128, bk: int = 128, bn: int = 128):
    """C = X @ Y via the tiled Pallas kernel.

    Shapes must be 2-D with an inner-dimension match; tile sizes are clipped
    to divisors of the problem so arbitrary (small) test shapes work.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner dims mismatch: {x.shape} @ {y.shape}"
    bm, bk, bn = _block(m, bm), _block(k, bk), _block(n, bn)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, y)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def matmul_acc(c, x, y, *, bm: int = 128, bk: int = 128, bn: int = 128):
    """O = C + X @ Y — the GEMM inner-product accumulation task.

    The paper's blocked GEMM DAG chains `gemm_acc` tasks over the K block
    index; fusing the addition into the kernel saves one full C round trip
    through HBM per task.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2 and c.shape == (m, n), f"{c.shape} + {x.shape}@{y.shape}"
    bm, bk, bn = _block(m, bm), _block(k, bk), _block(n, bn)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_acc_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), c.dtype),
        interpret=True,
    )(c, x, y)
