"""Reduction Pallas kernels — final TR sum and SVC loss terms.

``total_sum`` streams (block,) tiles and accumulates into a (1,) VMEM
scalar across the grid (sequential grid => the accumulator survives between
steps, the Pallas idiom for cross-step reductions). ``row_sum`` reduces a
(bm, n) panel per grid row.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _total_sum_kernel(x_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.sum(x_ref[...], keepdims=True)


def _row_sum_kernel(x_ref, o_ref):
    o_ref[...] = jnp.sum(x_ref[...], axis=1)


def _block(dim: int, want: int) -> int:
    b = min(dim, want)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block",))
def total_sum(x, *, block: int = 4096):
    """Scalar sum of a 1-D chunk (TR root task). Returns shape (1,)."""
    (n,) = x.shape
    b = _block(n, block)
    return pl.pallas_call(
        _total_sum_kernel,
        grid=(n // b,),
        in_specs=[pl.BlockSpec((b,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), x.dtype),
        interpret=True,
    )(x)


@functools.partial(jax.jit, static_argnames=("bm",))
def row_sum(x, *, bm: int = 128):
    """Per-row sum of a 2-D block — SVC per-sample loss aggregation."""
    m, n = x.shape
    b = _block(m, bm)
    return pl.pallas_call(
        _row_sum_kernel,
        grid=(m // b,),
        in_specs=[pl.BlockSpec((b, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), x.dtype),
        interpret=True,
    )(x)
