"""Elementwise add / axpy Pallas kernels — the tree-reduction task body.

The paper's TR microbenchmark sums adjacent array chunks pass-by-pass; in
Wukong each pass is one Lambda task whose body is ``x + y`` over a chunk.
On TPU this is a pure VPU (vector unit) kernel: stream (block,) tiles of
both operands through VMEM and write the sum. Bandwidth-bound, so the only
tunable is the tile size: large enough to amortize the HBM->VMEM DMA,
small enough to fit (3 tiles resident).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _add_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...] + y_ref[...]


def _scale_add_kernel(a_ref, x_ref, y_ref, o_ref):
    # o = a * x + y with a broadcast scalar held in SMEM-like (1,) block.
    o_ref[...] = a_ref[0] * x_ref[...] + y_ref[...]


def _block(dim: int, want: int) -> int:
    b = min(dim, want)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block",))
def add(x, y, *, block: int = 4096):
    """o = x + y over 1-D chunks (the TR pairwise-add task)."""
    (n,) = x.shape
    assert x.shape == y.shape
    b = _block(n, block)
    return pl.pallas_call(
        _add_kernel,
        grid=(n // b,),
        in_specs=[
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(x, y)


@functools.partial(jax.jit, static_argnames=("block",))
def scale_add(a, x, y, *, block: int = 4096):
    """o = a*x + y (axpy) — used by the SVC gradient-step task."""
    (n,) = x.shape
    assert x.shape == y.shape and a.shape == (1,)
    b = _block(n, block)
    return pl.pallas_call(
        _scale_add_kernel,
        grid=(n // b,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(a, x, y)
