"""Layer-1 Pallas kernels for Wukong task compute hot-spots.

Every kernel here is authored TPU-style (VMEM-tiled BlockSpecs, MXU-shaped
128x128 blocks) but lowered with ``interpret=True`` so the resulting HLO
runs on the CPU PJRT client used by the Rust runtime. See
DESIGN.md "Hardware adaptation".
"""

from .matmul import matmul, matmul_acc
from .add import add, scale_add
from .reduce import row_sum, total_sum

__all__ = [
    "matmul",
    "matmul_acc",
    "add",
    "scale_add",
    "row_sum",
    "total_sum",
]
