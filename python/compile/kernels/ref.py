"""Pure-jnp oracles for every Pallas kernel and L2 op (the ground truth).

pytest asserts ``kernels.* == ref.*`` (allclose) across a hypothesis sweep
of shapes/dtypes; the Rust integration tests re-check the same identities
through the AOT artifacts, closing the loop python->HLO->PJRT->rust.
"""

import jax.numpy as jnp


def matmul(x, y):
    return jnp.dot(x, y, preferred_element_type=x.dtype)


def matmul_acc(c, x, y):
    return c + jnp.dot(x, y, preferred_element_type=c.dtype)


def add(x, y):
    return x + y


def scale_add(a, x, y):
    return a[0] * x + y


def total_sum(x):
    return jnp.sum(x, keepdims=True)


def row_sum(x):
    return jnp.sum(x, axis=1)


def qr(a):
    """Reference thin QR via numpy (NOT lowered — oracle only)."""
    import numpy as np

    q, r = np.linalg.qr(np.asarray(a))
    return jnp.asarray(q), jnp.asarray(r)
