"""AOT pipeline: lower every Layer-2 task op to an HLO-text artifact.

Interchange format is HLO *text*, NOT serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that the Rust side's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out ../artifacts

Emits one ``<op>.hlo.txt`` per entry in ``ARTIFACTS`` plus ``manifest.json``
describing shapes/dtypes/flops for the Rust runtime's artifact registry.
Python runs ONLY here (build time); the Rust binary is self-contained
afterwards.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32


def spec(*shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


# Each artifact: op name -> (callable, example input specs, flops estimate).
# Shapes are the block shapes used by the Rust real engine's workloads
# (examples/ and integration tests); the sim engine uses the analytic cost
# model and is shape-independent.
ARTIFACTS = {
    # -- Tree reduction --------------------------------------------------
    "tr_add_f32_8192": (model.tr_add, [spec(8192), spec(8192)], 8192),
    "tr_root_f32_8192": (model.tr_root, [spec(8192)], 8192),
    # -- Blocked GEMM ----------------------------------------------------
    "gemm_block_f32_256": (
        model.gemm_block,
        [spec(256, 256), spec(256, 256)],
        2 * 256**3,
    ),
    "gemm_acc_f32_256": (
        model.gemm_acc,
        [spec(256, 256), spec(256, 256), spec(256, 256)],
        2 * 256**3 + 256**2,
    ),
    "block_add_f32_256": (
        model.block_add,
        [spec(256, 256), spec(256, 256)],
        256**2,
    ),
    # -- TSQR ---------------------------------------------------------------
    "qr_factor_f32_1024x128": (
        model.qr_factor,
        [spec(1024, 128)],
        4 * 1024 * 1024 * 128,  # O(m^2 n) for the P-accumulating variant
    ),
    "qr_merge_f32_128": (
        model.qr_merge,
        [spec(128, 128), spec(128, 128)],
        4 * 256 * 256 * 128,
    ),
    "q_apply_leaf_f32_1024x128": (
        model.q_apply,
        [spec(128, 128), spec(1024, 128)],
        2 * 1024 * 128 * 128,
    ),
    "q_apply_half_f32_128": (
        model.q_apply,
        [spec(128, 128), spec(128, 128)],
        2 * 128**3,
    ),
    # -- SVD1 substrate ----------------------------------------------------
    "gram_f32_1024x128": (model.gram, [spec(1024, 128)], 2 * 1024 * 128 * 128),
    "svd1_finish_f32_128": (
        model.svd1_finish,
        [spec(128, 128)],
        12 * (128 * 127 // 2) * 12 * 128,  # sweeps * pairs * O(n) updates
    ),
    # -- SVC -----------------------------------------------------------------
    "svc_grad_f32_1024x64": (
        model.svc_partial_grad,
        [spec(1024, 64), spec(1024), spec(64)],
        4 * 1024 * 64,
    ),
    "svc_update_f32_64": (
        model.svc_update,
        [spec(64), spec(64), spec(1)],
        2 * 64,
    ),
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_name(d) -> str:
    return jnp.dtype(d).name


def lower_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "return_tuple": True, "ops": {}}
    for name, (fn, in_specs, flops) in sorted(ARTIFACTS.items()):
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_avals = lowered.out_info
        outs = jax.tree_util.tree_leaves(out_avals)
        manifest["ops"][name] = {
            "file": fname,
            "inputs": [
                {"shape": list(s.shape), "dtype": _dtype_name(s.dtype)}
                for s in in_specs
            ],
            "outputs": [
                {"shape": list(o.shape), "dtype": _dtype_name(o.dtype)}
                for o in outs
            ],
            "flops": int(flops),
        }
        print(f"  {name}: {len(text)} chars -> {fname}")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument(
        "--only", default=None, help="comma-separated op subset (debug)"
    )
    args = ap.parse_args()
    global ARTIFACTS
    if args.only:
        keep = set(args.only.split(","))
        ARTIFACTS = {k: v for k, v in ARTIFACTS.items() if k in keep}
    manifest = lower_all(args.out)
    print(f"wrote {len(manifest['ops'])} artifacts to {args.out}")


if __name__ == "__main__":
    main()
