"""Layer-2 task compute graphs (JAX), calling the Layer-1 Pallas kernels.

Each public function here is one *task body* in the paper's DAG workloads
(TR, GEMM, TSQR, SVD, SVC). `aot.py` lowers each to an HLO-text artifact
that the Rust coordinator executes through PJRT on the request path —
Python never runs at serve time.

Linear-algebra primitives that jaxlib implements as LAPACK custom-calls
(`jnp.linalg.qr`, `cholesky`, `svd`, `eigh`) CANNOT appear here: the
standalone xla_extension runtime has no jaxlib custom-call registry. QR is
therefore a blocked Householder factorization in pure jnp ops (fori_loop +
dot + where), and the SVD small-matrix step is a cyclic Jacobi eigensolver
— both lower to plain HLO (while / dot / select).
"""

import functools

import jax
import jax.numpy as jnp

from . import kernels


# --------------------------------------------------------------------------
# TR — tree reduction task bodies
# --------------------------------------------------------------------------

def tr_add(x, y):
    """One TR pass step: elementwise sum of two sibling chunks."""
    return kernels.add(x, y)


def tr_root(x):
    """TR root: collapse the last chunk to a (1,) scalar."""
    return kernels.total_sum(x)


# --------------------------------------------------------------------------
# GEMM — blocked matrix-multiply task bodies
# --------------------------------------------------------------------------

def gemm_block(a, b):
    """C_ij partial product for one (i, k, j) block triple."""
    return kernels.matmul(a, b)


def gemm_acc(c, a, b):
    """C_ij += A_ik @ B_kj — the K-chain accumulation task."""
    return kernels.matmul_acc(c, a, b)


def block_add(x, y):
    """Pairwise reduction of partial products (tree-sum over K)."""
    m, n = x.shape
    return kernels.add(x.reshape(m * n), y.reshape(m * n)).reshape(m, n)


# --------------------------------------------------------------------------
# QR — blocked Householder factorization (TSQR / SVD substrate)
# --------------------------------------------------------------------------

def householder_qr(a):
    """Thin QR of a tall-skinny block via Householder reflections.

    Returns (Q: (m, n), R: (n, n)) with A = Q @ R, Q^T Q = I. Pure jnp ops
    only: two `fori_loop`s of rank-1 updates (outer products -> HLO dot),
    so the whole factorization lowers to plain HLO while-loops.

    Two-pass thin-Q formulation (EXPERIMENTS.md §Perf L2): the R pass
    stores the unit reflectors V (m, n) instead of accumulating the full
    m×m product, and the Q pass applies them in reverse to the thin
    identity — O(m·n²) total instead of O(m²·n), a ~4× flop cut at the
    paper's (1024, 128) block shape.
    """
    m, n = a.shape
    idx = jnp.arange(m)

    def r_pass(j, carry):
        r, vs = carry
        col = r[:, j]
        mask = idx >= j
        x = jnp.where(mask, col, 0.0)
        normx = jnp.sqrt(jnp.sum(x * x))
        sign = jnp.where(x[j] >= 0.0, 1.0, -1.0)
        alpha = -sign * normx
        v = x - alpha * (idx == j).astype(a.dtype)
        vnorm = jnp.sqrt(jnp.sum(v * v))
        # Guard the (already upper-triangular) zero-column case.
        v = jnp.where(vnorm > 0.0, v / jnp.maximum(vnorm, 1e-30), v)
        r = r - jnp.outer(2.0 * v, v @ r)
        vs = vs.at[:, j].set(v)
        return r, vs

    r, vs = jax.lax.fori_loop(
        0, n, r_pass, (a, jnp.zeros((m, n), a.dtype))
    )

    def q_pass(i, q):
        j = n - 1 - i  # reflectors applied in reverse: Q = H_1 … H_n I
        v = vs[:, j]
        return q - jnp.outer(2.0 * v, v @ q)

    q = jax.lax.fori_loop(0, n, q_pass, jnp.eye(m, n, dtype=a.dtype))
    r = jnp.triu(r[:n, :])              # clamp numerical noise below diag
    return q, r


def qr_factor(a):
    """TSQR leaf task: factor one (m, n) input block."""
    return householder_qr(a)


def qr_merge(r_top, r_bot):
    """TSQR merge task: QR of two stacked (n, n) R factors.

    Returns (Q: (2n, n), R: (n, n)). The Q is needed to reconstruct the
    global Q factor down the tree.
    """
    stacked = jnp.concatenate([r_top, r_bot], axis=0)
    return householder_qr(stacked)


def q_apply(q_parent_half, q_child):
    """Back-propagate Q down the TSQR tree: Q_global_block = Q_child @ Q_half."""
    return kernels.matmul(q_child, q_parent_half)


# --------------------------------------------------------------------------
# SVD substrate — Gram + Jacobi eigensolver (pure HLO)
# --------------------------------------------------------------------------

def gram(a):
    """A^T A for the tall-skinny SVD (SVD1) normal-equations path."""
    return kernels.matmul(a.T, a)


@functools.partial(jax.jit, static_argnames=("sweeps",))
def jacobi_eigh(s, sweeps: int = 12):
    """Eigendecomposition of a small symmetric matrix by cyclic Jacobi.

    Returns (eigenvalues desc-sorted, eigenvectors as columns). Lowers to
    an HLO while-loop of Givens row/column rotations (dynamic-update-slice
    + vector math, O(n) per rotation) — plain HLO, no custom calls.
    """
    n = s.shape[0]

    def rotate(carry, pq):
        a, v = carry
        p, q = pq[0], pq[1]
        app, aqq, apq = a[p, p], a[q, q], a[p, q]
        # Stable rotation angle (Golub & Van Loan §8.5).
        tau = (aqq - app) / (2.0 * jnp.where(apq == 0.0, 1e-30, apq))
        t = jnp.sign(tau) / (jnp.abs(tau) + jnp.sqrt(1.0 + tau * tau))
        t = jnp.where(apq == 0.0, 0.0, t)
        c = 1.0 / jnp.sqrt(1.0 + t * t)
        sn = t * c
        # A <- J^T A J applied as two rank-limited row/col updates.
        rowp, rowq = a[p, :], a[q, :]
        a = a.at[p, :].set(c * rowp - sn * rowq)
        a = a.at[q, :].set(sn * rowp + c * rowq)
        colp, colq = a[:, p], a[:, q]
        a = a.at[:, p].set(c * colp - sn * colq)
        a = a.at[:, q].set(sn * colp + c * colq)
        vp, vq = v[:, p], v[:, q]
        v = v.at[:, p].set(c * vp - sn * vq)
        v = v.at[:, q].set(sn * vp + c * vq)
        return (a, v), None

    pairs = jnp.array(
        [(p, q) for p in range(n) for q in range(p + 1, n)], dtype=jnp.int32
    )

    def sweep(_, carry):
        carry, _ = jax.lax.scan(rotate, carry, pairs)
        return carry

    a, v = jax.lax.fori_loop(
        0, sweeps, sweep, (s, jnp.eye(n, dtype=s.dtype))
    )
    w = jnp.diagonal(a)
    order = jnp.argsort(-w)
    return w[order], v[:, order]


def svd1_finish(g):
    """SVD1 final task: eig of the (n, n) Gram matrix -> singular values."""
    w, v = jacobi_eigh(g)
    return jnp.sqrt(jnp.maximum(w, 0.0)), v


# --------------------------------------------------------------------------
# SVC — logistic/hinge gradient-step task bodies (Dask-ML style)
# --------------------------------------------------------------------------

def svc_partial_grad(xb, yb, w):
    """Per-partition gradient of the logistic loss: X^T (sigmoid(Xw) - y)."""
    m, n = xb.shape
    z = kernels.matmul(xb, w.reshape(n, 1)).reshape(m)
    p = jax.nn.sigmoid(z)
    return kernels.matmul(xb.T, (p - yb).reshape(m, 1)).reshape(n)


def svc_update(w, g, lr):
    """w' = w - lr * g via the axpy kernel."""
    return kernels.scale_add(-lr, g, w)
