//! Offline subset of the `anyhow` error crate.
//!
//! The build environment has no crate registry, so this path dependency
//! provides the exact API surface the repo uses: [`Error`], [`Result`],
//! the [`anyhow!`] / [`bail!`] macros, and the [`Context`] extension
//! trait. Semantics match upstream for that subset: `Error` is an opaque
//! dynamic error that any `std::error::Error + Send + Sync + 'static`
//! converts into via `?`, and context lines prepend the cause.

use std::error::Error as StdError;
use std::fmt;

/// An opaque, context-carrying error (subset of `anyhow::Error`).
///
/// Deliberately does **not** implement `std::error::Error`, exactly like
/// upstream anyhow — that is what makes the blanket `From` impl below
/// coherent with `impl<T> From<T> for T`.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
            source: None,
        }
    }

    /// Wrap a standard error.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error {
            msg: error.to_string(),
            source: Some(Box::new(error)),
        }
    }

    /// Prepend a context line (`{context}: {cause}`), keeping the source.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: format!("{context}: {}", self.msg),
            source: self.source,
        }
    }

    /// The root cause, when this error wraps a standard error.
    pub fn source(&self) -> Option<&(dyn StdError + 'static)> {
        match &self.source {
            Some(boxed) => {
                let cause: &(dyn StdError + 'static) = &**boxed;
                Some(cause)
            }
            None => None,
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let mut cur = self.source();
        let mut first = true;
        while let Some(e) = cur {
            if first {
                write!(f, "\n\nCaused by:")?;
                first = false;
            }
            write!(f, "\n    {e}")?;
            cur = e.source();
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// `anyhow::Result`: defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(|| ...)`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Early-return an `Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(f().is_err());
    }

    #[test]
    fn context_prepends() {
        let e: Result<()> = std::result::Result::<(), _>::Err(io_err())
            .with_context(|| "reading manifest".to_string());
        let msg = format!("{}", e.unwrap_err());
        assert!(msg.starts_with("reading manifest: "), "{msg}");
    }

    #[test]
    fn bail_and_anyhow_format() {
        fn f(x: u32) -> Result<u32> {
            if x == 0 {
                bail!("zero not allowed (got {x})");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(0).unwrap_err()), "zero not allowed (got 0)");
    }

    #[test]
    fn debug_prints_cause_chain() {
        let e = Error::new(io_err()).context("top");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
    }
}
