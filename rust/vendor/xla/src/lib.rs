//! Offline stub of the `xla` crate (xla-rs PJRT bindings).
//!
//! The real crate links the multi-hundred-MB `xla_extension` C++ bundle,
//! which this environment does not ship. This stub mirrors the API
//! surface `wukong::runtime` uses so the crate builds and the simulator /
//! conformance paths run everywhere; any attempt to actually create a
//! PJRT client or execute an artifact returns a clear runtime error, and
//! callers (the real-engine tests, `wukong serve`) skip or report it.
//!
//! Swapping in real PJRT = point the `xla` dependency in rust/Cargo.toml
//! at the real crate; no `wukong` source changes needed.

use std::fmt;

/// Error type for every stubbed operation.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT backend unavailable — wukong was built against the \
         offline `xla` stub (rust/vendor/xla); install the real xla crate \
         + xla_extension to enable real compute"
    )))
}

/// Stub of the PJRT client. Creation always fails (no backend).
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Stub of a parsed HLO module proto.
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Stub of an XLA computation.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Stub of a compiled+loaded executable.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Stub of a device buffer.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Stub of a host literal.
#[derive(Clone)]
pub struct Literal(());

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creation_reports_stub() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        let msg = err.to_string();
        assert!(msg.contains("offline `xla` stub"), "{msg}");
    }

    #[test]
    fn literal_construction_is_allowed() {
        // Building literals must not panic (runtime builds them before
        // execute()); only execution paths error.
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
    }
}
