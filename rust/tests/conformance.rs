//! The differential conformance matrix under plain `cargo test -q`:
//! every sim-path engine, the full policy-knob matrix, and the corpus of
//! regular + irregular DAG shapes — no artifacts required.
//!
//! This is the regression gate the ROADMAP's "refactor freely" license
//! leans on: a scheduling/perf refactor that breaks exactly-once,
//! completion, per-seed determinism or the paper's locality ordering
//! fails here with a replayable case seed.

use wukong::config::Config;
use wukong::dag::{Dag, DagBuilder, OpKind};
use wukong::engine::{sim_registry, Engine};
use wukong::util::Rng;
use wukong::verify::{corpus, diff, run_verify, VerifyOptions};

/// The acceptance matrix: 25 generated DAGs through every registered
/// engine (≥ 3), mirroring `wukong verify --runs 25 --seed 7`.
#[test]
fn differential_matrix_runs_clean() {
    let summary = run_verify(&VerifyOptions {
        runs: 25,
        seed: 7,
        ..VerifyOptions::default()
    })
    .expect("default options are valid");
    assert_eq!(summary.cases, 25);
    assert!(
        summary.engines.len() >= 3,
        "need ≥ 3 engines, got {:?}",
        summary.engines
    );
    assert!(
        summary.violations.is_empty(),
        "conformance violations:\n{}",
        summary.violations.join("\n")
    );
    // wukong's 8-combo knob matrix ×2 runs + 4 baselines ×2, per case
    assert_eq!(summary.engine_runs, 25 * 24);
}

/// The §3.6 fault axis (`wukong verify --faults`): on top of the base
/// matrix, every fault-capable engine sweeps `corpus::fault_matrix()`
/// (p_fail × max_retries) with a fault-free reference run, asserting
/// retry bounds, completed-⊕-failed totality, determinism under faults
/// and p_fail=0 bit-identity to fault-free.
#[test]
fn faulty_matrix_runs_clean() {
    let summary = run_verify(&VerifyOptions {
        runs: 8,
        seed: 7,
        faults: true,
        ..VerifyOptions::default()
    })
    .expect("default options are valid");
    assert_eq!(summary.cases, 8);
    assert!(
        summary.violations.is_empty(),
        "fault-axis violations:\n{}",
        summary.violations.join("\n")
    );
    // base 24 + 5 engines × (1 reference + 8 fault plans × 2), per case
    assert_eq!(summary.engine_runs, 8 * (24 + 5 * 17));
}

/// The durable-KVS crash axis (`wukong verify --crashes`): on top of
/// the base matrix, every fault-capable engine sweeps
/// `corpus::crash_matrix()` under both durability profiles
/// (`corpus::crash_profiles`), each anchored by its own uninterrupted
/// reference run. The recovery gate: a crashed-and-recovered run is
/// byte-identical to the reference in every data-plane metric — task
/// outcomes, KVS/WAL byte meters, event counts, makespan — with only
/// `recoveries`/`replayed_ops`/`stall_s` allowed to differ, and
/// `p_crash=0` plans fully bit-identical.
#[test]
fn crash_recovery_matrix_runs_clean() {
    let summary = run_verify(&VerifyOptions {
        runs: 6,
        seed: 7,
        crashes: true,
        ..VerifyOptions::default()
    })
    .expect("default options are valid");
    assert_eq!(summary.cases, 6);
    assert!(
        summary.violations.is_empty(),
        "crash-axis violations:\n{}",
        summary.violations.join("\n")
    );
    // base 24 + 5 engines × 2 profiles × (1 reference + 4 plans × 2)
    assert_eq!(summary.engine_runs, 6 * (24 + 5 * 18));
}

/// The multi-tenant serving axis (`wukong verify --serving`): on top of
/// the base matrix, every case multiplexes `corpus::arrival_matrix()`
/// job streams over the shared Lambda pool + KVS (alternating FIFO and
/// weighted-fair admission), each session run twice. Gates: job
/// conservation (admitted = completed ⊕ failed, partitioned exactly by
/// the per-tenant rollups), byte-identical replays, and the zero-rate
/// plan admitting nothing.
#[test]
fn serving_matrix_runs_clean() {
    let summary = run_verify(&VerifyOptions {
        runs: 4,
        seed: 7,
        serving: true,
        ..VerifyOptions::default()
    })
    .expect("default options are valid");
    assert_eq!(summary.cases, 4);
    assert!(
        summary.violations.is_empty(),
        "serving-axis violations:\n{}",
        summary.violations.join("\n")
    );
    // base 24 + 2 sessions × 3 live plans × SERVING_JOBS admitted jobs
    // (each admitted job is one engine run; the zero-rate plan admits 0)
    assert_eq!(
        summary.engine_runs,
        4 * (24 + 2 * 3 * corpus::SERVING_JOBS)
    );
}

/// The dynamic-DAG axis (`wukong verify --dynamic`): on top of the base
/// matrix, every spawn-capable engine sweeps `corpus::spawn_matrix()`.
/// Each live plan runs dynamically (plus a determinism replay) and is
/// gated byte-for-byte against the statically pre-expanded equivalent
/// DAG run plan-free; completion/exactly-once/fault-contract are checked
/// against the *expanded* task set; the zero-rate plan must be
/// bit-identical to the plan-free reference.
#[test]
fn dynamic_matrix_runs_clean() {
    let summary = run_verify(&VerifyOptions {
        runs: 4,
        seed: 7,
        dynamic: true,
        ..VerifyOptions::default()
    })
    .expect("default options are valid");
    assert_eq!(summary.cases, 4);
    assert!(
        summary.violations.is_empty(),
        "dynamic-axis violations:\n{}",
        summary.violations.join("\n")
    );
    // base 24 + 5 engines × (1 plan-free reference + 4 live plans ×
    // (dynamic + rerun + pre-expanded) + 1 zero-rate run)
    assert_eq!(summary.engine_runs, 4 * (24 + 5 * 14));
}

/// Satellite: the dynamic-axis sweep stays byte-identical to
/// `--threads 1` (spawn expansion is a pure function of the run seed —
/// no cross-case leakage through worker reuse).
#[test]
fn dynamic_sweep_is_thread_count_invariant() {
    let base = VerifyOptions {
        runs: 3,
        seed: 53,
        dynamic: true,
        ..VerifyOptions::default()
    };
    let seq = run_verify(&VerifyOptions {
        threads: 1,
        ..base.clone()
    })
    .unwrap();
    let par = run_verify(&VerifyOptions {
        threads: 3,
        ..base
    })
    .unwrap();
    assert_eq!(seq, par);
}

/// Satellite: the serving-axis sweep stays byte-identical to
/// `--threads 1` (arrival streams are per-session state salted off the
/// run seed — no cross-case leakage through worker reuse).
#[test]
fn serving_sweep_is_thread_count_invariant() {
    let base = VerifyOptions {
        runs: 3,
        seed: 47,
        serving: true,
        ..VerifyOptions::default()
    };
    let seq = run_verify(&VerifyOptions {
        threads: 1,
        ..base.clone()
    })
    .unwrap();
    let par = run_verify(&VerifyOptions {
        threads: 3,
        ..base
    })
    .unwrap();
    assert_eq!(seq, par);
}

/// Satellite: the crash-axis sweep stays byte-identical to `--threads 1`
/// (crash streams are per-run state, like fault streams — no cross-case
/// leakage through worker reuse).
#[test]
fn crash_sweep_is_thread_count_invariant() {
    let base = VerifyOptions {
        runs: 4,
        seed: 41,
        crashes: true,
        ..VerifyOptions::default()
    };
    let seq = run_verify(&VerifyOptions {
        threads: 1,
        ..base.clone()
    })
    .unwrap();
    let par = run_verify(&VerifyOptions {
        threads: 3,
        ..base
    })
    .unwrap();
    assert_eq!(seq, par);
}

/// Satellite: the pooled sweep stays byte-identical to `--threads 1`
/// when the fault axis is on (fault streams are per-run state, so no
/// cross-case leakage through worker reuse).
#[test]
fn faulty_sweep_is_thread_count_invariant() {
    let base = VerifyOptions {
        runs: 5,
        seed: 13,
        faults: true,
        ..VerifyOptions::default()
    };
    let seq = run_verify(&VerifyOptions {
        threads: 1,
        ..base.clone()
    })
    .unwrap();
    let par = run_verify(&VerifyOptions {
        threads: 3,
        ..base
    })
    .unwrap();
    assert_eq!(seq, par);
}

/// Satellite: same seed ⇒ byte-identical `RunMetrics` across two runs of
/// each sim-path engine (catches accidental HashMap-iteration
/// nondeterminism introduced during engine refactors).
#[test]
fn determinism_same_seed_byte_identical_metrics() {
    let mut rng = Rng::new(0xD_E7E_12);
    for case in 0..6u64 {
        let dag = corpus::random_dag(&mut rng);
        let cfg = corpus::random_config(&mut rng);
        let seed = rng.next_u64();
        for engine in sim_registry() {
            let a = engine.run(&dag, &cfg, seed);
            let b = engine.run(&dag, &cfg, seed);
            assert_eq!(
                a.metrics,
                b.metrics,
                "{} metrics diverged on case {case} (dag {})",
                engine.name(),
                dag.name
            );
            assert_eq!(a.sim_events, b.sim_events, "{}", engine.name());
        }
    }
}

/// The conformance path constructs engines only through the shared trait
/// registry — and the registry names stay stable for the CLI.
#[test]
fn registry_covers_the_paper_comparison_set() {
    let names: Vec<&str> = sim_registry().iter().map(|e| e.name()).collect();
    for expected in ["wukong", "numpywren", "pywren", "dask125", "dask1000"] {
        assert!(names.contains(&expected), "missing engine {expected}");
    }
}

/// Engine filtering and unknown-engine handling of the verify options.
#[test]
fn verify_engine_selection() {
    let s = run_verify(&VerifyOptions {
        engines: vec!["wukong".into(), "numpywren".into(), "dask125".into()],
        runs: 3,
        seed: 21,
        ..VerifyOptions::default()
    })
    .unwrap();
    assert_eq!(s.engines, vec!["wukong", "numpywren", "dask125"]);
    assert!(s.violations.is_empty(), "{:#?}", s.violations);

    let err = run_verify(&VerifyOptions {
        engines: vec!["spark".into()],
        runs: 1,
        ..VerifyOptions::default()
    })
    .unwrap_err();
    assert!(err.contains("unknown engine"), "{err}");
}

fn irregular_sampler() -> Vec<Dag> {
    let mut rng = Rng::new(42);
    vec![
        corpus::skewed_fanout(&mut rng),
        corpus::diamond_stack(&mut rng),
        corpus::long_chain(&mut rng),
        corpus::multi_sink(&mut rng),
        corpus::wide_fanin(&mut rng),
    ]
}

/// The locality ordering invariant, asserted directly on every irregular
/// shape: Wukong never moves more KVS bytes than the stateless closed
/// form, and numpywren's meters match the closed form exactly.
#[test]
fn locality_ordering_holds_on_every_irregular_shape() {
    let cfg = Config::default();
    for dag in irregular_sampler() {
        let engines = sim_registry();
        let wukong = engines.iter().find(|e| e.name() == "wukong").unwrap();
        let numpywren =
            engines.iter().find(|e| e.name() == "numpywren").unwrap();
        let wk = wukong.run(&dag, &cfg, 5);
        let np = numpywren.run(&dag, &cfg, 5);
        diff::check_locality(&dag, &wk)
            .unwrap_or_else(|e| panic!("{}: {e}", dag.name));
        diff::check_stateless_model(&dag, &np)
            .unwrap_or_else(|e| panic!("{}: {e}", dag.name));
        assert!(
            wk.metrics.kvs.bytes_written <= np.metrics.kvs.bytes_written,
            "{}: wukong wrote {} > numpywren {}",
            dag.name,
            wk.metrics.kvs.bytes_written,
            np.metrics.kvs.bytes_written
        );
    }
}

/// Per-task execution counts flow through the trait for every engine,
/// even on a hand-built fan-in DAG with a zero-byte edge.
#[test]
fn per_task_counts_cover_zero_byte_edges() {
    let mut b = DagBuilder::new("zero-edge");
    let a = b.task("a", OpKind::Generic, 1e6, 0); // zero-byte output
    let x = b.task("x", OpKind::Generic, 1e6, 300 * 1024); // > inline max
    let z = b.task("z", OpKind::Generic, 1e6, 64);
    b.edge(a, z).edge(x, z);
    let dag = b.build().unwrap();
    for engine in sim_registry() {
        let rep = engine.run(&dag, &Config::default(), 9);
        diff::check_completion(&dag, &rep)
            .unwrap_or_else(|e| panic!("{e}"));
        diff::check_exactly_once(&dag, &rep)
            .unwrap_or_else(|e| panic!("{e}"));
    }
}
