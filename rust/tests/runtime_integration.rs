//! PJRT runtime integration: load every AOT artifact, execute it, and
//! check the numerics against plain-Rust references — closing the
//! python→HLO→PJRT→Rust loop.
//!
//! Requires the AOT artifacts (`make artifacts`) and a real PJRT backend;
//! when either is missing every test *skips* with a message instead of
//! failing, so plain `cargo test -q` stays green out of the box.

use wukong::runtime::{SharedRuntime, Tensor};
use wukong::util::Rng;

/// The shared runtime, or `None` (with a skip message) when artifacts /
/// PJRT are unavailable in this environment.
fn rt() -> Option<std::sync::Arc<SharedRuntime>> {
    let rt = SharedRuntime::try_load_default();
    if rt.is_none() {
        eprintln!(
            "skipping runtime test: AOT artifacts or the PJRT backend are \
             unavailable (run `make artifacts`)"
        );
    }
    rt
}

fn tensor(rng: &mut Rng, shape: &[usize]) -> Tensor {
    let n = shape.iter().product();
    Tensor::new(shape.to_vec(), rng.f32_vec(n))
}

fn matmul_ref(a: &Tensor, b: &Tensor) -> Vec<f32> {
    let (m, k) = (a.shape[0], a.shape[1]);
    let n = b.shape[1];
    let mut c = vec![0f32; m * n];
    for i in 0..m {
        for kk in 0..k {
            let av = a.data[i * k + kk];
            for j in 0..n {
                c[i * n + j] += av * b.data[kk * n + j];
            }
        }
    }
    c
}

fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= tol * (1.0 + w.abs()),
            "{what}[{i}]: {g} vs {w}"
        );
    }
}

#[test]
fn manifest_lists_all_ops() {
    let Some(rt) = rt() else { return };
    let names = rt.op_names();
    for expected in [
        "tr_add_f32_8192",
        "tr_root_f32_8192",
        "gemm_block_f32_256",
        "gemm_acc_f32_256",
        "block_add_f32_256",
        "qr_factor_f32_1024x128",
        "qr_merge_f32_128",
        "q_apply_leaf_f32_1024x128",
        "q_apply_half_f32_128",
        "gram_f32_1024x128",
        "svd1_finish_f32_128",
        "svc_grad_f32_1024x64",
        "svc_update_f32_64",
    ] {
        assert!(names.iter().any(|n| n == expected), "missing {expected}");
    }
}

#[test]
fn tr_add_matches_cpu() {
    let Some(rt) = rt() else { return };
    let mut rng = Rng::new(1);
    let x = tensor(&mut rng, &[8192]);
    let y = tensor(&mut rng, &[8192]);
    let out = rt.execute("tr_add_f32_8192", &[x.clone(), y.clone()]).unwrap();
    let want: Vec<f32> = x.data.iter().zip(&y.data).map(|(a, b)| a + b).collect();
    assert_close(&out[0].data, &want, 1e-6, "tr_add");
}

#[test]
fn tr_root_sums() {
    let Some(rt) = rt() else { return };
    let x = Tensor::new(vec![8192], vec![0.5f32; 8192]);
    let out = rt.execute("tr_root_f32_8192", &[x]).unwrap();
    assert_eq!(out[0].shape, vec![1]);
    assert!((out[0].data[0] - 4096.0).abs() < 0.5);
}

#[test]
fn gemm_block_matches_naive_matmul() {
    let Some(rt) = rt() else { return };
    let mut rng = Rng::new(2);
    let a = tensor(&mut rng, &[256, 256]);
    let b = tensor(&mut rng, &[256, 256]);
    let out = rt
        .execute("gemm_block_f32_256", &[a.clone(), b.clone()])
        .unwrap();
    assert_close(&out[0].data, &matmul_ref(&a, &b), 3e-4, "gemm_block");
}

#[test]
fn gemm_acc_adds_c() {
    let Some(rt) = rt() else { return };
    let mut rng = Rng::new(3);
    let c = tensor(&mut rng, &[256, 256]);
    let a = tensor(&mut rng, &[256, 256]);
    let b = tensor(&mut rng, &[256, 256]);
    let out = rt
        .execute("gemm_acc_f32_256", &[c.clone(), a.clone(), b.clone()])
        .unwrap();
    let mut want = matmul_ref(&a, &b);
    for (w, cv) in want.iter_mut().zip(&c.data) {
        *w += cv;
    }
    assert_close(&out[0].data, &want, 3e-4, "gemm_acc");
}

#[test]
fn qr_factor_reconstructs_and_is_orthonormal() {
    let Some(rt) = rt() else { return };
    let mut rng = Rng::new(4);
    let a = tensor(&mut rng, &[1024, 128]);
    let out = rt.execute("qr_factor_f32_1024x128", &[a.clone()]).unwrap();
    let (q, r) = (&out[0], &out[1]);
    assert_eq!(q.shape, vec![1024, 128]);
    assert_eq!(r.shape, vec![128, 128]);
    // Q·R = A
    let qr = matmul_ref(q, r);
    assert_close(&qr, &a.data, 5e-3, "Q·R");
    // QᵀQ = I (sample the diagonal + a few off-diagonals)
    for j in [0usize, 17, 64, 127] {
        let mut dot = 0f32;
        for i in 0..1024 {
            dot += q.data[i * 128 + j] * q.data[i * 128 + j];
        }
        assert!((dot - 1.0).abs() < 2e-3, "‖q_{j}‖² = {dot}");
    }
    // R upper-triangular
    for i in 1..128 {
        for j in 0..i {
            assert_eq!(r.data[i * 128 + j], 0.0, "R[{i},{j}]");
        }
    }
}

#[test]
fn qr_merge_stacks() {
    let Some(rt) = rt() else { return };
    let mut rng = Rng::new(5);
    // Use upper-triangular inputs like real R factors.
    let mut r1 = tensor(&mut rng, &[128, 128]);
    let mut r2 = tensor(&mut rng, &[128, 128]);
    for r in [&mut r1, &mut r2] {
        for i in 0..128 {
            for j in 0..i {
                r.data[i * 128 + j] = 0.0;
            }
        }
    }
    let out = rt
        .execute("qr_merge_f32_128", &[r1.clone(), r2.clone()])
        .unwrap();
    let (q, r) = (&out[0], &out[1]);
    assert_eq!(q.shape, vec![256, 128]);
    // Q·R reconstructs the stack
    let qr = matmul_ref(q, r);
    let mut stacked = r1.data.clone();
    stacked.extend_from_slice(&r2.data);
    assert_close(&qr, &stacked, 5e-3, "merge Q·R");
}

#[test]
fn gram_is_ata() {
    let Some(rt) = rt() else { return };
    let mut rng = Rng::new(6);
    let a = tensor(&mut rng, &[1024, 128]);
    let out = rt.execute("gram_f32_1024x128", &[a.clone()]).unwrap();
    // check a few entries of AᵀA
    for (i, j) in [(0usize, 0usize), (3, 70), (127, 127)] {
        let mut want = 0f32;
        for row in 0..1024 {
            want += a.data[row * 128 + i] * a.data[row * 128 + j];
        }
        let got = out[0].data[i * 128 + j];
        assert!(
            (got - want).abs() < 1e-2 * (1.0 + want.abs()),
            "G[{i},{j}]: {got} vs {want}"
        );
    }
}

#[test]
fn svd1_finish_singular_values_match_gram_trace() {
    let Some(rt) = rt() else { return };
    let mut rng = Rng::new(7);
    let a = tensor(&mut rng, &[1024, 128]);
    let g = rt.execute("gram_f32_1024x128", &[a]).unwrap();
    let out = rt.execute("svd1_finish_f32_128", &[g[0].clone()]).unwrap();
    let sv = &out[0];
    assert_eq!(sv.shape, vec![128]);
    // Σσ² = trace(AᵀA)
    let trace: f32 = (0..128).map(|i| g[0].data[i * 128 + i]).sum();
    let sumsq: f32 = sv.data.iter().map(|s| s * s).sum();
    assert!(
        (sumsq - trace).abs() < 0.01 * trace,
        "Σσ²={sumsq} vs trace={trace}"
    );
    // sorted descending
    for w in sv.data.windows(2) {
        assert!(w[0] >= w[1] - 1e-3);
    }
}

#[test]
fn svc_update_is_axpy() {
    let Some(rt) = rt() else { return };
    let mut rng = Rng::new(8);
    let w = tensor(&mut rng, &[64]);
    let g = tensor(&mut rng, &[64]);
    let lr = Tensor::new(vec![1], vec![0.1]);
    let out = rt
        .execute("svc_update_f32_64", &[w.clone(), g.clone(), lr])
        .unwrap();
    let want: Vec<f32> =
        w.data.iter().zip(&g.data).map(|(w, g)| w - 0.1 * g).collect();
    assert_close(&out[0].data, &want, 1e-5, "svc_update");
}

#[test]
fn shape_mismatch_is_rejected() {
    let Some(rt) = rt() else { return };
    let bad = Tensor::new(vec![16], vec![0.0; 16]);
    assert!(rt.execute("tr_add_f32_8192", &[bad.clone(), bad]).is_err());
}

#[test]
fn unknown_op_is_rejected() {
    let Some(rt) = rt() else { return };
    assert!(rt.execute("nope", &[]).is_err());
}

#[test]
fn warmup_compiles_everything() {
    let Some(rt) = rt() else { return };
    rt.warmup().unwrap();
}
