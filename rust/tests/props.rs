//! Property-based tests on the coordinator invariants, driven by the
//! in-repo `util::prop` harness over randomly generated DAGs.
//!
//! Invariants (for every engine, under every knob combination):
//!  * every task executes exactly once (fan-ins claimed by one executor);
//!  * the job completes (no deadlock from clustering/delayed I/O);
//!  * static schedules are exactly the reachable closures and their union
//!    covers the DAG;
//!  * the same seed yields the identical trace (determinism);
//!  * KVS byte meters never exceed what a fully-stateless engine moves.

use wukong::baselines::{run_dask, run_numpywren};
use wukong::config::{Config, DaskConfig};
use wukong::coordinator::{generate_schedules, run_wukong};
use wukong::dag::{Dag, DagBuilder, OpKind};
use wukong::platform::faults::FaultPlan;
use wukong::util::prop::{check, gen};
use wukong::util::Rng;

/// Random layered DAG: `layers` ranks, forward-only random edges,
/// sizes straddling the inline (256 KB) and clustering thresholds.
fn random_dag_valid(rng: &mut Rng) -> Dag {
    // A duplicate random edge makes build() fail; retry a few times.
    for _ in 0..20 {
        let layers = gen::usize_in(rng, 1, 5);
        let mut b = DagBuilder::new("prop");
        let mut prev: Vec<u32> = Vec::new();
        let mut all: Vec<u32> = Vec::new();
        let mut edges: std::collections::HashSet<(u32, u32)> =
            std::collections::HashSet::new();
        let mut ok = true;
        for layer in 0..layers {
            let width = gen::usize_in(rng, 1, 6);
            let mut cur = Vec::new();
            for i in 0..width {
                let bytes = *gen::choose(
                    rng,
                    &[64u64, 8 * 1024, 300 * 1024, 2 << 20, 300 << 20],
                );
                let t = b.task(
                    format!("t{layer}_{i}"),
                    OpKind::Generic,
                    rng.below(1_000_000) as f64 + 1.0,
                    bytes,
                );
                if layer == 0 {
                    b.with_input(t, 1024);
                }
                cur.push(t);
            }
            if layer > 0 {
                for &t in &cur {
                    let p = *gen::choose(rng, &prev);
                    edges.insert((p, t));
                    b.edge(p, t);
                    for _ in 0..gen::usize_in(rng, 0, 2) {
                        let extra = *gen::choose(rng, &all);
                        if edges.insert((extra, t)) {
                            b.edge(extra, t);
                        }
                    }
                }
            }
            all.extend(&cur);
            prev = cur;
        }
        if ok {
            match b.build() {
                Ok(d) => return d,
                Err(_) => ok = false,
            }
        }
        let _ = ok;
    }
    panic!("could not build a random DAG");
}

fn random_config(rng: &mut Rng) -> Config {
    let mut cfg = Config::default();
    cfg.wukong.use_clustering = rng.f64() < 0.7;
    cfg.wukong.use_delayed_io = rng.f64() < 0.7;
    cfg.wukong.clustering_threshold =
        *gen::choose(rng, &[1u64 << 20, 200 << 20, 100]);
    cfg.wukong.fanout_delegation_threshold = gen::usize_in(rng, 1, 10);
    cfg.storage.n_shards = gen::usize_in(rng, 1, 75);
    cfg
}

#[test]
fn wukong_executes_every_task_exactly_once() {
    check(0xA11CE, 60, |rng| {
        let dag = random_dag_valid(rng);
        let cfg = random_config(rng);
        let r = run_wukong(&dag, &cfg, rng.next_u64());
        // exactly-once is asserted inside the engine; completeness here:
        assert_eq!(r.metrics.tasks_executed as usize, dag.len());
    });
}

#[test]
fn baselines_execute_every_task() {
    check(0xBEEF, 25, |rng| {
        let dag = random_dag_valid(rng);
        let mut cfg = random_config(rng);
        cfg.numpywren.n_workers = gen::usize_in(rng, 1, 16);
        let np = run_numpywren(&dag, &cfg, rng.next_u64());
        assert_eq!(np.tasks_executed as usize, dag.len());
        let dk = run_dask(&dag, &cfg, &DaskConfig::workers_125(), 0);
        assert_eq!(dk.tasks_executed as usize, dag.len());
    });
}

#[test]
fn wukong_is_deterministic_per_seed() {
    check(0xDE7, 20, |rng| {
        let dag = random_dag_valid(rng);
        let cfg = random_config(rng);
        let seed = rng.next_u64();
        let a = run_wukong(&dag, &cfg, seed);
        let b = run_wukong(&dag, &cfg, seed);
        assert_eq!(a.metrics.makespan_s, b.metrics.makespan_s);
        assert_eq!(a.metrics.kvs, b.metrics.kvs);
        assert_eq!(a.sim_events, b.sim_events);
        assert_eq!(a.metrics.executors_used, b.metrics.executors_used);
    });
}

#[test]
fn wukong_never_moves_more_bytes_than_stateless() {
    check(0x10CA1, 30, |rng| {
        let dag = random_dag_valid(rng);
        let cfg = random_config(rng);
        let wk = run_wukong(&dag, &cfg, 1).metrics;
        let np = run_numpywren(&dag, &cfg, 1);
        assert!(
            wk.kvs.bytes_written <= np.kvs.bytes_written,
            "wukong wrote {} > stateless {}",
            wk.kvs.bytes_written,
            np.kvs.bytes_written
        );
    });
}

#[test]
fn schedules_are_reachable_closures_and_cover() {
    check(0x5CED, 60, |rng| {
        let dag = random_dag_valid(rng);
        let scheds = generate_schedules(&dag);
        assert_eq!(scheds.len(), dag.leaves().len());
        let mut covered = vec![false; dag.len()];
        for s in &scheds {
            // DFS set == reachable set
            let reach = dag.reachable_from(s.leaf);
            assert_eq!(s.tasks, reach);
            for &t in &s.tasks {
                covered[t as usize] = true;
            }
        }
        assert!(covered.iter().all(|&c| c), "schedules must cover the DAG");
    });
}

#[test]
fn faults_never_lose_tasks() {
    use wukong::coordinator::sim_engine::run_wukong_faulty;
    check(0xFA17, 25, |rng| {
        let dag = random_dag_valid(rng);
        let cfg = random_config(rng);
        let p = rng.f64() * 0.4;
        let r = run_wukong_faulty(&dag, &cfg, 3, FaultPlan::with_failure_rate(p));
        // Either the retries absorbed every fault and the job completed,
        // or an executor exhausted its budget and the job is *reported*
        // failed — tasks silently lost without a failure report would be
        // a correctness bug.
        if r.metrics.failed_executors == 0 {
            assert_eq!(r.metrics.tasks_executed as usize, dag.len());
        } else {
            assert!(r.metrics.tasks_executed as usize <= dag.len());
        }
    });
}

#[test]
fn moderate_fault_rates_with_retries_complete() {
    use wukong::coordinator::sim_engine::run_wukong_faulty;
    check(0xFA18, 25, |rng| {
        let dag = random_dag_valid(rng);
        let cfg = random_config(rng);
        // p=5%: triple-failure odds are 1.25e-4 per executor; none of the
        // seeded cases hits one (determinism makes this stable).
        let r =
            run_wukong_faulty(&dag, &cfg, 3, FaultPlan::with_failure_rate(0.05));
        assert_eq!(r.metrics.failed_executors, 0);
        assert_eq!(r.metrics.tasks_executed as usize, dag.len());
    });
}

#[test]
fn makespan_at_least_critical_path() {
    check(0xC121, 30, |rng| {
        let dag = random_dag_valid(rng);
        let cfg = Config::default();
        let r = run_wukong(&dag, &cfg, 1);
        let cp = dag.critical_path(|t| {
            wukong::sim::secs(t.flops / (cfg.lambda.gflops * 1e9))
        });
        assert!(
            r.metrics.makespan_s >= wukong::sim::to_secs(cp) * 0.999,
            "makespan below compute critical path"
        );
    });
}
