//! Property-based tests on the coordinator invariants, driven by the
//! in-repo `util::prop` harness over randomly generated DAGs.
//!
//! Invariants (for every engine, under every knob combination):
//!  * every task executes exactly once (fan-ins claimed by one executor);
//!  * the job completes (no deadlock from clustering/delayed I/O);
//!  * static schedules are exactly the reachable closures and their union
//!    covers the DAG;
//!  * the same seed yields the identical trace (determinism);
//!  * KVS byte meters never exceed what a fully-stateless engine moves.

use wukong::baselines::{run_dask, run_numpywren};
use wukong::config::{Config, DaskConfig};
use wukong::coordinator::{generate_schedules, run_wukong};
use wukong::platform::faults::FaultPlan;
use wukong::util::prop::{check, gen};
use wukong::util::Rng;
use wukong::verify::corpus::{random_config, random_dag};

#[test]
fn wukong_executes_every_task_exactly_once() {
    check(0xA11CE, 60, |rng| {
        let dag = random_dag(rng);
        let cfg = random_config(rng);
        let r = run_wukong(&dag, &cfg, rng.next_u64());
        // exactly-once is asserted inside the engine; completeness here:
        assert_eq!(r.metrics.tasks_executed as usize, dag.len());
    });
}

#[test]
fn baselines_execute_every_task() {
    check(0xBEEF, 25, |rng| {
        let dag = random_dag(rng);
        let mut cfg = random_config(rng);
        cfg.numpywren.n_workers = gen::usize_in(rng, 1, 16);
        let np = run_numpywren(&dag, &cfg, rng.next_u64());
        assert_eq!(np.tasks_executed as usize, dag.len());
        let dk = run_dask(&dag, &cfg, &DaskConfig::workers_125(), 0);
        assert_eq!(dk.tasks_executed as usize, dag.len());
    });
}

#[test]
fn wukong_is_deterministic_per_seed() {
    check(0xDE7, 20, |rng| {
        let dag = random_dag(rng);
        let cfg = random_config(rng);
        let seed = rng.next_u64();
        let a = run_wukong(&dag, &cfg, seed);
        let b = run_wukong(&dag, &cfg, seed);
        assert_eq!(a.metrics.makespan_s, b.metrics.makespan_s);
        assert_eq!(a.metrics.kvs, b.metrics.kvs);
        assert_eq!(a.sim_events, b.sim_events);
        assert_eq!(a.metrics.executors_used, b.metrics.executors_used);
    });
}

#[test]
fn wukong_never_moves_more_bytes_than_stateless() {
    check(0x10CA1, 30, |rng| {
        let dag = random_dag(rng);
        let cfg = random_config(rng);
        let wk = run_wukong(&dag, &cfg, 1).metrics;
        let np = run_numpywren(&dag, &cfg, 1);
        assert!(
            wk.kvs.bytes_written <= np.kvs.bytes_written,
            "wukong wrote {} > stateless {}",
            wk.kvs.bytes_written,
            np.kvs.bytes_written
        );
    });
}

#[test]
fn schedules_are_reachable_closures_and_cover() {
    check(0x5CED, 60, |rng| {
        let dag = random_dag(rng);
        let scheds = generate_schedules(&dag);
        assert_eq!(scheds.len(), dag.leaves().len());
        let mut covered = vec![false; dag.len()];
        for s in &scheds {
            // DFS set == reachable set
            let reach = dag.reachable_from(s.leaf);
            assert_eq!(s.tasks, reach);
            for &t in &s.tasks {
                covered[t as usize] = true;
            }
        }
        assert!(covered.iter().all(|&c| c), "schedules must cover the DAG");
    });
}

#[test]
fn faults_never_lose_tasks() {
    use wukong::coordinator::sim_engine::run_wukong_faulty;
    check(0xFA17, 25, |rng| {
        let dag = random_dag(rng);
        let cfg = random_config(rng);
        let p = rng.f64() * 0.4;
        let r = run_wukong_faulty(&dag, &cfg, 3, FaultPlan::with_failure_rate(p));
        // Either the retries absorbed every fault and the job completed,
        // or an executor exhausted its budget and the job is *reported*
        // failed — tasks silently lost without a failure report would be
        // a correctness bug. A failed executor's start task stays claimed
        // and unexecuted, so a reported failure implies strict shortfall.
        if r.metrics.failed_executors == 0 {
            assert_eq!(r.metrics.tasks_executed as usize, dag.len());
        } else {
            assert!(
                (r.metrics.tasks_executed as usize) < dag.len(),
                "failure reported but all tasks executed"
            );
        }
        // Totality: every task is completed ⊕ reported-failed; the
        // shortfall above is exactly the failed set, never silent loss.
        assert_eq!(
            r.metrics.tasks_executed + r.metrics.failed_tasks,
            dag.len() as u64
        );
    });
}

#[test]
fn moderate_fault_rates_with_retries_mostly_complete() {
    use wukong::coordinator::sim_engine::run_wukong_faulty;
    // p=5% with two retries: triple-failure odds are 1.25e-4 per
    // executor, so nearly every case completes; a rare exhausted budget
    // must be *reported*, never silent. Aggregate over the cases instead
    // of asserting each one so the test is robust to corpus changes
    // (runs stay deterministic per seed either way).
    let mut rng = Rng::new(0xFA18);
    let mut complete = 0;
    let total = 25;
    for _ in 0..total {
        let dag = random_dag(&mut rng);
        let cfg = random_config(&mut rng);
        let r =
            run_wukong_faulty(&dag, &cfg, 3, FaultPlan::with_failure_rate(0.05));
        if r.metrics.failed_executors == 0 {
            assert_eq!(r.metrics.tasks_executed as usize, dag.len());
            complete += 1;
        } else {
            // Completed-XOR-reported-failed: the dead executor's claimed
            // start task can never have executed.
            assert!(
                (r.metrics.tasks_executed as usize) < dag.len(),
                "failure reported but all tasks executed"
            );
        }
        assert_eq!(
            r.metrics.tasks_executed + r.metrics.failed_tasks,
            dag.len() as u64
        );
    }
    assert!(complete >= total - 2, "only {complete}/{total} completed");
}

#[test]
fn fault_attempts_and_outcomes_partition_every_engine() {
    use wukong::engine::select_engines;
    use wukong::metrics::TaskOutcome;
    // §3.6 contract, property-swept over every sim engine with a random
    // fault plan: attempts are bounded by the retry budget, completed
    // tasks executed effectively-once with ≥1 attempt, failed tasks
    // never executed, and completed ⊕ failed partitions the DAG.
    check(0xFA19, 12, |rng| {
        let dag = random_dag(rng);
        let mut cfg = random_config(rng);
        cfg.faults = FaultPlan::with_retries(
            rng.f64() * 0.5,
            gen::usize_in(rng, 0, 3) as u32,
        );
        let seed = rng.next_u64();
        for engine in select_engines(&[]).unwrap() {
            if !engine.caps().supports_faults {
                continue;
            }
            let m = engine.run(&dag, &cfg, seed).metrics;
            let name = engine.name();
            assert_eq!(m.per_task_attempts.len(), dag.len(), "[{name}]");
            assert_eq!(m.per_task_outcome.len(), dag.len(), "[{name}]");
            assert_eq!(
                m.tasks_executed + m.failed_tasks,
                dag.len() as u64,
                "[{name}] completed + failed must cover the DAG"
            );
            for t in 0..dag.len() {
                let attempts = m.per_task_attempts[t];
                assert!(
                    attempts <= cfg.faults.max_attempts(),
                    "[{name}] task {t}: {attempts} attempts > budget {}",
                    cfg.faults.max_attempts()
                );
                match m.per_task_outcome[t] {
                    TaskOutcome::Completed => {
                        assert!(attempts >= 1, "[{name}] task {t}");
                        assert_eq!(
                            m.per_task_exec[t], 1,
                            "[{name}] task {t}: effectively-once violated"
                        );
                    }
                    TaskOutcome::Failed => {
                        assert_eq!(
                            m.per_task_exec[t], 0,
                            "[{name}] task {t}: failed yet executed"
                        );
                    }
                }
            }
        }
    });
}

#[test]
fn certain_failure_with_exhausted_budget_reports_every_task() {
    use wukong::engine::select_engines;
    // p_fail=1.0: no attempt ever succeeds, so once the retry budget is
    // exhausted every scheduled task fails directly and the structural
    // cascade must cover the entire DAG — nothing executes, nothing is
    // silently dropped.
    check(0xFA20, 8, |rng| {
        let dag = random_dag(rng);
        let mut cfg = random_config(rng);
        cfg.faults =
            FaultPlan::with_retries(1.0, gen::usize_in(rng, 0, 2) as u32);
        let seed = rng.next_u64();
        for engine in select_engines(&[]).unwrap() {
            if !engine.caps().supports_faults {
                continue;
            }
            let m = engine.run(&dag, &cfg, seed).metrics;
            let name = engine.name();
            assert_eq!(m.tasks_executed, 0, "[{name}]");
            assert_eq!(m.failed_tasks, dag.len() as u64, "[{name}]");
            assert!(m.failed_executors > 0, "[{name}] no failure report");
        }
    });
}

#[test]
fn zero_rate_fault_plans_are_invisible() {
    use wukong::engine::select_engines;
    // Regression for the RNG-coupling bug: a p_fail=0 plan draws nothing
    // from the fault stream, so enabling the knob (any retry budget)
    // must leave every engine's report bit-identical to fault-free.
    check(0xFA21, 10, |rng| {
        let dag = random_dag(rng);
        let base = random_config(rng);
        let mut faulty = base.clone();
        faulty.faults =
            FaultPlan::with_retries(0.0, gen::usize_in(rng, 0, 5) as u32);
        let seed = rng.next_u64();
        for engine in select_engines(&[]).unwrap() {
            if !engine.caps().supports_faults {
                continue;
            }
            let a = engine.run(&dag, &base, seed);
            let b = engine.run(&dag, &faulty, seed);
            let name = engine.name();
            assert_eq!(a.sim_events, b.sim_events, "[{name}]");
            assert_eq!(a.peak_pending, b.peak_pending, "[{name}]");
            assert_eq!(a.metrics, b.metrics, "[{name}]");
        }
    });
}

#[test]
fn shard_crashes_never_perturb_the_data_plane() {
    use wukong::engine::select_engines;
    use wukong::platform::faults::ShardCrashPlan;
    // The durable-KVS recovery property: under any crash plan and any
    // durability knobs, a crashed-and-recovered run differs from the
    // uninterrupted run *only* in the recovery meters — the synchronous
    // WAL means no acknowledged op is lost, so outcomes, byte meters and
    // event streams are byte-identical.
    check(0xC4A5, 10, |rng| {
        let dag = random_dag(rng);
        let mut base = random_config(rng);
        base.storage.wal_fsync_s = rng.f64() * 1e-3;
        base.storage.snapshot_every_ops = gen::usize_in(rng, 0, 64) as u64;
        let mut crashed = base.clone();
        crashed.crashes = ShardCrashPlan::with_crashes(
            rng.f64(),
            gen::usize_in(rng, 1, 6) as u32,
        );
        let seed = rng.next_u64();
        for engine in select_engines(&[]).unwrap() {
            if !engine.caps().supports_faults {
                continue;
            }
            let a = engine.run(&dag, &base, seed);
            let b = engine.run(&dag, &crashed, seed);
            let name = engine.name();
            assert_eq!(a.sim_events, b.sim_events, "[{name}]");
            assert_eq!(a.peak_pending, b.peak_pending, "[{name}]");
            assert!(
                b.metrics.durability.recoveries
                    <= crashed.crashes.max_crashes as u64,
                "[{name}] recoveries over budget"
            );
            let scrub = |mut m: wukong::metrics::RunMetrics| {
                m.durability.recoveries = 0;
                m.durability.replayed_ops = 0;
                m.durability.stall_s = 0.0;
                m
            };
            assert_eq!(
                scrub(a.metrics),
                scrub(b.metrics),
                "[{name}] data plane perturbed by crashes"
            );
        }
    });
}

#[test]
fn zero_rate_crash_plans_are_invisible() {
    use wukong::engine::select_engines;
    use wukong::platform::faults::ShardCrashPlan;
    // The salted-crash-stream regression guard: a p_crash=0 plan draws
    // nothing, so enabling the knob (any crash budget) leaves every
    // engine's report fully bit-identical — recovery meters included.
    check(0xC4A6, 10, |rng| {
        let dag = random_dag(rng);
        let base = random_config(rng);
        let mut planned = base.clone();
        planned.crashes =
            ShardCrashPlan::with_crashes(0.0, gen::usize_in(rng, 0, 8) as u32);
        let seed = rng.next_u64();
        for engine in select_engines(&[]).unwrap() {
            if !engine.caps().supports_faults {
                continue;
            }
            let a = engine.run(&dag, &base, seed);
            let b = engine.run(&dag, &planned, seed);
            let name = engine.name();
            assert_eq!(a.sim_events, b.sim_events, "[{name}]");
            assert_eq!(a.peak_pending, b.peak_pending, "[{name}]");
            assert_eq!(a.metrics, b.metrics, "[{name}]");
        }
    });
}

#[test]
fn zero_rate_spawn_plans_are_invisible_across_axes() {
    use wukong::dag::SpawnPlan;
    use wukong::engine::select_engines;
    use wukong::platform::faults::ShardCrashPlan;
    // The dynamic-DAG regression guard, crossed with the fault and
    // crash axes: a p_spawn=0 plan draws nothing from the salted spawn
    // stream, so enabling the knob (any fanout) leaves every
    // spawn-capable engine's report bit-identical — even while retries
    // and shard recoveries are reshaping the calendar.
    check(0x5B01, 8, |rng| {
        let dag = random_dag(rng);
        let mut base = random_config(rng);
        base.faults = FaultPlan::with_retries(
            rng.f64() * 0.4,
            gen::usize_in(rng, 0, 3) as u32,
        );
        base.crashes = ShardCrashPlan::with_crashes(
            rng.f64() * 0.5,
            gen::usize_in(rng, 0, 4) as u32,
        );
        let mut planned = base.clone();
        planned.spawn =
            SpawnPlan::with_rate(0.0, gen::usize_in(rng, 1, 8) as u32);
        let seed = rng.next_u64();
        for engine in select_engines(&[]).unwrap() {
            if !engine.caps().supports_spawning || !engine.caps().supports_faults
            {
                continue;
            }
            let a = engine.run(&dag, &base, seed);
            let b = engine.run(&dag, &planned, seed);
            let name = engine.name();
            assert_eq!(a.sim_events, b.sim_events, "[{name}]");
            assert_eq!(a.peak_pending, b.peak_pending, "[{name}]");
            assert_eq!(a.metrics, b.metrics, "[{name}]");
        }
    });
}

#[test]
fn dynamic_outcomes_partition_the_expanded_task_set() {
    use wukong::dag::{pre_expand, SpawnPlan};
    use wukong::engine::select_engines;
    // Totality under runtime spawning: the per-task meters are sized to
    // the *expanded* task set (the staged ids are first-class tasks),
    // and completed ⊕ failed partitions it exactly — a fault cascade
    // that kills a spawning parent must report its staged block too,
    // never silently drop it.
    check(0x5B02, 8, |rng| {
        let dag = random_dag(rng);
        let mut cfg = random_config(rng);
        let plan = SpawnPlan::recursive(
            rng.f64() * 0.8 + 0.1,
            gen::usize_in(rng, 1, 4) as u32,
            gen::usize_in(rng, 1, 2) as u32,
        );
        cfg.spawn = plan;
        cfg.faults = FaultPlan::with_retries(
            rng.f64() * 0.4,
            gen::usize_in(rng, 0, 2) as u32,
        );
        let seed = rng.next_u64();
        let expanded = pre_expand(&dag, plan, seed);
        for engine in select_engines(&[]).unwrap() {
            if !engine.caps().supports_spawning || !engine.caps().supports_faults
            {
                continue;
            }
            let m = engine.run(&dag, &cfg, seed).metrics;
            let name = engine.name();
            assert_eq!(m.per_task_attempts.len(), expanded.len(), "[{name}]");
            assert_eq!(m.per_task_outcome.len(), expanded.len(), "[{name}]");
            assert_eq!(m.per_task_exec.len(), expanded.len(), "[{name}]");
            assert_eq!(
                m.tasks_executed + m.failed_tasks,
                expanded.len() as u64,
                "[{name}] completed + failed must cover the expanded set"
            );
            for t in 0..expanded.len() {
                match m.per_task_outcome[t] {
                    wukong::metrics::TaskOutcome::Completed => assert_eq!(
                        m.per_task_exec[t], 1,
                        "[{name}] task {t}: effectively-once violated"
                    ),
                    wukong::metrics::TaskOutcome::Failed => assert_eq!(
                        m.per_task_exec[t], 0,
                        "[{name}] task {t}: failed yet executed"
                    ),
                }
            }
        }
    });
}

#[test]
fn serving_conserves_jobs_over_random_arrival_plans() {
    use wukong::serving::{run_serving, ArrivalPlan, FairnessPolicy};
    // Multi-tenant job conservation: under random Poisson/trace streams,
    // tenant counts and both fairness policies, every arrived job is
    // admitted and every admitted job finishes completed ⊕ failed — the
    // per-tenant rollups partition the totals exactly.
    check(0x5E21, 8, |rng| {
        let mut cfg = random_config(rng);
        let jobs = gen::usize_in(rng, 1, 10) as u64;
        cfg.arrival = if rng.f64() < 0.5 {
            ArrivalPlan::poisson(rng.f64() * 30.0 + 0.1, jobs)
        } else {
            ArrivalPlan::trace(rng.f64() * 2.0, jobs)
        };
        cfg.tenants.count = gen::usize_in(rng, 1, 5);
        if rng.f64() < 0.5 {
            cfg.tenants.policy = FairnessPolicy::WeightedFair;
            cfg.tenants.weight_skew = rng.f64();
        }
        let rep = run_serving(&cfg, rng.next_u64(), 1);
        assert_eq!(rep.arrived, jobs);
        assert!(
            rep.conserves_jobs(),
            "{} arrived, {} admitted, {} completed + {} failed",
            rep.arrived,
            rep.admitted,
            rep.completed,
            rep.failed
        );
    });
}

#[test]
fn serving_reports_are_thread_count_invariant() {
    use wukong::serving::{run_serving, ArrivalPlan, FairnessPolicy};
    // The per-job precompute fans out across the pool; the session
    // replay must be byte-identical regardless of worker count.
    check(0x5E22, 5, |rng| {
        let mut cfg = random_config(rng);
        cfg.arrival =
            ArrivalPlan::poisson(rng.f64() * 20.0 + 0.5, gen::usize_in(rng, 2, 8) as u64);
        cfg.tenants.count = gen::usize_in(rng, 1, 4);
        cfg.tenants.policy = FairnessPolicy::WeightedFair;
        cfg.tenants.weight_skew = rng.f64();
        let seed = rng.next_u64();
        let a = run_serving(&cfg, seed, 1);
        let b = run_serving(&cfg, seed, 4);
        assert_eq!(a, b, "serving report diverged across thread counts");
        assert_eq!(a.render(), b.render());
    });
}

#[test]
fn zero_rate_arrival_plans_are_invisible() {
    use wukong::engine::select_engines;
    use wukong::serving::{run_serving, ArrivalPlan};
    // The serving keys must be inert outside the serving layer: engines
    // never consult `cfg.arrival`/`cfg.tenants`, so setting them leaves
    // every single-DAG run bit-identical — and a zero-rate stream is an
    // all-zero no-op report (it draws nothing from any RNG stream).
    check(0x5E23, 8, |rng| {
        let dag = random_dag(rng);
        let base = random_config(rng);
        let mut planned = base.clone();
        planned.arrival =
            ArrivalPlan::poisson(0.0, gen::usize_in(rng, 0, 500) as u64);
        planned.tenants.count = gen::usize_in(rng, 1, 8);
        let seed = rng.next_u64();
        for engine in select_engines(&[]).unwrap() {
            let a = engine.run(&dag, &base, seed);
            let b = engine.run(&dag, &planned, seed);
            let name = engine.name();
            assert_eq!(a.sim_events, b.sim_events, "[{name}]");
            assert_eq!(a.metrics, b.metrics, "[{name}]");
        }
        let rep = run_serving(&planned, seed, 1);
        assert_eq!((rep.arrived, rep.admitted), (0, 0));
        assert_eq!(rep.total_events, 0);
        assert_eq!(rep.kvs_bytes, 0);
        assert!(rep.conserves_jobs());
    });
}

#[test]
fn bucket_and_heap_calendars_are_byte_identical_per_engine() {
    use wukong::engine::select_engines;
    use wukong::sim::CalendarKind;
    // The tentpole determinism gate: swapping the priority structure
    // under the calendar changes *nothing* observable — `(t, seq)` is a
    // total order, so every engine's full report (event counts, byte
    // meters, makespan, peak calendar depth) is byte-identical whether
    // the bucket queue or the reference heap pops the events.
    check(0xB0C4, 10, |rng| {
        let dag = random_dag(rng);
        let bucket = random_config(rng);
        assert_eq!(bucket.sim.calendar, CalendarKind::Bucket, "default");
        let mut heap = bucket.clone();
        heap.sim.calendar = CalendarKind::Heap;
        let seed = rng.next_u64();
        for engine in select_engines(&[]).unwrap() {
            let a = engine.run(&dag, &bucket, seed);
            let b = engine.run(&dag, &heap, seed);
            let name = engine.name();
            assert_eq!(a.sim_events, b.sim_events, "[{name}]");
            assert_eq!(a.peak_pending, b.peak_pending, "[{name}]");
            assert_eq!(a.metrics, b.metrics, "[{name}]");
        }
    });
}

#[test]
fn calendar_swap_is_invisible_under_faults_and_crashes() {
    use wukong::engine::select_engines;
    use wukong::platform::faults::ShardCrashPlan;
    use wukong::sim::CalendarKind;
    // Same gate through the fault axis (retries re-enqueue events) and
    // the durable-KVS crash axis (recovery stalls reshape the calendar
    // mid-run): the heap and bucket runs must still agree bit-for-bit,
    // recovery meters included.
    check(0xB0C5, 8, |rng| {
        let dag = random_dag(rng);
        let mut bucket = random_config(rng);
        bucket.faults = FaultPlan::with_retries(
            rng.f64() * 0.5,
            gen::usize_in(rng, 0, 3) as u32,
        );
        bucket.crashes = ShardCrashPlan::with_crashes(
            rng.f64() * 0.5,
            gen::usize_in(rng, 0, 4) as u32,
        );
        bucket.storage.wal_fsync_s = rng.f64() * 1e-3;
        bucket.storage.snapshot_every_ops = gen::usize_in(rng, 0, 32) as u64;
        let mut heap = bucket.clone();
        heap.sim.calendar = CalendarKind::Heap;
        let seed = rng.next_u64();
        for engine in select_engines(&[]).unwrap() {
            if !engine.caps().supports_faults {
                continue;
            }
            let a = engine.run(&dag, &bucket, seed);
            let b = engine.run(&dag, &heap, seed);
            let name = engine.name();
            assert_eq!(a.sim_events, b.sim_events, "[{name}]");
            assert_eq!(a.peak_pending, b.peak_pending, "[{name}]");
            assert_eq!(a.metrics, b.metrics, "[{name}]");
        }
    });
}

#[test]
fn calendar_swap_is_invisible_under_spawning() {
    use wukong::dag::SpawnPlan;
    use wukong::engine::select_engines;
    use wukong::sim::CalendarKind;
    // Same determinism gate through the dynamic-DAG axis: runtime
    // spawning enqueues fresh events mid-run (the calendar grows with
    // the task set), and the heap and bucket structures must still
    // agree bit-for-bit on the expanded execution.
    check(0xB0C8, 8, |rng| {
        let dag = random_dag(rng);
        let mut bucket = random_config(rng);
        bucket.spawn = SpawnPlan::recursive(
            rng.f64() * 0.5 + 0.2,
            gen::usize_in(rng, 1, 4) as u32,
            gen::usize_in(rng, 1, 3) as u32,
        );
        let mut heap = bucket.clone();
        heap.sim.calendar = CalendarKind::Heap;
        let seed = rng.next_u64();
        for engine in select_engines(&[]).unwrap() {
            if !engine.caps().supports_spawning {
                continue;
            }
            let a = engine.run(&dag, &bucket, seed);
            let b = engine.run(&dag, &heap, seed);
            let name = engine.name();
            assert_eq!(a.sim_events, b.sim_events, "[{name}]");
            assert_eq!(a.peak_pending, b.peak_pending, "[{name}]");
            assert_eq!(a.metrics, b.metrics, "[{name}]");
        }
    });
}

#[test]
fn calendar_swap_is_invisible_to_the_serving_session() {
    use wukong::serving::{run_serving, ArrivalPlan, FairnessPolicy};
    use wukong::sim::CalendarKind;
    // The serving session runs its own `Sim<ServeEv>` plus one inner
    // engine sim per admitted job; both layers pick the structure up
    // from `cfg.sim`, and the whole report — per-tenant rollups,
    // latency percentiles, billing — must not move. Crossed with a
    // thread-count change to pin both invariances at once.
    check(0xB0C6, 6, |rng| {
        let mut bucket = random_config(rng);
        bucket.arrival =
            ArrivalPlan::poisson(rng.f64() * 20.0 + 0.5, gen::usize_in(rng, 2, 8) as u64);
        bucket.tenants.count = gen::usize_in(rng, 1, 4);
        if rng.f64() < 0.5 {
            bucket.tenants.policy = FairnessPolicy::WeightedFair;
            bucket.tenants.weight_skew = rng.f64();
        }
        let mut heap = bucket.clone();
        heap.sim.calendar = CalendarKind::Heap;
        let seed = rng.next_u64();
        let a = run_serving(&bucket, seed, 1);
        let b = run_serving(&heap, seed, 1);
        assert_eq!(a, b, "serving report moved with the calendar swap");
        assert_eq!(a.render(), b.render());
        let c = run_serving(&heap, seed, 4);
        assert_eq!(a, c, "calendar x thread-count cross");
    });
}

#[test]
fn pinned_bucket_width_never_changes_any_engine_report() {
    use wukong::engine::select_engines;
    // `sim.bucket_width_us` is a geometry knob, not a semantics knob:
    // any pinned width yields the same report as auto-sizing.
    check(0xB0C7, 6, |rng| {
        let dag = random_dag(rng);
        let auto = random_config(rng);
        let mut pinned = auto.clone();
        pinned.sim.bucket_width_us = 1 + rng.below(1_000_000);
        let seed = rng.next_u64();
        for engine in select_engines(&[]).unwrap() {
            let a = engine.run(&dag, &auto, seed);
            let b = engine.run(&dag, &pinned, seed);
            let name = engine.name();
            assert_eq!(a.sim_events, b.sim_events, "[{name}]");
            assert_eq!(a.peak_pending, b.peak_pending, "[{name}]");
            assert_eq!(a.metrics, b.metrics, "[{name}]");
        }
    });
}

#[test]
fn makespan_at_least_critical_path() {
    check(0xC121, 30, |rng| {
        let dag = random_dag(rng);
        let cfg = Config::default();
        let r = run_wukong(&dag, &cfg, 1);
        let cp = dag.critical_path(|t| {
            wukong::sim::secs(t.flops / (cfg.lambda.gflops * 1e9))
        });
        assert!(
            r.metrics.makespan_s >= wukong::sim::to_secs(cp) * 0.999,
            "makespan below compute critical path"
        );
    });
}
