//! Real-engine end-to-end tests: the decentralized Wukong executor pool
//! runs real PJRT compute over a real KVS, and the results are verified
//! numerically against ground truth.
//!
//! Requires the AOT artifacts (`make artifacts`) and a real PJRT backend;
//! when either is missing every test *skips* with a message instead of
//! failing, so plain `cargo test -q` stays green out of the box.

use std::sync::Arc;

use wukong::dag::Dag;
use wukong::engine::compute::{seed_inputs, Obj};
use wukong::engine::{run_real_numpywren, run_real_wukong, RealConfig, RealReport};
use wukong::runtime::{SharedRuntime, Tensor};
use wukong::storage::real_kvs::RealKvs;
use wukong::workloads::{gemm, tr, tsqr};

/// The shared runtime, or `None` (with a skip message) when artifacts /
/// PJRT are unavailable in this environment.
fn rt() -> Option<Arc<SharedRuntime>> {
    let rt = SharedRuntime::try_load_default();
    if rt.is_none() {
        eprintln!(
            "skipping real-engine test: AOT artifacts or the PJRT backend \
             are unavailable (run `make artifacts`)"
        );
    }
    rt
}

fn fast_cfg() -> RealConfig {
    RealConfig {
        invoke_latency: std::time::Duration::from_micros(200),
        delayed_io_wait: std::time::Duration::from_micros(500),
        ..RealConfig::default()
    }
}

fn run_wk(dag: &Dag, seed: u64) -> Option<(RealReport, Vec<(String, Obj)>)> {
    let rt = rt()?;
    rt.warmup().unwrap();
    let kvs = RealKvs::new(16, 0.0, 0.0);
    let seeded = seed_inputs(dag, &kvs, seed);
    let report = run_real_wukong(dag, rt, kvs, fast_cfg()).expect("run ok");
    Some((report, seeded))
}

#[test]
fn real_tr_sums_correctly() {
    let dag = tr::dag(tr::TrParams {
        n: 16,
        chunk: 8192,
        delay: None,
    });
    let Some((report, seeded)) = run_wk(&dag, 11) else { return };
    assert_eq!(report.tasks_executed as usize, dag.len());
    // ground truth: sum of every seeded chunk
    let want: f64 = seeded
        .iter()
        .flat_map(|(_, obj)| obj.iter())
        .flat_map(|t| t.data.iter())
        .map(|&x| x as f64)
        .sum();
    let out = report.outputs.get("tr_root").expect("root output");
    let got = out[0].data[0] as f64;
    assert!(
        (got - want).abs() < 1e-2 * want.abs().max(1.0),
        "TR sum {got} vs {want}"
    );
}

#[test]
fn real_gemm_matches_block_reference() {
    // 512x512 with 256-blocks: C = A·B verified blockwise.
    let dag = gemm::dag(gemm::GemmParams { n: 512, block: 256 });
    let Some((report, seeded)) = run_wk(&dag, 13) else { return };
    assert_eq!(report.tasks_executed as usize, dag.len());

    let find = |key: &str| -> &Tensor {
        &seeded
            .iter()
            .find(|(k, _)| k == key)
            .unwrap_or_else(|| panic!("{key}"))
            .1[0]
    };
    // C[0,0] = A00·B00 + A01·B10 where task in:mul_0_0_k = (A[0,k], B[k,0])
    let a00 = find("in:mul_0_0_0");
    let b00 = &seeded.iter().find(|(k, _)| k == "in:mul_0_0_0").unwrap().1[1];
    let a01 = find("in:mul_0_0_1");
    let b10 = &seeded.iter().find(|(k, _)| k == "in:mul_0_0_1").unwrap().1[1];
    let mut want = vec![0f32; 256 * 256];
    for (a, b) in [(a00, b00), (a01, b10)] {
        for i in 0..256 {
            for k in 0..256 {
                let av = a.data[i * 256 + k];
                for j in 0..256 {
                    want[i * 256 + j] += av * b.data[k * 256 + j];
                }
            }
        }
    }
    // the C00 sink is the root of the acc_0_0 reduction tree
    let out = report
        .outputs
        .iter()
        .find(|(name, _)| name.starts_with("acc_0_0"))
        .map(|(_, o)| o)
        .expect("C00 output");
    let got = &out[0].data;
    for i in (0..got.len()).step_by(4097) {
        assert!(
            (got[i] - want[i]).abs() < 5e-3 * (1.0 + want[i].abs()),
            "C00[{i}]: {} vs {}",
            got[i],
            want[i]
        );
    }
}

#[test]
fn real_tsqr_factorization_is_valid() {
    // Full explicit-Q TSQR over 4 blocks: Q·R = A and QᵀQ = I, through
    // the real decentralized execution (becomes/invokes/counters).
    let p = tsqr::TsqrParams {
        rows: 4096,
        cols: 128,
        block_rows: 1024,
        with_q: true,
    };
    let dag = tsqr::dag(p);
    let Some((report, seeded)) = run_wk(&dag, 17) else { return };
    assert_eq!(report.tasks_executed as usize, dag.len());

    // Assemble A from seeds and Q from the applyq outputs; R from sink.
    let mut a_rows: Vec<Vec<f32>> = Vec::new();
    for i in 0..4 {
        let blk = &seeded
            .iter()
            .find(|(k, _)| k == &format!("in:qr_{i}"))
            .unwrap()
            .1[0];
        a_rows.push(blk.data.clone());
    }
    let r = report
        .outputs
        .iter()
        .find(|(name, _)| name.starts_with("merge_l1") || name.starts_with("r_l1"))
        .map(|(_, o)| o.last().unwrap())
        .expect("root R");
    let mut q_blocks: Vec<Vec<f32>> = Vec::new();
    for i in 0..4 {
        let q = &report.outputs[&format!("applyq_{i}")][0];
        assert_eq!(q.shape, vec![1024, 128]);
        q_blocks.push(q.data.clone());
    }
    // Q·R = A per block (sampled entries)
    for blk in 0..4 {
        let (q, a) = (&q_blocks[blk], &a_rows[blk]);
        for &(i, j) in &[(0usize, 0usize), (511, 64), (1023, 127)] {
            let mut qr = 0f32;
            for k in 0..128 {
                qr += q[i * 128 + k] * r.data[k * 128 + j];
            }
            assert!(
                (qr - a[i * 128 + j]).abs() < 2e-2,
                "blk{blk} QR[{i},{j}]={qr} vs A={}",
                a[i * 128 + j]
            );
        }
    }
    // global QᵀQ = I (sampled columns over all blocks)
    for j in [0usize, 63, 127] {
        let mut dot = 0f64;
        for q in &q_blocks {
            for i in 0..1024 {
                dot += (q[i * 128 + j] as f64).powi(2);
            }
        }
        assert!((dot - 1.0).abs() < 5e-3, "‖q_{j}‖² = {dot}");
    }
}

#[test]
fn real_wukong_beats_stateless_numpywren_on_io() {
    let p = tsqr::TsqrParams {
        rows: 8192,
        cols: 128,
        block_rows: 1024,
        with_q: false,
    };
    let dag = tsqr::dag(p);
    let Some(rt) = rt() else { return };
    rt.warmup().unwrap();

    let kvs = RealKvs::new(16, 0.0, 0.0);
    seed_inputs(&dag, &kvs, 23);
    let seeded = kvs.bytes_written.load(std::sync::atomic::Ordering::SeqCst);
    let wk = run_real_wukong(&dag, Arc::clone(&rt), kvs, fast_cfg()).unwrap();

    let kvs = RealKvs::new(16, 0.0, 0.0);
    seed_inputs(&dag, &kvs, 23);
    let np = run_real_numpywren(&dag, rt, kvs, fast_cfg()).unwrap();

    assert_eq!(wk.tasks_executed, np.tasks_executed);
    // Compare intermediate-object traffic (exclude the input upload that
    // both engines share).
    let wk_w = wk.kvs_bytes_written - seeded;
    let np_w = np.kvs_bytes_written - seeded;
    assert!(
        np_w > 8 * wk_w,
        "numpywren {np_w} vs wukong {wk_w} intermediate bytes written"
    );
    // identical results through both engines
    let wk_r = wk
        .outputs
        .values()
        .next()
        .and_then(|o| o.last())
        .expect("wukong R");
    let np_r = np
        .outputs
        .values()
        .next()
        .and_then(|o| o.last())
        .expect("numpywren R");
    for (a, b) in wk_r.data.iter().zip(&np_r.data) {
        assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()));
    }
}

#[test]
fn real_engine_is_exactly_once_under_concurrency() {
    // Stress the CAS-claim protocol with a wide fan-in DAG and a small
    // pool (forced contention), several times.
    for round in 0..3 {
        let dag = tr::dag(tr::TrParams {
            n: 32,
            chunk: 8192,
            delay: None,
        });
        let Some(rt) = rt() else { return };
        let kvs = RealKvs::new(4, 0.0, 0.0);
        seed_inputs(&dag, &kvs, round);
        let mut cfg = fast_cfg();
        cfg.n_threads = 3;
        cfg.invoke_latency = std::time::Duration::ZERO;
        let report = run_real_wukong(&dag, rt, kvs, cfg).unwrap();
        assert_eq!(report.tasks_executed as usize, dag.len());
    }
}
