//! Dynamic-DAG differential suite: runtime task spawning behind the
//! delta-graph layer, gated against static pre-expansion.
//!
//! The correctness anchor for the whole subsystem is a single sentence:
//! running a DAG with a live `SpawnPlan` must be **byte-identical**
//! (metrics, event counts, peak calendar depth) to running the
//! statically pre-expanded equivalent DAG with no plan at all. These
//! tests sweep that anchor across every spawn-capable engine, the
//! pinned `corpus::spawn_matrix()`, random corpus DAGs, and the new
//! irregular workload generators — then pin the `verify --dynamic`
//! wiring end-to-end.

use wukong::dag::{pre_expand, SpawnPlan, SpawnState};
use wukong::engine::select_engines;
use wukong::util::prop::{check, gen};
use wukong::verify::corpus::{self, random_config, random_dag};
use wukong::verify::{run_verify, VerifyOptions};
use wukong::workloads::dynamic::{
    branch_and_bound, fork_join, BranchBoundParams, ForkJoinParams,
};

/// The headline differential: for every live plan in the pinned spawn
/// matrix, every spawn-capable engine's dynamic run over a random
/// corpus DAG is byte-identical to the plan-free run over
/// `pre_expand(dag, plan, seed)`.
#[test]
fn spawn_matrix_is_byte_identical_to_pre_expansion_on_every_engine() {
    check(0xD7A6, 6, |rng| {
        let dag = random_dag(rng);
        let base = random_config(rng);
        let seed = rng.next_u64();
        for (name, plan) in corpus::spawn_matrix() {
            if !plan.is_live() {
                continue;
            }
            let mut cfg = base.clone();
            cfg.spawn = plan;
            let expanded = pre_expand(&dag, plan, seed);
            for engine in select_engines(&[]).unwrap() {
                if !engine.caps().supports_spawning {
                    continue;
                }
                let dy = engine.run(&dag, &cfg, seed);
                let st = engine.run(&expanded, &base, seed);
                let ename = engine.name();
                assert_eq!(dy.sim_events, st.sim_events, "[{ename}/{name}]");
                assert_eq!(dy.peak_pending, st.peak_pending, "[{ename}/{name}]");
                assert_eq!(dy.metrics, st.metrics, "[{ename}/{name}]");
                assert_eq!(
                    dy.metrics.tasks_executed as usize,
                    expanded.len(),
                    "[{ename}/{name}] dynamic run must complete the expanded set"
                );
            }
        }
    });
}

/// Zero-rate plans draw nothing from the salted spawn stream, so
/// enabling the knob leaves every engine's report bit-identical to a
/// plan-free run — the static-workload regression guard.
#[test]
fn zero_rate_spawn_plans_are_invisible_on_every_engine() {
    check(0xD7A7, 8, |rng| {
        let dag = random_dag(rng);
        let base = random_config(rng);
        let mut planned = base.clone();
        planned.spawn =
            SpawnPlan::with_rate(0.0, gen::usize_in(rng, 1, 16) as u32);
        let seed = rng.next_u64();
        for engine in select_engines(&[]).unwrap() {
            if !engine.caps().supports_spawning {
                continue;
            }
            let a = engine.run(&dag, &base, seed);
            let b = engine.run(&dag, &planned, seed);
            let name = engine.name();
            assert_eq!(a.sim_events, b.sim_events, "[{name}]");
            assert_eq!(a.peak_pending, b.peak_pending, "[{name}]");
            assert_eq!(a.metrics, b.metrics, "[{name}]");
        }
    });
}

/// Dynamic expansion is deterministic per `(dag, plan, seed)`: the
/// same seed replays the identical report, and `pre_expand` itself is
/// a pure function — two calls yield structurally identical DAGs.
#[test]
fn dynamic_expansion_is_a_pure_function_of_the_seed() {
    check(0xD7A8, 8, |rng| {
        let dag = random_dag(rng);
        let mut cfg = random_config(rng);
        let plan = SpawnPlan::recursive(
            rng.f64() * 0.6 + 0.1,
            gen::usize_in(rng, 1, 4) as u32,
            gen::usize_in(rng, 1, 3) as u32,
        );
        cfg.spawn = plan;
        let seed = rng.next_u64();
        let a = pre_expand(&dag, plan, seed);
        let b = pre_expand(&dag, plan, seed);
        assert_eq!(a.len(), b.len());
        assert_eq!(a.leaves(), b.leaves());
        assert_eq!(a.sinks(), b.sinks());
        for t in 0..a.len() as u32 {
            assert_eq!(a.parents(t), b.parents(t), "task {t}");
            assert_eq!(a.children(t), b.children(t), "task {t}");
            assert_eq!(a.task_name(t), b.task_name(t), "task {t}");
        }
        for engine in select_engines(&[]).unwrap() {
            if !engine.caps().supports_spawning {
                continue;
            }
            let x = engine.run(&dag, &cfg, seed);
            let y = engine.run(&dag, &cfg, seed);
            let name = engine.name();
            assert_eq!(x.sim_events, y.sim_events, "[{name}]");
            assert_eq!(x.metrics, y.metrics, "[{name}]");
        }
    });
}

/// Structural audit of the sealed view that engines and downstream
/// consumers cache: staged tasks have exactly their spawning parent,
/// parent ids precede child ids, the leaf set is the base leaf set
/// verbatim (spawned tasks always have a parent), and the staged block
/// layout agrees with `SpawnState`'s accounting.
#[test]
fn pre_expanded_dags_pass_the_structural_audit() {
    check(0xD7A9, 10, |rng| {
        let dag = random_dag(rng);
        let plan = SpawnPlan::recursive(
            rng.f64(),
            gen::usize_in(rng, 1, 5) as u32,
            gen::usize_in(rng, 1, 3) as u32,
        );
        let seed = rng.next_u64();
        let spawn = SpawnState::for_run(&dag, plan, seed);
        let expanded = pre_expand(&dag, plan, seed);
        assert_eq!(expanded.len(), spawn.total_len());
        assert_eq!(expanded.leaves(), dag.leaves());
        assert_eq!(expanded.sinks().len(), spawn.sinks_after(&dag));
        for t in 0..dag.len() as u32 {
            assert_eq!(expanded.parents(t), dag.parents(t), "base task {t}");
        }
        for t in dag.len() as u32..expanded.len() as u32 {
            assert!(spawn.is_staged(t));
            let p = spawn.parent_of(t);
            assert_eq!(expanded.parents(t), &[p], "staged task {t}");
            assert!(p < t, "staged task {t} must follow its parent {p}");
            assert_eq!(expanded.indegree(t), 1);
            assert!(expanded.task_name(t).starts_with("sp"), "staged name");
        }
    });
}

/// The irregular workload generators are first-class base graphs for
/// spawning: a recursive fork-join tree and a branch-and-bound search
/// both expand dynamically into exactly the pre-expanded equivalent.
#[test]
fn irregular_workloads_expand_identically() {
    let fj = fork_join(ForkJoinParams {
        fanout: 3,
        depth: 3,
        flops: 2.0e6,
        out_bytes: 32 * 1024,
    });
    let bb = branch_and_bound(BranchBoundParams {
        branches: 3,
        depth: 4,
        keep_levels: 2,
        p_prune: 0.4,
        flops: 1.0e6,
        out_bytes: 16 * 1024,
        seed: 0xB0B,
    });
    let base = wukong::config::Config::default();
    for dag in [&fj, &bb] {
        for (name, plan) in corpus::spawn_matrix() {
            let mut cfg = base.clone();
            cfg.spawn = plan;
            let seed = 0xFEED ^ dag.len() as u64;
            let expanded = pre_expand(dag, plan, seed);
            for engine in select_engines(&[]).unwrap() {
                if !engine.caps().supports_spawning {
                    continue;
                }
                let dy = engine.run(dag, &cfg, seed);
                let st = engine.run(&expanded, &base, seed);
                let ename = engine.name();
                assert_eq!(dy.sim_events, st.sim_events, "[{ename}/{name}]");
                assert_eq!(dy.metrics, st.metrics, "[{ename}/{name}]");
            }
        }
    }
}

/// End-to-end wiring: `--dynamic` adds exactly the spawn axis on top
/// of the base matrix — 5 spawn-capable engines × (1 reference + 4
/// live plans × (dynamic + rerun + pre-expanded) + 1 zero-rate run)
/// per case — and the sweep comes back clean.
#[test]
fn verify_dynamic_flag_gates_exactly_the_spawn_axis() {
    let plain = run_verify(&VerifyOptions {
        runs: 2,
        seed: 31,
        ..VerifyOptions::default()
    })
    .unwrap();
    let dynamic = run_verify(&VerifyOptions {
        runs: 2,
        seed: 31,
        dynamic: true,
        ..VerifyOptions::default()
    })
    .unwrap();
    assert!(plain.violations.is_empty());
    assert!(
        dynamic.violations.is_empty(),
        "dynamic-axis violations:\n{}",
        dynamic.violations.join("\n")
    );
    assert_eq!(plain.engine_runs, 2 * 24);
    assert_eq!(
        dynamic.engine_runs - plain.engine_runs,
        2 * 5 * (1 + 4 * 3 + 1),
        "--dynamic must add exactly the spawn axis"
    );
}
