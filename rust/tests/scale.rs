//! Scale smoke tests (tier-1, artifact-free): a ~100k-task DAG completes
//! exactly-once on Wukong and on a centralized baseline, and DES event
//! counts grow linearly — not quadratically — with task count. This is
//! the `cargo test`-runnable guard for the million-task regimes `wukong
//! bench` sweeps (which are release-build only).

use wukong::baselines::run_numpywren_full;
use wukong::config::Config;
use wukong::coordinator::run_wukong;
use wukong::workloads::micro;

fn scale_cfg() -> Config {
    let mut cfg = Config::default();
    // Lift the Lambda cap so the 100k fan-out measures the engine, not
    // admission-throttle modeling.
    cfg.lambda.concurrency_limit = 200_000;
    cfg
}

#[test]
fn wukong_100k_task_fanout_completes_exactly_once() {
    let dag = micro::serverless(100_000, 0);
    let r = run_wukong(&dag, &scale_cfg(), 1);
    assert_eq!(r.metrics.tasks_executed, 100_000);
    assert_eq!(r.metrics.per_task_exec.len(), 100_000);
    assert!(r.metrics.per_task_exec.iter().all(|&c| c == 1));
    assert_eq!(r.metrics.executors_used, 100_000);
    assert!(r.sim_events >= 100_000);
}

#[test]
fn numpywren_100k_task_fanout_completes_exactly_once() {
    let dag = micro::serverless(100_000, 0);
    let mut cfg = scale_cfg();
    cfg.numpywren.n_workers = 512;
    let r = run_numpywren_full(&dag, &cfg, 1);
    assert_eq!(r.metrics.tasks_executed, 100_000);
    assert!(r.metrics.per_task_exec.iter().all(|&c| c == 1));
    assert!(r.sim_events >= 100_000);
}

#[test]
fn wukong_sim_events_grow_linearly_with_task_count() {
    // 4x the tasks must cost ~4x the events (linear); a quadratic hot
    // path (e.g. per-dispatch child-list clones feeding re-scans) would
    // show ~16x. Allow 2x slack over linear for constant terms.
    let cfg = scale_cfg();
    let small = run_wukong(&micro::serverless(25_000, 0), &cfg, 1);
    let large = run_wukong(&micro::serverless(100_000, 0), &cfg, 1);
    assert_eq!(small.metrics.tasks_executed, 25_000);
    assert_eq!(large.metrics.tasks_executed, 100_000);
    let ratio = large.sim_events as f64 / small.sim_events as f64;
    assert!(
        ratio < 8.0,
        "events grew superlinearly: {} -> {} ({ratio:.2}x for 4x tasks)",
        small.sim_events,
        large.sim_events
    );
    assert!(ratio > 2.0, "suspiciously sublinear: {ratio:.2}x");
}

#[test]
fn wukong_long_chain_events_stay_linear() {
    // The pure "becomes" path: one executor, zero invocations — events
    // must be a small constant per task.
    let cfg = Config::default();
    let dag = micro::chains(micro::MicroParams {
        n_chains: 1,
        chain_len: 50_000,
        task_dur: 0,
    });
    let r = run_wukong(&dag, &cfg, 1);
    assert_eq!(r.metrics.tasks_executed, 50_000);
    assert_eq!(r.metrics.executors_used, 1);
    assert!(
        r.sim_events < 10 * 50_000,
        "chain events blew up: {}",
        r.sim_events
    );
}
