//! Scale smoke tests (tier-1, artifact-free): large fan-outs complete
//! exactly-once on Wukong and on a centralized baseline, and DES event
//! counts grow linearly — not quadratically — up to the million-task
//! regime `wukong bench` sweeps. Since PR 9 this is also the bucketed
//! calendar queue's stress tier: a 1M-task fan-out is one giant
//! same-window backlog (the overload-rebuild path), and the
//! all-same-timestamp burst pins the worst case of every event landing
//! in a single bucket. Since PR 10 it is also the dynamic-DAG stress
//! tier: a certain recursive spawn plan expands a 50k fan-out into a
//! million runtime-spawned tasks under the same linear-event guard.

use wukong::baselines::run_numpywren_full;
use wukong::config::Config;
use wukong::coordinator::run_wukong;
use wukong::sim::CalendarKind;
use wukong::workloads::micro;

fn scale_cfg() -> Config {
    let mut cfg = Config::default();
    // Lift the Lambda cap so the fan-outs (up to 1M tasks) measure the
    // engine, not admission-throttle modeling.
    cfg.lambda.concurrency_limit = 2_000_000;
    cfg
}

#[test]
fn wukong_100k_task_fanout_completes_exactly_once() {
    let dag = micro::serverless(100_000, 0);
    let r = run_wukong(&dag, &scale_cfg(), 1);
    assert_eq!(r.metrics.tasks_executed, 100_000);
    assert_eq!(r.metrics.per_task_exec.len(), 100_000);
    assert!(r.metrics.per_task_exec.iter().all(|&c| c == 1));
    assert_eq!(r.metrics.executors_used, 100_000);
    assert!(r.sim_events >= 100_000);
}

#[test]
fn numpywren_100k_task_fanout_completes_exactly_once() {
    let dag = micro::serverless(100_000, 0);
    let mut cfg = scale_cfg();
    cfg.numpywren.n_workers = 512;
    let r = run_numpywren_full(&dag, &cfg, 1);
    assert_eq!(r.metrics.tasks_executed, 100_000);
    assert!(r.metrics.per_task_exec.iter().all(|&c| c == 1));
    assert!(r.sim_events >= 100_000);
}

#[test]
fn wukong_sim_events_grow_linearly_to_a_million_tasks() {
    // 4x the tasks must cost ~4x the events (linear); a quadratic hot
    // path (e.g. per-dispatch child-list clones feeding re-scans) would
    // show ~16x. Allow 2x slack over linear for constant terms. The
    // large leg is the full bench-tier 1,000,000-task fan-out — the
    // bucket calendar's overload-growth path runs for real here, and
    // exactly-once is asserted inside the engine.
    let cfg = scale_cfg();
    let small = run_wukong(&micro::serverless(250_000, 0), &cfg, 1);
    let large = run_wukong(&micro::serverless(1_000_000, 0), &cfg, 1);
    assert_eq!(small.metrics.tasks_executed, 250_000);
    assert_eq!(large.metrics.tasks_executed, 1_000_000);
    assert_eq!(large.metrics.executors_used, 1_000_000);
    let ratio = large.sim_events as f64 / small.sim_events as f64;
    assert!(
        ratio < 8.0,
        "events grew superlinearly: {} -> {} ({ratio:.2}x for 4x tasks)",
        small.sim_events,
        large.sim_events
    );
    assert!(ratio > 2.0, "suspiciously sublinear: {ratio:.2}x");
}

#[test]
fn all_same_timestamp_burst_matches_the_heap_exactly() {
    // Pathological calendar shape: zero out every latency source so all
    // 50k invocations (and their successor events) collapse onto shared
    // timestamps — on the bucket queue everything piles into one bucket
    // per instant, the pure FIFO-tie regime. The run must complete
    // exactly-once and be byte-identical to the reference heap.
    let mut bucket = scale_cfg();
    bucket.lambda.invoke_latency_s = 0.0;
    bucket.lambda.invoke_jitter_sigma = 0.0;
    bucket.compute.task_overhead_s = 0.0;
    bucket.storage.op_latency_s = 0.0;
    bucket.storage.mds_latency_s = 0.0;
    let mut heap = bucket.clone();
    heap.sim.calendar = CalendarKind::Heap;
    let dag = micro::serverless(50_000, 0);
    let b = run_wukong(&dag, &bucket, 1);
    let h = run_wukong(&dag, &heap, 1);
    assert_eq!(b.metrics.tasks_executed, 50_000);
    assert!(b.metrics.per_task_exec.iter().all(|&c| c == 1));
    assert_eq!(b.sim_events, h.sim_events, "event counts diverged");
    assert_eq!(b.peak_pending, h.peak_pending, "calendar depth diverged");
    assert_eq!(b.metrics, h.metrics, "burst run moved with the calendar");
}

#[test]
fn runtime_spawning_to_a_million_tasks_stays_linear() {
    use wukong::dag::{pre_expand, SpawnPlan};
    // The dynamic-DAG stress tier: a certain recursive plan (p=1,
    // fanout 4, depth 2) expands every base task into a 21-task subtree
    // (1 + 4 + 16), so the large leg takes a 50k fan-out to 1,050,000
    // runtime-spawned tasks. The expansion must keep the linear-event
    // guard — spawning enqueues each staged task exactly once, never
    // re-scans — and complete exactly the pre-expanded task count.
    let mut cfg = scale_cfg();
    let plan = SpawnPlan::recursive(1.0, 4, 2);
    cfg.spawn = plan;
    let small_dag = micro::serverless(12_500, 0);
    let large_dag = micro::serverless(50_000, 0);
    assert_eq!(pre_expand(&small_dag, plan, 1).len(), 262_500);
    assert_eq!(pre_expand(&large_dag, plan, 1).len(), 1_050_000);
    let small = run_wukong(&small_dag, &cfg, 1);
    let large = run_wukong(&large_dag, &cfg, 1);
    assert_eq!(small.metrics.tasks_executed, 262_500);
    assert_eq!(large.metrics.tasks_executed, 1_050_000);
    assert_eq!(large.metrics.per_task_exec.len(), 1_050_000);
    assert!(large.metrics.per_task_exec.iter().all(|&c| c == 1));
    let ratio = large.sim_events as f64 / small.sim_events as f64;
    assert!(
        ratio < 8.0,
        "spawned events grew superlinearly: {} -> {} ({ratio:.2}x for 4x tasks)",
        small.sim_events,
        large.sim_events
    );
    assert!(ratio > 2.0, "suspiciously sublinear: {ratio:.2}x");
}

#[test]
fn wukong_long_chain_events_stay_linear() {
    // The pure "becomes" path: one executor, zero invocations — events
    // must be a small constant per task.
    let cfg = Config::default();
    let dag = micro::chains(micro::MicroParams {
        n_chains: 1,
        chain_len: 50_000,
        task_dur: 0,
    });
    let r = run_wukong(&dag, &cfg, 1);
    assert_eq!(r.metrics.tasks_executed, 50_000);
    assert_eq!(r.metrics.executors_used, 1);
    assert!(
        r.sim_events < 10 * 50_000,
        "chain events blew up: {}",
        r.sim_events
    );
}
