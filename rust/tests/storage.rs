//! Integration tests for the storage substrate: shard routing
//! stability, FIFO contention, proxy pass-through byte accounting, MDS
//! coordination + WAL metering, and durable-KVS checkpoint/restore at
//! arbitrary cut points in an op stream.
//!
//! These exercise `storage::{kvs,mds,proxy}` through the public crate
//! surface (the same types the sim engines compose), complementing the
//! in-module unit tests.

use wukong::config::StorageConfig;
use wukong::platform::faults::ShardCrashPlan;
use wukong::sim::secs;
use wukong::storage::{InvokerPool, KvsModel, MdsModel};

fn cfg(n_shards: usize) -> StorageConfig {
    StorageConfig {
        n_shards,
        shard_bw: 100e6,
        op_latency_s: 0.001,
        iops_limit: 0.0,
        ..StorageConfig::default()
    }
}

/// Shard routing is a pure function of the key and the shard count:
/// stable across model instances and insensitive to the ops already
/// served (re-keying a running cluster would break FIFO accounting and
/// recovery alike).
#[test]
fn shard_routing_is_stable_across_instances_and_ops() {
    let a = KvsModel::new(cfg(16));
    let mut b = KvsModel::new(cfg(16));
    let routes: Vec<usize> = (0..500u64).map(|k| a.shard_of(k)).collect();
    for key in 0..500u64 {
        b.write(0, key, 64);
        b.read(0, key, 64);
    }
    let after: Vec<usize> = (0..500u64).map(|k| b.shard_of(k)).collect();
    assert_eq!(routes, after, "routing must not depend on served ops");
    // And every route is in range with a non-degenerate spread.
    let mut hit = vec![false; 16];
    for &s in &routes {
        hit[s] = true;
    }
    assert!(hit.iter().all(|&h| h), "500 keys must touch all 16 shards");
}

/// FIFO contention end to end: a burst of same-instant large transfers
/// serializes per shard, so total completion is bounded below by the
/// busiest shard's queue — and the model's busy-time meter agrees.
#[test]
fn same_shard_bursts_serialize_and_busy_time_accounts_for_it() {
    let mut k = KvsModel::new(cfg(4));
    // Collect 6 keys that all land on shard 0.
    let mut keys = Vec::new();
    let mut key = 0u64;
    while keys.len() < 6 {
        if k.shard_of(key) == 0 {
            keys.push(key);
        }
        key += 1;
    }
    let ends: Vec<_> =
        keys.iter().map(|&key| k.write(0, key, 100_000_000)).collect();
    // 1 s of transfer + 1 ms latency each, strictly FIFO on one shard.
    for (i, &end) in ends.iter().enumerate() {
        assert_eq!(end, secs(1.001) * (i as u64 + 1), "op {i}");
    }
    assert_eq!(k.busy_total(), secs(1.001) * 6);
    assert_eq!(k.metrics.writes, 6);
    assert_eq!(k.metrics.bytes_written, 6 * 100_000_000);
}

/// Proxy pass-through accounting: invocation counts, delegated-fanout
/// counts and inline payload bytes are exact across interleaved batches,
/// and batch latency reflects pool parallelism (the §3.4 claim).
#[test]
fn proxy_accounts_batches_and_inline_bytes_exactly() {
    let mut p = InvokerPool::new(8);
    assert_eq!(p.n_invokers(), 8);
    let mut total_invocations = 0u64;
    let mut total_inline = 0u64;
    for (n, payload) in [(16usize, 2048u64), (8, 0), (3, 777), (1, 1)] {
        let ends = p.invoke_batch(0, n, 10_000, payload);
        assert_eq!(ends.len(), n);
        total_invocations += n as u64;
        total_inline += n as u64 * payload;
    }
    assert_eq!(p.invocations, total_invocations);
    assert_eq!(p.inline_bytes, total_inline);
    assert_eq!(p.delegated_fanouts, 4);
    // 28 serial ops of 10 ms would end at 280 ms; 8 invokers finish the
    // final op no later than ceil(28/8) rounds.
    let mut p1 = InvokerPool::new(1);
    let serial = *p1.invoke_batch(0, 28, 10_000, 0).iter().max().unwrap();
    assert_eq!(serial, 280_000);
}

/// MDS counters drive fan-in coordination: increments are atomic and
/// monotonic per key, reads are non-mutating, and every mutation is
/// WAL-metered (fixed-size counter records) while reads stay free.
#[test]
fn mds_counters_coordinate_and_meter_durability() {
    let mut m = MdsModel::new(&StorageConfig::default());
    // A 5-parent fan-in: the 5th incr (and only it) sees the full count.
    let fanin_key = 42;
    let mut claimed = 0;
    for _ in 0..5 {
        let (v, _) = m.incr(0, fanin_key);
        if v == 5 {
            claimed += 1;
        }
    }
    assert_eq!(claimed, 1, "exactly one parent claims the fan-in");
    assert_eq!(m.peek(fanin_key), 5);
    let (v, _) = m.read(0, fanin_key);
    assert_eq!(v, 5);
    assert_eq!(m.peek(fanin_key), 5, "reads must not mutate");
    assert_eq!(m.ops, 6);
    assert_eq!(m.durability().wal_appends, 5, "5 incrs, 0 for the read");
    assert_eq!(m.durability().wal_bytes, 5 * 16);
    assert_eq!(m.durability().recoveries, 0);
}

/// Checkpoint/restore round-trips losslessly at *every* cut point of an
/// op stream, including cuts that land mid-WAL and right after a
/// snapshot — and a restored model recovers from a crash exactly like
/// the original (the WAL suffix replays over the snapshot).
#[test]
fn checkpoint_round_trips_at_arbitrary_cut_points() {
    let base = StorageConfig {
        n_shards: 4,
        snapshot_every_ops: 3,
        ..StorageConfig::default()
    };
    for cut in 0..30usize {
        let mut k = KvsModel::new(base.clone());
        for i in 0..cut as u64 {
            k.write(0, i % 7, 50 + i);
        }
        let ckpt = k.checkpoint();
        let mut resumed = KvsModel::new(base.clone());
        resumed.restore(&ckpt).unwrap();
        assert_eq!(resumed.durable_state(), k.durable_state(), "cut {cut}");
        assert_eq!(resumed.checkpoint(), ckpt, "cut {cut}: re-checkpoint");
        // Continue both models with crash-free vs crash-every-op
        // configs: recovery replays the restored snapshot + WAL, so the
        // durable view stays identical despite the crashes.
        let mut crashy = KvsModel::with_crashes(
            base.clone(),
            ShardCrashPlan::with_crashes(1.0, u32::MAX),
            7,
        );
        crashy.restore(&ckpt).unwrap();
        for i in cut as u64..cut as u64 + 5 {
            resumed.write(0, i % 7, 50 + i);
            crashy.write(0, i % 7, 50 + i);
        }
        assert_eq!(
            resumed.durable_state(),
            crashy.durable_state(),
            "cut {cut}: crashed continuation diverged"
        );
        assert_eq!(crashy.durability.recoveries, 5);
    }
}
