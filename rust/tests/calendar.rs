//! Differential calendar suite: the bucketed calendar queue must
//! reproduce the binary heap's pop sequence *exactly* — same `(t, seq)`
//! tuples in the same order — on every schedule shape that stresses its
//! geometry (clustered, uniform, far-future overflow, same-timestamp
//! bursts, adversarial pop/push interleavings), and `Sim<E>` built on
//! either structure must report identical counters (`processed`,
//! `peak_pending`, end time) and trip the event-budget watchdog at the
//! identical point. Since `seq` is unique, `(t, seq)` is a total order,
//! so any discrepancy here is a bucket-queue bug, not a tie-break
//! ambiguity.

use wukong::sim::{
    BucketCalendar, Calendar, CalendarKind, Handler, HeapCalendar, Sim, Time,
};
use wukong::util::prop::check;
use wukong::util::Rng;

/// Drive the same `(t, seq)` pushes through both structures, then pop
/// both dry, asserting the sequences match element-for-element.
fn assert_same_drain(label: &str, pushes: &[Time]) {
    let mut bucket: BucketCalendar<u64> = BucketCalendar::new(None);
    let mut heap: HeapCalendar<u64> = HeapCalendar::new();
    for (seq, &t) in pushes.iter().enumerate() {
        bucket.push(t, seq as u64, seq as u64);
        heap.push(t, seq as u64, seq as u64);
    }
    assert_eq!(bucket.len(), heap.len(), "{label}: len after pushes");
    let mut popped = 0usize;
    loop {
        assert_eq!(
            bucket.next_time(),
            heap.next_time(),
            "{label}: next_time after {popped} pops"
        );
        let (b, h) = (bucket.pop(), heap.pop());
        match (b, h) {
            (None, None) => break,
            (Some(b), Some(h)) => {
                assert_eq!(
                    (b.t, b.seq, b.ev),
                    (h.t, h.seq, h.ev),
                    "{label}: pop #{popped} diverged"
                );
            }
            (b, h) => panic!(
                "{label}: pop #{popped}: bucket {:?} vs heap {:?}",
                b.map(|e| (e.t, e.seq)),
                h.map(|e| (e.t, e.seq))
            ),
        }
        popped += 1;
    }
    assert_eq!(popped, pushes.len(), "{label}: drained count");
    assert!(bucket.is_empty() && heap.is_empty());
}

#[test]
fn clustered_schedules_pop_identically() {
    // Tight clusters separated by gaps 6 orders of magnitude wider than
    // the cluster span: every cluster past the first starts life in the
    // overflow heap and crosses `advance_year`.
    let mut rng = Rng::new(0xca1e);
    let mut pushes = Vec::new();
    for cluster in 0..20u64 {
        let base = cluster * 1_000_000_000_000;
        for _ in 0..200 {
            pushes.push(base + rng.below(1_000));
        }
    }
    assert_same_drain("clustered", &pushes);
}

#[test]
fn uniform_schedules_pop_identically() {
    let mut rng = Rng::new(0x0f1);
    let pushes: Vec<Time> =
        (0..5_000).map(|_| rng.below(10_000_000)).collect();
    assert_same_drain("uniform", &pushes);
}

#[test]
fn far_future_outliers_pop_identically() {
    // A dense near-term backlog with a handful of events near the top
    // of the time axis: the auto-width heuristic sees a huge span, and
    // the outliers must sit in overflow without perturbing near-term
    // order.
    let mut rng = Rng::new(0xfa2);
    let mut pushes: Vec<Time> = (0..3_000).map(|_| rng.below(50_000)).collect();
    for _ in 0..7 {
        pushes.push(u64::MAX / 2 + rng.below(1_000_000));
    }
    pushes.push(u64::MAX - 1);
    assert_same_drain("far-future", &pushes);
}

#[test]
fn same_timestamp_bursts_preserve_fifo() {
    // The all-ties case: everything lands in one bucket and pop order
    // must be pure insertion order on both structures.
    let pushes = vec![777u64; 4_096];
    assert_same_drain("burst", &pushes);
    // Mixed: a burst inside a spread-out schedule.
    let mut rng = Rng::new(0xb0b);
    let mut mixed: Vec<Time> = (0..1_000).map(|_| rng.below(1_000)).collect();
    mixed.extend(std::iter::repeat(500).take(2_048));
    mixed.extend((0..1_000).map(|_| rng.below(1_000)));
    assert_same_drain("burst-mixed", &mixed);
}

#[test]
fn random_pop_push_interleavings_match() {
    // Adversarial op streams over the *raw* structures, including
    // pushes behind an already-advanced year window (legal on the raw
    // calendar; `Sim::at` clamps so engines never do this). Checks
    // every pop and every `next_time`/`len` observation, not just the
    // final drain.
    check(0x1eaf, 40, |rng| {
        let mut bucket: BucketCalendar<u32> = BucketCalendar::new(None);
        let mut heap: HeapCalendar<u32> = HeapCalendar::new();
        let mut seq = 0u64;
        let ops = 400 + rng.below(600);
        for op in 0..ops {
            if rng.below(100) < 60 || bucket.is_empty() {
                // Push: usually near-term, sometimes far-future,
                // sometimes behind everything pushed so far.
                let t = match rng.below(10) {
                    0..=6 => 1_000_000 + rng.below(100_000),
                    7 => rng.below(1_000), // behind the window
                    8 => u64::MAX / 4 + rng.below(1_000_000),
                    _ => 1_000_000, // exact tie hot-spot
                };
                bucket.push(t, seq, seq as u32);
                heap.push(t, seq, seq as u32);
                seq += 1;
            } else {
                let (b, h) = (bucket.pop(), heap.pop());
                assert_eq!(
                    b.as_ref().map(|e| (e.t, e.seq, e.ev)),
                    h.as_ref().map(|e| (e.t, e.seq, e.ev)),
                    "op #{op} diverged"
                );
            }
            assert_eq!(bucket.len(), heap.len(), "len after op #{op}");
            assert_eq!(
                bucket.next_time(),
                heap.next_time(),
                "next_time after op #{op}"
            );
        }
        // Drain whatever is left in lock-step.
        while let Some(h) = heap.pop() {
            let b = bucket.pop().expect("bucket drained early");
            assert_eq!((b.t, b.seq, b.ev), (h.t, h.seq, h.ev));
        }
        assert!(bucket.pop().is_none());
    });
}

#[test]
fn pinned_width_extremes_match_heap() {
    // Degenerate geometries — 1 µs buckets under a wide spread (every
    // event beyond the first window is overflow) and near-max-width
    // buckets (everything collapses into bucket 0) — still reproduce
    // the reference order.
    let mut rng = Rng::new(0x31d);
    let pushes: Vec<Time> =
        (0..2_000).map(|_| rng.below(100_000_000)).collect();
    for width in [1, u64::MAX / 2] {
        let mut bucket: BucketCalendar<u64> =
            BucketCalendar::new(Some(width));
        let mut heap: HeapCalendar<u64> = HeapCalendar::new();
        for (seq, &t) in pushes.iter().enumerate() {
            bucket.push(t, seq as u64, seq as u64);
            heap.push(t, seq as u64, seq as u64);
        }
        loop {
            let (b, h) = (bucket.pop(), heap.pop());
            assert_eq!(
                b.as_ref().map(|e| (e.t, e.seq)),
                h.as_ref().map(|e| (e.t, e.seq)),
                "width {width}"
            );
            if h.is_none() {
                break;
            }
        }
    }
}

// ---------------------------------------------------------------------
// Sim-level parity: the full event loop (dynamic scheduling included)
// over both calendar kinds.
// ---------------------------------------------------------------------

enum Ev {
    /// Record `(now, tag)`.
    Log(u64),
    /// Schedule `n` `Log` events `dt, dt+1, ...` ticks out (in-run
    /// pushes that race the cursor and trigger mid-run re-plans).
    Spawn { dt: Time, n: u64 },
}

#[derive(Default)]
struct Recorder {
    log: Vec<(Time, u64)>,
}

impl Handler for Recorder {
    type Ev = Ev;

    fn handle(&mut self, sim: &mut Sim<Ev>, ev: Ev) {
        match ev {
            Ev::Log(tag) => self.log.push((sim.now(), tag)),
            Ev::Spawn { dt, n } => {
                for k in 0..n {
                    sim.after(dt + k, Ev::Log(1_000_000 + k));
                }
            }
        }
    }
}

/// Seed both sims with an identical schedule mixing static far-apart
/// events, same-time bursts, and dynamic spawners.
fn seed_schedule(sim: &mut Sim<Ev>, seed: u64) {
    let mut rng = Rng::new(seed);
    for i in 0..300u64 {
        sim.at(rng.below(1_000_000), Ev::Log(i));
    }
    for i in 0..50u64 {
        sim.at(123_456, Ev::Log(10_000 + i)); // burst
    }
    for _ in 0..20 {
        let dt = 1 + rng.below(10_000);
        sim.at(rng.below(500_000), Ev::Spawn { dt, n: 25 });
    }
    sim.at(900_000_000_000, Ev::Log(42)); // far-future outlier
}

fn sims() -> (Sim<Ev>, Sim<Ev>) {
    (
        Sim::with_calendar(CalendarKind::Bucket, 0),
        Sim::with_calendar(CalendarKind::Heap, 0),
    )
}

#[test]
fn sim_runs_byte_identically_on_both_calendars() {
    for seed in [1u64, 7, 99] {
        let (mut bucket, mut heap) = sims();
        let (mut wb, mut wh) = (Recorder::default(), Recorder::default());
        seed_schedule(&mut bucket, seed);
        seed_schedule(&mut heap, seed);
        let (eb, eh) = (bucket.run(&mut wb), heap.run(&mut wh));
        assert_eq!(eb, eh, "end time (seed {seed})");
        assert_eq!(wb.log, wh.log, "event trace (seed {seed})");
        assert_eq!(bucket.processed(), heap.processed());
        assert_eq!(bucket.peak_pending(), heap.peak_pending());
        assert_eq!(bucket.pending(), 0);
        assert_eq!(heap.pending(), 0);
    }
}

#[test]
fn sim_run_until_parity_across_calendars() {
    let (mut bucket, mut heap) = sims();
    let (mut wb, mut wh) = (Recorder::default(), Recorder::default());
    seed_schedule(&mut bucket, 5);
    seed_schedule(&mut heap, 5);
    // Step both through a staircase of deadlines; state must agree at
    // every step, including pending backlog and the clamped `now`.
    for deadline in [1_000, 250_000, 250_000, 7_777_777, u64::MAX] {
        let (nb, nh) = (
            bucket.run_until(&mut wb, deadline),
            heap.run_until(&mut wh, deadline),
        );
        assert_eq!(nb, nh, "now at deadline {deadline}");
        assert_eq!(wb.log, wh.log, "trace at deadline {deadline}");
        assert_eq!(bucket.pending(), heap.pending());
        assert_eq!(bucket.processed(), heap.processed());
        assert_eq!(bucket.peak_pending(), heap.peak_pending());
    }
    assert_eq!(bucket.pending(), 0, "u64::MAX deadline drains everything");
}

#[test]
fn event_budget_watchdog_trips_identically() {
    // The livelock watchdog must fire after the same number of events
    // with the same message on both structures — verify's fault
    // reporting depends on that equivalence.
    let budget = 100u64;
    let mut msgs = Vec::new();
    for kind in [CalendarKind::Bucket, CalendarKind::Heap] {
        let mut sim: Sim<Ev> = Sim::with_calendar(kind, 0);
        sim.set_event_budget(budget);
        seed_schedule(&mut sim, 11);
        let mut w = Recorder::default();
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || {
                sim.run(&mut w);
            },
        ))
        .expect_err("budget must trip: the schedule exceeds 100 events");
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("sim event budget exceeded"), "{msg}");
        msgs.push((msg, w.log));
    }
    assert_eq!(msgs[0], msgs[1], "same message, same partial trace");
}

#[test]
fn peak_pending_is_calendar_independent() {
    // `peak_pending` feeds BENCH_*.json; it must not depend on which
    // structure backs the calendar (it counts entries, not buckets).
    check(0x9eaf, 20, |rng| {
        let (mut bucket, mut heap) = sims();
        let n = 50 + rng.below(500);
        let burst_t = rng.below(1_000_000);
        for i in 0..n {
            let t = if rng.below(4) == 0 {
                burst_t
            } else {
                rng.below(2_000_000)
            };
            bucket.at(t, Ev::Log(i));
            heap.at(t, Ev::Log(i));
        }
        let (mut wb, mut wh) = (Recorder::default(), Recorder::default());
        bucket.run(&mut wb);
        heap.run(&mut wh);
        assert_eq!(bucket.peak_pending(), heap.peak_pending());
        assert_eq!(bucket.peak_pending(), n as usize);
        assert_eq!(wb.log, wh.log);
    });
}
