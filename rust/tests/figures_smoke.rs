//! Figure-harness smoke + shape assertions: every paper figure runs in
//! quick mode and the key qualitative claims hold.

use wukong::config::Config;
use wukong::figures;

#[test]
fn all_figures_render_nonempty_tables() {
    let cfg = Config::default();
    for id in figures::all_ids() {
        let fig = figures::run(id, &cfg, true).unwrap();
        let rendered = fig.table.render();
        assert!(rendered.lines().count() >= 3, "{id}: {rendered}");
        assert!(!fig.caption.is_empty());
    }
}

#[test]
fn fig2_pywren_grows_wukong_stays_flat() {
    let cfg = Config::default();
    let fig = figures::run("fig2", &cfg, true).unwrap();
    let rows: Vec<Vec<f64>> = fig
        .table
        .render()
        .lines()
        .skip(2)
        .map(|l| {
            l.split('|')
                .filter_map(|c| c.trim().parse::<f64>().ok())
                .collect()
        })
        .collect();
    // columns: n, launch, pywren e2e, wukong e2e
    let first = &rows[0];
    let last = &rows[rows.len() - 1];
    // pywren end-to-end grows superlinearly-ish with N...
    assert!(last[2] > first[2]);
    // ...and wukong stays within seconds
    assert!(last[3] < 10.0, "wukong e2e {}", last[3]);
}

#[test]
fn fig23_staircase_is_monotone() {
    let cfg = Config::default();
    let fig = figures::run("fig23", &cfg, true).unwrap();
    let makespans: Vec<f64> = fig
        .table
        .render()
        .lines()
        .skip(2)
        .map(|l| {
            l.split('|').nth(2).unwrap().trim().parse::<f64>().unwrap()
        })
        .collect();
    assert_eq!(makespans.len(), 4);
    for w in makespans.windows(2) {
        assert!(
            w[1] <= w[0] * 1.02,
            "factor analysis regressed: {makespans:?}"
        );
    }
}
