//! `cargo bench --bench figures` — regenerates every paper figure at full
//! problem sizes and reports wall time per figure. (criterion is not in
//! the offline crate set; this is a plain `harness = false` driver.)
//!
//! The rendered tables are the reproduction artifact: paste into
//! EXPERIMENTS.md and compare shapes against the paper.

use std::time::Instant;

use wukong::config::Config;
use wukong::figures;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = Config::default();
    let total = Instant::now();
    for id in figures::all_ids() {
        let t0 = Instant::now();
        let fig = figures::run(id, &cfg, quick).expect("registered figure");
        let dt = t0.elapsed();
        println!("== {} — {} [generated in {:.2?}]", fig.id, fig.caption, dt);
        println!("{}", fig.table.render());
    }
    println!("total: {:.2?}", total.elapsed());
}
