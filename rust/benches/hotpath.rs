//! `cargo bench --bench hotpath` — microbenchmarks of the L3 hot paths
//! plus real PJRT kernel latencies (L1/L2), with before/after numbers
//! recorded in EXPERIMENTS.md §Perf. Plain `harness = false` driver
//! (criterion is not in the offline crate set).
//!
//! Targets (DESIGN.md §Perf):
//!  * DES engine:     ≥ 1M events/s
//!  * Wukong sim:     10k-Lambda serverless scaling sweep ≪ 1 s
//!  * Million-task:   `wukong bench` regime — see BENCH_PR2.json
//!  * real executor:  coordinator overhead per task ≪ the 50 ms invoke
//!  * PJRT kernels:   per-op latency (informational; interpret=True CPU)

use std::time::{Duration, Instant};

use wukong::config::Config;
use wukong::coordinator::run_wukong;
use wukong::sim::{secs, Handler, Sim};
use wukong::util::Rng;
use wukong::workloads::{micro, svd, tsqr};

fn bench<F: FnMut()>(name: &str, iters: u32, mut f: F) -> Duration {
    // warmup
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed() / iters;
    println!("{name:<44} {per:>12.2?}/iter  ({iters} iters)");
    per
}

/// Empty world for raw-calendar benchmarks (typed unit events).
struct NopWorld;

impl Handler for NopWorld {
    type Ev = ();

    fn handle(&mut self, _sim: &mut Sim<()>, _ev: ()) {}
}

fn main() {
    println!("== L3: DES engine ==");
    let per = bench("des: 1M empty events", 5, || {
        let mut sim: Sim<()> = Sim::new();
        for i in 0..1_000_000u64 {
            sim.at(i, ());
        }
        sim.run(&mut NopWorld);
    });
    let evps = 1_000_000.0 / per.as_secs_f64();
    println!("  -> {:.1}M events/s (target >= 1M/s)", evps / 1e6);

    let cfg = Config::default();
    bench("wukong sim: serverless 10k lambdas", 3, || {
        let mut c = cfg.clone();
        c.lambda.concurrency_limit = 10_000;
        let dag = micro::serverless(10_000, 0);
        let r = run_wukong(&dag, &c, 1);
        assert_eq!(r.metrics.tasks_executed, 10_000);
    });
    bench("wukong sim: serverless 1M lambdas (bench gate)", 1, || {
        let mut c = cfg.clone();
        c.lambda.concurrency_limit = 2_000_000;
        let dag = micro::serverless(1_000_000, 0);
        let r = run_wukong(&dag, &c, 1);
        assert_eq!(r.metrics.tasks_executed, 1_000_000);
        println!(
            "  -> {} events, peak pending {}",
            r.sim_events, r.peak_pending
        );
    });
    bench("wukong sim: strong 10k tasks / 1k chains", 3, || {
        let dag = micro::strong(10_000, 1_000, secs(0.1));
        run_wukong(&dag, &cfg, 1);
    });
    bench("wukong sim: TSQR 16.7M (~4096 leaves)", 1, || {
        let dag = tsqr::dag(tsqr::TsqrParams::paper(16.7));
        run_wukong(&dag, &cfg, 1);
    });
    bench("wukong sim: SVD2 50k full", 3, || {
        let mut c = cfg.clone();
        c.wukong.clustering_threshold = 1 << 20;
        let dag = svd::svd2(svd::Svd2Params::paper(50));
        run_wukong(&dag, &c, 1);
    });

    println!("\n== L3 substrates ==");
    bench("rng: 10M u64", 10, || {
        let mut r = Rng::new(1);
        let mut acc = 0u64;
        for _ in 0..10_000_000 {
            acc ^= r.next_u64();
        }
        std::hint::black_box(acc);
    });
    bench("json: parse manifest 1000x", 5, || {
        let text = std::fs::read_to_string("artifacts/manifest.json")
            .unwrap_or_else(|_| r#"{"ops":{}}"#.into());
        for _ in 0..1000 {
            std::hint::black_box(
                wukong::util::json::Json::parse(&text).unwrap(),
            );
        }
    });

    println!("\n== L1/L2: PJRT kernel latency (interpret-mode CPU) ==");
    match wukong::runtime::SharedRuntime::load(
        &wukong::runtime::default_artifact_dir(),
    ) {
        Ok(rt) => {
            rt.warmup().expect("warmup");
            let mut rng = Rng::new(7);
            let t8192 = wukong::runtime::Tensor::new(
                vec![8192],
                rng.f32_vec(8192),
            );
            bench("pjrt: tr_add 8192", 50, || {
                rt.execute("tr_add_f32_8192", &[t8192.clone(), t8192.clone()])
                    .unwrap();
            });
            let m256 = wukong::runtime::Tensor::new(
                vec![256, 256],
                rng.f32_vec(256 * 256),
            );
            let per = bench("pjrt: gemm_block 256 (33.6 MFLOP)", 30, || {
                rt.execute("gemm_block_f32_256", &[m256.clone(), m256.clone()])
                    .unwrap();
            });
            println!(
                "  -> {:.2} GFLOP/s effective",
                2.0 * 256f64.powi(3) / per.as_secs_f64() / 1e9
            );
            let tall = wukong::runtime::Tensor::new(
                vec![1024, 128],
                rng.f32_vec(1024 * 128),
            );
            bench("pjrt: qr_factor 1024x128", 5, || {
                rt.execute("qr_factor_f32_1024x128", &[tall.clone()]).unwrap();
            });
            bench("pjrt: gram 1024x128", 20, || {
                rt.execute("gram_f32_1024x128", &[tall.clone()]).unwrap();
            });
        }
        Err(e) => println!("(skipping PJRT benches: {e})"),
    }
}
