//! Dask-distributed model: serverful central scheduler + VM worker pool
//! (§4.1's Dask-125 and Dask-1000 configurations).
//!
//! The scheduler is a single FIFO service (every ready-task assignment
//! and every completion message passes through it — the Dask-1000
//! bottleneck); workers hold their outputs in memory (data locality) and
//! fetch missing inputs peer-to-peer over their NICs. Assignment prefers
//! the worker holding the most input bytes, tie-broken by earliest-free
//! core — Dask's own locality heuristic.
//!
//! Hot-path layout mirrors the other sim engines: the world borrows the
//! DAG/configs, adjacency comes from the CSR slices, and the calendar
//! carries typed events (no per-event allocation).

use std::collections::VecDeque;

use crate::config::{Config, DaskConfig};
use crate::dag::{Dag, SpawnState, TaskId, TaskNode};
use crate::metrics::{RunMetrics, TaskOutcome};
use crate::platform::faults::FaultStream;
use crate::sim::{
    secs, to_secs, FifoResource, Handler, MultiResource, ReadyCounters, Sim,
    Time,
};

use super::BaselineReport;

struct Worker {
    cores: MultiResource,
    nic: FifoResource,
    holds: Vec<bool>, // task outputs resident (indexed by TaskId)
    used: bool,
}

/// Typed calendar events.
enum Ev {
    /// Scheduler assigns the next ready task.
    Schedule,
    /// Worker `wid` starts fetching + computing `task`.
    Exec { wid: usize, task: TaskId },
    /// Worker `wid` finished `task`.
    Done { wid: usize, task: TaskId },
}

struct World<'a> {
    cfg: &'a Config,
    dcfg: &'a DaskConfig,
    dag: &'a Dag,
    sched: FifoResource,
    ready: VecDeque<TaskId>,
    /// Remaining-parent counters (branch-light CSR sweep in `complete`).
    remaining: ReadyCounters,
    /// Per-task execution counters (fail-fast on 2; see RunMetrics).
    executed: Vec<u32>,
    /// Primary location of each task's output (executing worker).
    loc: Vec<Option<usize>>,
    /// External input partitions' round-robin placement.
    input_loc: Vec<usize>,
    workers: Vec<Worker>,
    metrics: RunMetrics,
    done: u64,
    finish: Option<Time>,
    busy: crate::metrics::Timeline,
    /// Dedicated fault RNG stream (§3.6); Dask has no other randomness,
    /// so fault-free runs stay seed-independent and bit-identical.
    faults: FaultStream,
    /// Per-task attempt counters (failed executions + the effective one).
    attempts: Vec<u32>,
    /// Failed attempts so far per task (retry-budget bookkeeping).
    fail_count: Vec<u32>,
    /// Live terminal outcomes; failures cascade in as budgets exhaust.
    outcome: Vec<TaskOutcome>,
    /// Tasks resolved Failed so far; termination is `done + n_failed == total`.
    n_failed: u64,
    /// Runtime-spawning state (`cfg.spawn`); staged ids pre-laid-out.
    spawn: SpawnState,
    /// Expanded task count (`spawn.total_len()`); every staged task
    /// resolves (spawner completes → it runs; spawner fails → cascade).
    total: u64,
}

impl Handler for World<'_> {
    type Ev = Ev;

    fn handle(&mut self, sim: &mut Sim<Ev>, ev: Ev) {
        match ev {
            Ev::Schedule => schedule_next(self, sim),
            Ev::Exec { wid, task } => exec_on_worker(self, sim, wid, task),
            Ev::Done { wid, task } => complete(self, sim, wid, task),
        }
    }
}

impl World<'_> {
    /// Task node, spawn-aware (staged ids resolve via the spawn state).
    fn node(&self, t: TaskId) -> TaskNode {
        if self.spawn.is_staged(t) {
            self.spawn.node(t)
        } else {
            *self.dag.task(t)
        }
    }

    fn compute_time(&self, t: TaskId) -> Time {
        let node = self.node(t);
        match node.dur_override {
            Some(d) => d + secs(self.cfg.compute.task_overhead_s),
            None => secs(
                node.flops / (self.dcfg.gflops_per_core * 1e9)
                    + self.cfg.compute.task_overhead_s,
            ),
        }
    }

    /// Bytes of task `t`'s inputs already resident on worker `wid`.
    /// Spawned tasks enter the locality heuristic exactly like declared
    /// ones: their single input is the spawner's output.
    fn local_bytes(&self, t: TaskId, wid: usize) -> u64 {
        let mut bytes = 0;
        let pbuf;
        let parents: &[TaskId] = if self.spawn.is_staged(t) {
            pbuf = [self.spawn.parent_of(t)];
            &pbuf
        } else {
            self.dag.parents(t)
        };
        for &p in parents {
            if self.workers[wid].holds[p as usize] {
                bytes += self.node(p).out_bytes;
            }
        }
        let node = self.node(t);
        if node.input_bytes > 0 && self.input_loc[t as usize] == wid {
            bytes += node.input_bytes;
        }
        bytes
    }
}

/// Scheduler picks up the next ready task (one message each).
fn schedule_next(w: &mut World<'_>, sim: &mut Sim<Ev>) {
    let Some(t) = w.ready.pop_front() else {
        return;
    };
    let (_, end) = w.sched.acquire(sim.now(), secs(w.dcfg.effective_msg_s()));
    // Locality-aware assignment: max local bytes, then earliest-free core.
    let wid = (0..w.workers.len())
        .max_by_key(|&wid| {
            (
                w.local_bytes(t, wid),
                std::cmp::Reverse(w.workers[wid].cores.next_free()),
            )
        })
        .expect("at least one worker");
    w.workers[wid].used = true;
    let dispatch = end + secs(w.dcfg.dispatch_latency_s);
    sim.at(dispatch, Ev::Exec { wid, task: t });
    // Keep draining the ready queue.
    if !w.ready.is_empty() {
        sim.at(end, Ev::Schedule);
    }
}

fn exec_on_worker(w: &mut World<'_>, sim: &mut Sim<Ev>, wid: usize, t: TaskId) {
    w.attempts[t as usize] += 1;
    if w.faults.attempt_fails() {
        // The worker process died on this task (§3.6): the scheduler
        // hears about it (one message), re-queues the task while its
        // retry budget lasts, else reports it — and everything
        // downstream — failed.
        let attempt = w.fail_count[t as usize];
        w.fail_count[t as usize] += 1;
        let (_, end) =
            w.sched.acquire(sim.now(), secs(w.dcfg.effective_msg_s()));
        w.metrics.breakdown.publish_s += to_secs(end - sim.now());
        if w.cfg.faults.can_retry(attempt) {
            w.ready.push_back(t);
            sim.at(end, Ev::Schedule);
        } else {
            w.metrics.failed_executors += 1;
            let dag = w.dag;
            // Spawn-aware cascade: a failed task also dooms the staged
            // subtree it would have spawned.
            w.n_failed +=
                w.spawn.propagate_failures(dag, &[t], &mut w.outcome);
            if w.done + w.n_failed == w.total {
                w.finish = Some(end);
            }
        }
        return;
    }
    // Fetch missing inputs peer-to-peer (sequential transfers). Staged
    // tasks fetch exactly one input — their spawner's output.
    let dag = w.dag;
    let mut cursor = sim.now();
    let pbuf;
    let parents: &[TaskId] = if w.spawn.is_staged(t) {
        pbuf = [w.spawn.parent_of(t)];
        &pbuf
    } else {
        dag.parents(t)
    };
    for &p in parents {
        if w.workers[wid].holds[p as usize] {
            continue;
        }
        let bytes = w.node(p).out_bytes;
        let src = w.loc[p as usize].expect("parent executed");
        let svc = secs(bytes as f64 / w.dcfg.worker_bw);
        let (_, src_end) = w.workers[src].nic.acquire(cursor, svc);
        let (_, dst_end) = w.workers[wid].nic.acquire(cursor, svc);
        let end = src_end.max(dst_end);
        w.metrics.breakdown.kvs_read_s += to_secs(end - cursor);
        cursor = end;
        w.workers[wid].holds[p as usize] = true;
    }
    // External partition: local by placement for leaves; remote otherwise.
    let ext = w.node(t).input_bytes;
    if ext > 0 && w.input_loc[t as usize] != wid {
        let src = w.input_loc[t as usize];
        let svc = secs(ext as f64 / w.dcfg.worker_bw);
        let (_, src_end) = w.workers[src].nic.acquire(cursor, svc);
        let (_, dst_end) = w.workers[wid].nic.acquire(cursor, svc);
        let end = src_end.max(dst_end);
        w.metrics.breakdown.kvs_read_s += to_secs(end - cursor);
        cursor = end;
    }
    // Compute on one core.
    let d = w.compute_time(t);
    w.metrics.breakdown.execute_s += to_secs(d);
    let (cstart, cend) = w.workers[wid].cores.acquire(cursor, d);
    w.busy.add(cstart, 1);
    w.busy.add(cend, -1);
    sim.at(cend, Ev::Done { wid, task: t });
}

fn complete(w: &mut World<'_>, sim: &mut Sim<Ev>, wid: usize, t: TaskId) {
    w.executed[t as usize] += 1;
    assert!(w.executed[t as usize] == 1, "task {t} executed twice");
    w.metrics.tasks_executed += 1;
    w.done += 1;
    w.workers[wid].holds[t as usize] = true;
    w.loc[t as usize] = Some(wid);
    // Completion message through the scheduler.
    let (_, end) = w.sched.acquire(sim.now(), secs(w.dcfg.effective_msg_s()));
    w.metrics.breakdown.publish_s += to_secs(end - sim.now());
    let dag = w.dag;
    let mut newly = false;
    if !w.spawn.is_staged(t) {
        let (remaining, ready) = (&mut w.remaining, &mut w.ready);
        newly = remaining.complete(dag, t, |c| ready.push_back(c));
    }
    // Runtime spawning: spawned children enqueue after the base children
    // — the sealed DAG's child order, so the ready queue matches a
    // pre-expanded run exactly.
    for c in w.spawn.spawned_children(t) {
        w.remaining.mark_ready(c);
        w.ready.push_back(c);
        newly = true;
    }
    if w.done + w.n_failed == w.total {
        w.finish = Some(end);
    } else if newly {
        sim.at(end, Ev::Schedule);
    }
}

/// Run a Dask job under the given cluster configuration, with sim stats.
pub fn run_dask_full(
    dag: &Dag,
    cfg: &Config,
    dcfg: &DaskConfig,
    seed: u64,
) -> BaselineReport {
    // Epoch open: freeze the spawn expansion and size per-task state
    // (including per-worker hold bitmaps and the external-input
    // placement, a pure id function) to the expanded count — exactly
    // what a pre-expanded run allocates.
    let spawn = SpawnState::for_run(dag, cfg.spawn, seed);
    let n = spawn.total_len();
    let mut remaining = ReadyCounters::new(dag);
    remaining.grow_to(n, 1); // staged tasks: one parent (their spawner)
    let mut w = World {
        cfg,
        dcfg,
        dag,
        sched: FifoResource::new(),
        ready: dag.leaves().iter().copied().collect(),
        remaining,
        executed: vec![0; n],
        loc: vec![None; n],
        input_loc: (0..n).map(|i| i % dcfg.n_workers).collect(),
        workers: (0..dcfg.n_workers)
            .map(|_| Worker {
                cores: MultiResource::new(dcfg.cores_per_worker),
                nic: FifoResource::new(),
                holds: vec![false; n],
                used: false,
            })
            .collect(),
        metrics: RunMetrics::default(),
        done: 0,
        finish: None,
        busy: crate::metrics::Timeline::default(),
        // The seed feeds only the fault and spawn streams: fault-free
        // plan-free Dask runs stay identical across seeds (the engine is
        // otherwise deterministic by construction).
        faults: FaultStream::for_run(cfg.faults, seed),
        attempts: vec![0; n],
        fail_count: vec![0; n],
        outcome: vec![TaskOutcome::Completed; n],
        n_failed: 0,
        total: n as u64,
        spawn,
    };
    let mut sim: Sim<Ev> = cfg.sim.build();
    sim.set_event_budget(cfg.event_budget);
    // Kick the scheduler once per initially-ready task.
    let initially_ready = w.ready.len();
    for _ in 0..initially_ready {
        sim.at(0, Ev::Schedule);
    }
    sim.run(&mut w);

    let makespan = to_secs(w.finish.unwrap_or(sim.now()));
    w.metrics.makespan_s = makespan;
    w.metrics.per_task_exec = w.executed.clone();
    w.metrics.failed_tasks = w.n_failed;
    w.metrics.per_task_attempts = w.attempts.clone();
    w.metrics.per_task_outcome = w.outcome.clone();
    w.metrics.invocations = w.metrics.tasks_executed; // dispatches
    let used = w.workers.iter().filter(|wk| wk.used).count();
    w.metrics.executors_used = used as u64;
    w.metrics.peak_concurrency = w.busy.peak() as usize;
    // Fig. 17 counts the cores *allocated* to active workers for the
    // job's duration (Dask holds them regardless of utilization).
    w.metrics.cpu_seconds = used as f64 * dcfg.cores_per_worker as f64 * makespan;
    w.metrics.timeline = w.busy.clone();
    // Billing: only the VMs hosting active workers, for the makespan.
    let total_vms = (dcfg.n_workers * dcfg.cores_per_worker).div_ceil(16);
    let vms_used =
        ((used * dcfg.cores_per_worker).div_ceil(16)).min(total_vms.max(1));
    let rate = dcfg.cluster_dollars_per_hour / total_vms.max(1) as f64;
    w.metrics
        .billing
        .charge_ec2(rate * vms_used as f64, makespan / 3600.0);
    BaselineReport {
        metrics: w.metrics,
        sim_events: sim.processed(),
        peak_pending: sim.peak_pending(),
    }
}

/// Run a Dask job under the given cluster configuration.
pub fn run_dask(dag: &Dag, cfg: &Config, dcfg: &DaskConfig, seed: u64) -> RunMetrics {
    run_dask_full(dag, cfg, dcfg, seed).metrics
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::{micro, tr};

    #[test]
    fn executes_all_tasks() {
        let dag = tr::dag(tr::TrParams {
            n: 64,
            chunk: 1,
            delay: Some(secs(0.01)),
        });
        let m = run_dask(&dag, &Config::default(), &DaskConfig::workers_125(), 1);
        assert_eq!(m.tasks_executed, 63);
        assert!(m.makespan_s > 0.0);
    }

    #[test]
    fn dask_beats_lambda_overhead_for_tiny_tasks() {
        // The paper's Fig. 9 base case: TCP dispatch ≪ Lambda invocation.
        let dag = micro::serverless(512, 0);
        let cfg = Config::default();
        let dm = run_dask(&dag, &cfg, &DaskConfig::workers_125(), 1);
        let wm = crate::coordinator::run_wukong(&dag, &cfg, 1);
        assert!(dm.makespan_s < wm.metrics.makespan_s);
    }

    #[test]
    fn locality_prefers_holding_worker() {
        // chain: second task should run where the first ran (no transfer)
        let dag = micro::chains(micro::MicroParams {
            n_chains: 1,
            chain_len: 5,
            task_dur: secs(0.01),
        });
        let m = run_dask(&dag, &Config::default(), &DaskConfig::workers_125(), 1);
        assert_eq!(m.executors_used, 1);
        assert_eq!(m.breakdown.kvs_read_s, 0.0);
    }

    #[test]
    fn scheduler_serializes_messages() {
        let dag = micro::serverless(1000, 0);
        let m = run_dask(&dag, &Config::default(), &DaskConfig::workers_1000(), 1);
        // 2 messages per task at 0.8 ms each ≥ 1.6 s total makespan floor
        assert!(m.makespan_s >= 1.0, "makespan={}", m.makespan_s);
    }

    #[test]
    fn more_cores_cost_more_cpu_seconds_when_idle() {
        let dag = micro::serverless(10, secs(0.1));
        let d125 = run_dask(&dag, &Config::default(), &DaskConfig::workers_125(), 1);
        assert!(d125.cpu_seconds > 0.0);
        assert_eq!(d125.tasks_executed, 10);
    }

    #[test]
    fn full_report_carries_sim_stats() {
        let dag = micro::serverless(16, 0);
        let r = run_dask_full(&dag, &Config::default(), &DaskConfig::workers_125(), 1);
        assert_eq!(r.metrics.tasks_executed, 16);
        assert!(r.sim_events > 0);
        assert!(r.peak_pending > 0);
    }

    #[test]
    fn zero_rate_runs_stay_seed_independent_and_identical() {
        let dag = micro::strong(40, 8, secs(0.01));
        let cfg = Config::default();
        let a = run_dask_full(&dag, &cfg, &DaskConfig::workers_125(), 1);
        let b = run_dask_full(&dag, &cfg, &DaskConfig::workers_125(), 99);
        assert_eq!(a.metrics, b.metrics);
        assert_eq!(a.sim_events, b.sim_events);
    }

    #[test]
    fn exhausted_budget_reports_every_task_failed() {
        use crate::platform::faults::FaultPlan;
        let mut cfg = Config::default();
        cfg.faults = FaultPlan::with_retries(1.0, 0);
        let dag = micro::serverless(12, secs(0.01));
        let m = run_dask(&dag, &cfg, &DaskConfig::workers_125(), 3);
        assert_eq!(m.tasks_executed, 0);
        assert_eq!(m.failed_tasks, 12);
        assert!(m.per_task_attempts.iter().all(|&a| a == 1));
        assert!(m
            .per_task_outcome
            .iter()
            .all(|&o| o == TaskOutcome::Failed));
    }

    #[test]
    fn fault_outcomes_partition_the_dag() {
        use crate::platform::faults::FaultPlan;
        let mut cfg = Config::default();
        cfg.faults = FaultPlan::with_failure_rate(0.3);
        let dag = micro::strong(40, 8, secs(0.01));
        let m = run_dask(&dag, &cfg, &DaskConfig::workers_1000(), 7);
        assert_eq!(m.tasks_executed + m.failed_tasks, dag.len() as u64);
        assert!(m.per_task_attempts.iter().all(|&a| a <= 3));
    }

    #[test]
    fn dynamic_spawning_matches_the_pre_expanded_dag() {
        use crate::dag::{pre_expand, SpawnPlan};
        let dag = micro::strong(24, 6, secs(0.01));
        let mut cfg = Config::default();
        cfg.spawn = SpawnPlan::recursive(0.4, 3, 2);
        let seed = 13;
        let dy = run_dask_full(&dag, &cfg, &DaskConfig::workers_125(), seed);

        let expanded = pre_expand(&dag, cfg.spawn, seed);
        assert!(expanded.len() > dag.len(), "plan must actually expand");
        let mut static_cfg = cfg;
        static_cfg.spawn = SpawnPlan::default();
        let st = run_dask_full(&expanded, &static_cfg, &DaskConfig::workers_125(), seed);

        assert_eq!(dy.metrics, st.metrics);
        assert_eq!(dy.sim_events, st.sim_events);
        assert_eq!(dy.peak_pending, st.peak_pending);
        assert_eq!(dy.metrics.tasks_executed, expanded.len() as u64);
    }

    #[test]
    fn zero_rate_spawn_plan_is_bit_identical_to_plan_free() {
        use crate::dag::SpawnPlan;
        let dag = micro::strong(40, 8, secs(0.01));
        let plain = run_dask_full(&dag, &Config::default(), &DaskConfig::workers_125(), 5);
        let mut cfg = Config::default();
        cfg.spawn = SpawnPlan::with_rate(0.0, 4);
        let zero = run_dask_full(&dag, &cfg, &DaskConfig::workers_125(), 5);
        assert_eq!(plain.metrics, zero.metrics);
        assert_eq!(plain.sim_events, zero.sim_events);
        assert_eq!(plain.peak_pending, zero.peak_pending);
    }
}
