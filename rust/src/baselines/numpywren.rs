//! numpywren model: centralized queue scheduling with stateless executors
//! (§1 method #3, §2.2).
//!
//! The provisioner launches `n_workers` Lambda executors through PyWren's
//! invoker threads. Each executor loops: poll the central queue → read
//! *all* task inputs from the KVS → compute → write the output to the KVS
//! → notify the scheduler, which updates dependency counts and enqueues
//! newly-ready tasks. No state survives between tasks — the design whose
//! read/write amplification Figs. 3–4 measure.
//!
//! Hot-path layout mirrors the Wukong engine: the world borrows the DAG
//! and config, adjacency comes from the CSR slices, and the calendar
//! carries typed events (no per-event allocation).

use std::collections::VecDeque;

use crate::config::Config;
use crate::dag::{Dag, SpawnState, TaskId, TaskNode};
use crate::metrics::{RunMetrics, TaskOutcome};
use crate::platform::faults::FaultStream;
use crate::platform::LambdaService;
use crate::sim::{
    secs, to_secs, FifoResource, Handler, MultiResource, ReadyCounters, Sim,
    Time,
};
use crate::storage::KvsModel;
use crate::util::Rng;

use super::BaselineReport;

struct Worker {
    started: Time,
    nic: FifoResource,
    ended: bool,
}

/// Typed calendar events.
enum Ev {
    /// Worker `wid` comes online: stamp its start, then poll.
    Start(usize),
    /// Worker `wid` polls the central queue.
    Poll(usize),
    /// Worker `wid` executes `task` (inputs → compute → output).
    Exec { wid: usize, task: TaskId },
    /// Worker `wid` finished `task`; scheduler-side dependency update.
    Done { wid: usize, task: TaskId },
}

struct World<'a> {
    cfg: &'a Config,
    dag: &'a Dag,
    kvs: KvsModel,
    queue_srv: FifoResource,
    queue: VecDeque<TaskId>,
    /// Remaining-parent counters (branch-light CSR sweep in `complete`).
    remaining: ReadyCounters,
    /// Per-task execution counters (fail-fast on 2; see RunMetrics).
    executed: Vec<u32>,
    done: u64,
    workers: Vec<Worker>,
    lambda: LambdaService,
    metrics: RunMetrics,
    finish: Option<Time>,
    /// Dedicated fault RNG stream (§3.6): failure draws never touch the
    /// main run RNG, so `p_fail = 0` runs are bit-identical to fault-free.
    faults: FaultStream,
    /// Per-task attempt counters (failed executions + the effective one).
    attempts: Vec<u32>,
    /// Failed attempts so far per task (retry-budget bookkeeping).
    fail_count: Vec<u32>,
    /// Live terminal outcomes; failures cascade in as budgets exhaust.
    outcome: Vec<TaskOutcome>,
    /// Tasks resolved Failed so far (direct + cascaded); termination is
    /// `done + n_failed == total` — failed jobs must still drain.
    n_failed: u64,
    /// Runtime-spawning state (`cfg.spawn`); staged ids pre-laid-out.
    spawn: SpawnState,
    /// Expanded task count (`spawn.total_len()`): every staged task
    /// eventually resolves — its spawner completes (it runs) or fails
    /// (the cascade dooms it) — so termination counts against the full
    /// expanded total, exactly like a pre-expanded run.
    total: u64,
}

impl Handler for World<'_> {
    type Ev = Ev;

    fn handle(&mut self, sim: &mut Sim<Ev>, ev: Ev) {
        match ev {
            Ev::Start(wid) => {
                self.workers[wid].started = sim.now();
                self.metrics.timeline.add(sim.now(), 1);
                poll(self, sim, wid);
            }
            Ev::Poll(wid) => poll(self, sim, wid),
            Ev::Exec { wid, task } => execute(self, sim, wid, task),
            Ev::Done { wid, task } => complete(self, sim, wid, task),
        }
    }
}

impl World<'_> {
    fn queue_op(&mut self, now: Time) -> Time {
        let per = secs(1.0 / self.cfg.numpywren.queue_ops_per_sec.max(1.0));
        let (_, end) = self.queue_srv.acquire(now, per);
        end + secs(self.cfg.numpywren.queue_op_s)
    }

    /// Task node, spawn-aware (staged ids resolve via the spawn state).
    fn node(&self, t: TaskId) -> TaskNode {
        if self.spawn.is_staged(t) {
            self.spawn.node(t)
        } else {
            *self.dag.task(t)
        }
    }

    fn compute_time(&self, t: TaskId) -> Time {
        let node = self.node(t);
        match node.dur_override {
            Some(d) => d + secs(self.cfg.compute.task_overhead_s),
            None => secs(
                node.flops / (self.cfg.lambda.gflops * 1e9)
                    + self.cfg.compute.task_overhead_s,
            ),
        }
    }
}

/// Worker polls the queue for work.
fn poll(w: &mut World<'_>, sim: &mut Sim<Ev>, wid: usize) {
    if w.done + w.n_failed == w.total {
        retire(w, sim, wid);
        return;
    }
    // The Lambda runtime ceiling: numpywren re-invokes expired executors.
    let age = sim.now().saturating_sub(w.workers[wid].started);
    if age >= w.lambda.max_runtime() {
        respawn(w, sim, wid);
        return;
    }
    let t_op = w.queue_op(sim.now());
    match w.queue.pop_front() {
        Some(task) => {
            sim.at(t_op, Ev::Exec { wid, task });
        }
        None => {
            let wait = secs(w.cfg.numpywren.poll_interval_s);
            sim.at(t_op + wait, Ev::Poll(wid));
        }
    }
}

/// A worker's execution attempt died (§3.6): the scheduler learns via
/// the queue service, re-enqueues the task while its retry budget lasts
/// (else reports the task — and its reachable set — failed), and the
/// platform replaces the crashed worker.
fn fail_attempt(w: &mut World<'_>, sim: &mut Sim<Ev>, wid: usize, t: TaskId) {
    let attempt = w.fail_count[t as usize];
    w.fail_count[t as usize] += 1;
    let t_op = w.queue_op(sim.now());
    w.metrics.breakdown.publish_s += to_secs(t_op - sim.now());
    if w.faults.plan().can_retry(attempt) {
        w.queue.push_back(t);
    } else {
        w.metrics.failed_executors += 1;
        let dag = w.dag;
        // Spawn-aware cascade: a failed task also dooms the staged
        // subtree it would have spawned (matching the pre-expanded run).
        w.n_failed += w.spawn.propagate_failures(dag, &[t], &mut w.outcome);
        if w.done + w.n_failed == w.total {
            w.finish = Some(t_op);
        }
    }
    respawn(w, sim, wid);
}

/// Stateless task execution: read everything, compute, write everything.
fn execute(w: &mut World<'_>, sim: &mut Sim<Ev>, wid: usize, t: TaskId) {
    w.attempts[t as usize] += 1;
    if w.faults.attempt_fails() {
        fail_attempt(w, sim, wid, t);
        return;
    }
    let dag = w.dag;
    let mut cursor = sim.now();
    let net_bw = w.cfg.lambda.net_bw;
    // Staged tasks read exactly one input — their spawner's output —
    // through a stack-local parent slice so the loop body is shared.
    let pbuf;
    let parents: &[TaskId] = if w.spawn.is_staged(t) {
        pbuf = [w.spawn.parent_of(t)];
        &pbuf
    } else {
        dag.parents(t)
    };
    for &p in parents {
        let bytes = w.node(p).out_bytes;
        let shard_end = w.kvs.read(cursor, TaskNode::obj_key(p), bytes);
        let (_, nic_end) = w.workers[wid]
            .nic
            .acquire(cursor, secs(bytes as f64 / net_bw));
        let end = shard_end.max(nic_end);
        w.metrics.breakdown.kvs_read_s += to_secs(end - cursor);
        let sd = secs(bytes as f64 / w.cfg.compute.serde_bw);
        w.metrics.breakdown.serde_s += to_secs(sd);
        cursor = end + sd;
    }
    let ext = w.node(t).input_bytes;
    if ext > 0 {
        let shard_end = w.kvs.read(cursor, TaskNode::input_key(t), ext);
        let (_, nic_end) = w.workers[wid]
            .nic
            .acquire(cursor, secs(ext as f64 / net_bw));
        let end = shard_end.max(nic_end);
        w.metrics.breakdown.kvs_read_s += to_secs(end - cursor);
        cursor = end + secs(ext as f64 / w.cfg.compute.serde_bw);
    }
    let d = w.compute_time(t);
    w.metrics.breakdown.execute_s += to_secs(d);
    cursor += d;
    // Write the full output back (statelessness).
    let out = w.node(t).out_bytes;
    let shard_end = w.kvs.write(cursor, TaskNode::obj_key(t), out);
    let (_, nic_end) = w.workers[wid]
        .nic
        .acquire(cursor, secs(out as f64 / net_bw));
    let end = shard_end.max(nic_end);
    w.metrics.breakdown.kvs_write_s += to_secs(end - cursor);
    cursor = end;
    sim.at(cursor, Ev::Done { wid, task: t });
}

fn complete(w: &mut World<'_>, sim: &mut Sim<Ev>, wid: usize, t: TaskId) {
    w.executed[t as usize] += 1;
    assert!(w.executed[t as usize] == 1, "task {t} executed twice");
    w.metrics.tasks_executed += 1;
    w.done += 1;
    // Scheduler-side dependency update (one queue op per completion).
    let t_op = w.queue_op(sim.now());
    w.metrics.breakdown.publish_s += to_secs(t_op - sim.now());
    let dag = w.dag;
    if !w.spawn.is_staged(t) {
        let (remaining, queue) = (&mut w.remaining, &mut w.queue);
        remaining.complete(dag, t, |c| queue.push_back(c));
    }
    // Runtime spawning: the completing task's spawned children enqueue
    // after its base children — the sealed DAG's child order, so the
    // queue contents match a pre-expanded run exactly.
    for c in w.spawn.spawned_children(t) {
        w.remaining.mark_ready(c);
        w.queue.push_back(c);
    }
    if w.done + w.n_failed == w.total {
        w.finish = Some(t_op);
    }
    sim.at(t_op, Ev::Poll(wid));
}

fn retire(w: &mut World<'_>, sim: &mut Sim<Ev>, wid: usize) {
    if std::mem::replace(&mut w.workers[wid].ended, true) {
        return;
    }
    let dur = to_secs(sim.now().saturating_sub(w.workers[wid].started));
    w.metrics.timeline.add(sim.now(), -1);
    w.metrics
        .billing
        .charge_lambda(w.cfg.lambda.memory_gb, dur.max(0.001));
    w.lambda.release();
}

fn respawn(w: &mut World<'_>, sim: &mut Sim<Ev>, wid: usize) {
    retire(w, sim, wid);
    let inv = w.lambda.invoke(sim.now());
    let nid = w.workers.len();
    w.workers.push(Worker {
        started: inv.start_at,
        nic: FifoResource::new(),
        ended: false,
    });
    w.metrics.executors_used += 1;
    sim.at(inv.start_at, Ev::Start(nid));
}

/// Run a numpywren job with an explicit worker count (the PyWren scaling
/// knob) — no `Config` clone on the per-run path.
pub fn run_numpywren_n(
    dag: &Dag,
    cfg: &Config,
    n_workers: usize,
    seed: u64,
) -> BaselineReport {
    let mut rng = Rng::new(seed);
    // Epoch open: freeze the spawn expansion and size per-task state to
    // the expanded count (what a pre-expanded run would allocate).
    let spawn = SpawnState::for_run(dag, cfg.spawn, seed);
    let n = spawn.total_len();
    let mut remaining = ReadyCounters::new(dag);
    remaining.grow_to(n, 1); // staged tasks: one parent (their spawner)
    let mut w = World {
        dag,
        kvs: KvsModel::with_crashes(cfg.storage, cfg.crashes, seed),
        queue_srv: FifoResource::new(),
        queue: dag.leaves().iter().copied().collect(),
        remaining,
        executed: vec![0; n],
        done: 0,
        workers: Vec::new(),
        lambda: LambdaService::new(cfg.lambda, rng.fork(1)),
        metrics: RunMetrics::default(),
        finish: None,
        faults: FaultStream::for_run(cfg.faults, seed),
        attempts: vec![0; n],
        fail_count: vec![0; n],
        outcome: vec![TaskOutcome::Completed; n],
        n_failed: 0,
        total: n as u64,
        spawn,
        cfg,
    };
    let mut sim: Sim<Ev> = cfg.sim.build();
    sim.set_event_budget(cfg.event_budget);

    // Provision the initial worker fleet through the invoker threads.
    let mut invokers = MultiResource::new(cfg.numpywren.n_invoker_threads);
    let per = secs(cfg.lambda.invoke_latency_s);
    for _ in 0..n_workers {
        let (_, end) = invokers.acquire(0, per);
        let inv = w.lambda.admit(end);
        let wid = w.workers.len();
        w.workers.push(Worker {
            started: inv.start_at,
            nic: FifoResource::new(),
            ended: false,
        });
        w.metrics.executors_used += 1;
        sim.at(inv.start_at, Ev::Start(wid));
    }
    sim.run(&mut w);

    let makespan = to_secs(w.finish.unwrap_or(sim.now()));
    w.metrics.makespan_s = makespan;
    w.metrics.per_task_exec = w.executed.clone();
    w.metrics.failed_tasks = w.n_failed;
    w.metrics.per_task_attempts = w.attempts.clone();
    w.metrics.per_task_outcome = w.outcome.clone();
    w.metrics.kvs = w.kvs.metrics;
    w.metrics.durability = w.kvs.durability;
    w.metrics.invocations = w.lambda.total_invocations();
    w.metrics.peak_concurrency = w.lambda.peak_active();
    w.metrics.cpu_seconds =
        w.metrics.timeline.integral_s() * w.lambda.vcpus_per_fn();
    let hours = makespan / 3600.0;
    // numpywren's S3 has no per-job cost here; single-Redis runs model an
    // ElastiCache-like node; count the scheduler VM either way.
    if cfg.storage.n_shards <= 2 {
        w.metrics.billing.charge_elasticache(cfg.storage.n_shards, hours);
    }
    w.metrics.billing.charge_scheduler_vm(hours);
    BaselineReport {
        metrics: w.metrics,
        sim_events: sim.processed(),
        peak_pending: sim.peak_pending(),
    }
}

/// Run a numpywren job with the configured worker count, with sim stats.
pub fn run_numpywren_full(dag: &Dag, cfg: &Config, seed: u64) -> BaselineReport {
    run_numpywren_n(dag, cfg, cfg.numpywren.n_workers, seed)
}

/// Run a numpywren job: `n_workers` stateless executors over the DAG.
pub fn run_numpywren(dag: &Dag, cfg: &Config, seed: u64) -> RunMetrics {
    run_numpywren_full(dag, cfg, seed).metrics
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::{DagBuilder, OpKind};
    use crate::workloads::micro;

    #[test]
    fn executes_all_tasks_exactly_once() {
        let dag = micro::serverless(20, secs(0.01));
        let mut cfg = Config::default();
        cfg.numpywren.n_workers = 4;
        let m = run_numpywren(&dag, &cfg, 1);
        assert_eq!(m.tasks_executed, 20);
    }

    #[test]
    fn stateless_design_reads_and_writes_everything() {
        let mut b = DagBuilder::new("chain");
        let a = b.task("a", OpKind::Generic, 1e6, 1000);
        let c = b.task("c", OpKind::Generic, 1e6, 1000);
        b.edge(a, c);
        let dag = b.build().unwrap();
        let mut cfg = Config::default();
        cfg.numpywren.n_workers = 2;
        let m = run_numpywren(&dag, &cfg, 2);
        // both outputs written; the intermediate read back
        assert_eq!(m.kvs.bytes_written, 2000);
        assert_eq!(m.kvs.bytes_read, 1000);
    }

    #[test]
    fn respects_dependencies() {
        let mut b = DagBuilder::new("fanin");
        let x = b.task("x", OpKind::Generic, 1e6, 100);
        let y = b.task("y", OpKind::Generic, 1e6, 100);
        let z = b.task("z", OpKind::Generic, 1e6, 100);
        b.edge(x, z).edge(y, z);
        let dag = b.build().unwrap();
        let mut cfg = Config::default();
        cfg.numpywren.n_workers = 3;
        let m = run_numpywren(&dag, &cfg, 3);
        assert_eq!(m.tasks_executed, 3);
    }

    #[test]
    fn deterministic() {
        let dag = micro::strong(100, 10, secs(0.01));
        let cfg = Config::default();
        let a = run_numpywren_full(&dag, &cfg, 9);
        let b = run_numpywren_full(&dag, &cfg, 9);
        assert_eq!(a.metrics.makespan_s, b.metrics.makespan_s);
        assert_eq!(a.sim_events, b.sim_events);
        assert_eq!(a.peak_pending, b.peak_pending);
    }

    #[test]
    fn shard_crashes_perturb_only_the_recovery_meters() {
        // numpywren is the KVS-heaviest engine (stateless: every
        // intermediate written + read back), so it is the strongest
        // unit-level check of time-decoupled recovery.
        let dag = micro::strong(50, 10, secs(0.01));
        let cfg = Config::default();
        let base = run_numpywren_full(&dag, &cfg, 9);
        let mut crashy_cfg = cfg.clone();
        crashy_cfg.crashes =
            crate::platform::faults::ShardCrashPlan::with_crashes(1.0, 3);
        let r = run_numpywren_full(&dag, &crashy_cfg, 9);
        assert_eq!(r.metrics.durability.recoveries, 3);
        assert_eq!(base.sim_events, r.sim_events);
        assert_eq!(base.metrics.makespan_s, r.metrics.makespan_s);
        assert_eq!(base.metrics.kvs, r.metrics.kvs);
        let mut scrubbed = r.metrics.clone();
        scrubbed.durability.recoveries = 0;
        scrubbed.durability.replayed_ops = 0;
        scrubbed.durability.stall_s = 0.0;
        assert_eq!(base.metrics, scrubbed);
        // Zero-rate plan: bit-identical, durability meters included.
        let mut zero_cfg = cfg.clone();
        zero_cfg.crashes =
            crate::platform::faults::ShardCrashPlan::with_crashes(0.0, 8);
        let z = run_numpywren_full(&dag, &zero_cfg, 9);
        assert_eq!(base.metrics, z.metrics);
        assert_eq!(base.sim_events, z.sim_events);
    }

    #[test]
    fn more_workers_do_not_break_small_jobs() {
        let dag = micro::serverless(5, secs(0.01));
        let mut cfg = Config::default();
        cfg.numpywren.n_workers = 50;
        let m = run_numpywren(&dag, &cfg, 4);
        assert_eq!(m.tasks_executed, 5);
    }

    #[test]
    fn worker_count_override_equals_configured_count() {
        let dag = micro::serverless(12, secs(0.01));
        let mut cfg = Config::default();
        cfg.numpywren.n_workers = 7;
        let a = run_numpywren_full(&dag, &cfg, 5);
        let b = run_numpywren_n(&dag, &Config::default(), 7, 5);
        assert_eq!(a.metrics.makespan_s, b.metrics.makespan_s);
        assert_eq!(a.sim_events, b.sim_events);
    }

    #[test]
    fn zero_rate_plan_is_bit_identical_to_fault_free() {
        use crate::platform::faults::FaultPlan;
        let dag = micro::strong(60, 6, secs(0.01));
        let mut zero = Config::default();
        zero.faults = FaultPlan::with_retries(0.0, 0);
        let a = run_numpywren_full(&dag, &Config::default(), 9);
        let b = run_numpywren_full(&dag, &zero, 9);
        assert_eq!(a.metrics, b.metrics);
        assert_eq!(a.sim_events, b.sim_events);
    }

    #[test]
    fn exhausted_budget_reports_every_task_failed() {
        use crate::platform::faults::FaultPlan;
        let dag = micro::serverless(8, secs(0.01));
        let mut cfg = Config::default();
        cfg.numpywren.n_workers = 3;
        cfg.faults = FaultPlan::with_retries(1.0, 0);
        let m = run_numpywren(&dag, &cfg, 6);
        assert_eq!(m.tasks_executed, 0);
        assert_eq!(m.failed_tasks, 8);
        assert_eq!(m.failed_executors, 8);
        assert!(m.per_task_attempts.iter().all(|&a| a == 1));
        assert!(m
            .per_task_outcome
            .iter()
            .all(|&o| o == TaskOutcome::Failed));
    }

    #[test]
    fn dynamic_spawning_matches_the_pre_expanded_dag() {
        use crate::dag::{pre_expand, SpawnPlan};
        let dag = micro::strong(24, 6, secs(0.01));
        let mut cfg = Config::default();
        cfg.numpywren.n_workers = 5;
        cfg.spawn = SpawnPlan::recursive(0.4, 3, 2);
        let dy = run_numpywren_full(&dag, &cfg, 13);
        let expanded = pre_expand(&dag, cfg.spawn, 13);
        let mut st_cfg = cfg.clone();
        st_cfg.spawn = SpawnPlan::default();
        let st = run_numpywren_full(&expanded, &st_cfg, 13);
        assert_eq!(dy.metrics, st.metrics);
        assert_eq!(dy.sim_events, st.sim_events);
        assert_eq!(dy.peak_pending, st.peak_pending);
        assert_eq!(dy.metrics.tasks_executed, expanded.len() as u64);
    }

    #[test]
    fn zero_rate_spawn_plan_is_bit_identical_to_plan_free() {
        use crate::dag::SpawnPlan;
        let dag = micro::strong(40, 8, secs(0.01));
        let base = run_numpywren_full(&dag, &Config::default(), 9);
        let mut cfg = Config::default();
        cfg.spawn = SpawnPlan::with_rate(0.0, 16);
        let r = run_numpywren_full(&dag, &cfg, 9);
        assert_eq!(base.metrics, r.metrics);
        assert_eq!(base.sim_events, r.sim_events);
    }

    #[test]
    fn fault_outcomes_partition_the_dag() {
        use crate::platform::faults::FaultPlan;
        let dag = micro::strong(40, 8, secs(0.01));
        let mut cfg = Config::default();
        cfg.numpywren.n_workers = 6;
        cfg.faults = FaultPlan::with_failure_rate(0.2);
        let m = run_numpywren(&dag, &cfg, 11);
        assert_eq!(m.tasks_executed + m.failed_tasks, dag.len() as u64);
        assert!(m.per_task_attempts.iter().all(|&a| a <= 3));
    }
}
