//! PyWren model — numpywren's execution substrate (§2.2, Figs. 2, 21).
//!
//! PyWren's centralized scheduler uses a fixed pool of invoker threads
//! (64 in the paper) issuing ~50 ms Lambda invocations serially per
//! thread, and its stateless executors pull tasks through the central
//! queue. `run_pywren` is the numpywren engine with worker count = the
//! scaling experiment's Lambda count (passed as an explicit override so
//! no `Config` is cloned on the run path); `pywren_launch_time` isolates
//! the fleet-scale-out time of Fig. 2.

use crate::config::Config;
use crate::dag::Dag;
use crate::metrics::RunMetrics;
use crate::sim::{secs, MultiResource};

use super::numpywren::run_numpywren_n;
use super::BaselineReport;

/// Run a (Num)PyWren scaling job with `n_workers` Lambda executors,
/// with sim stats.
pub fn run_pywren_full(
    dag: &Dag,
    cfg: &Config,
    n_workers: usize,
    seed: u64,
) -> BaselineReport {
    run_numpywren_n(dag, cfg, n_workers, seed)
}

/// Run a (Num)PyWren scaling job with `n_workers` Lambda executors.
pub fn run_pywren(dag: &Dag, cfg: &Config, n_workers: usize, seed: u64) -> RunMetrics {
    run_pywren_full(dag, cfg, n_workers, seed).metrics
}

/// Fig. 2: time (s) until all `n` Lambda executors have been invoked by
/// the scheduler's invoker-thread pool.
pub fn pywren_launch_time(cfg: &Config, n: usize) -> f64 {
    let mut pool = MultiResource::new(cfg.numpywren.n_invoker_threads);
    let per = secs(cfg.lambda.invoke_latency_s);
    let mut last = 0;
    for _ in 0..n {
        let (_, end) = pool.acquire(0, per);
        last = last.max(end);
    }
    crate::sim::to_secs(last)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::micro;

    #[test]
    fn launch_time_scales_linearly_past_pool_size() {
        let cfg = Config::default();
        let t64 = pywren_launch_time(&cfg, 64);
        let t6400 = pywren_launch_time(&cfg, 6400);
        assert!((t64 - 0.05).abs() < 1e-9);
        assert!((t6400 - 5.0).abs() < 1e-6); // 6400/64 × 50 ms
    }

    #[test]
    fn ten_thousand_lambdas_take_minutes_not_seconds() {
        // The paper: PyWren needs ~2 min to scale to 10k executors
        // (invocations + queue pulls); the pure launch time alone is ~8 s.
        let cfg = Config::default();
        let t = pywren_launch_time(&cfg, 10_000);
        assert!(t > 7.0 && t < 10.0, "launch={t}");
    }

    #[test]
    fn run_pywren_sets_worker_count() {
        let dag = micro::serverless(10, 0);
        let m = run_pywren(&dag, &Config::default(), 10, 1);
        assert_eq!(m.tasks_executed, 10);
        assert!(m.executors_used >= 10);
    }

    #[test]
    fn spawning_passes_through_to_the_numpywren_substrate() {
        use crate::dag::{pre_expand, SpawnPlan};
        use crate::sim::secs;
        let dag = micro::strong(18, 6, secs(0.01));
        let mut cfg = Config::default();
        cfg.spawn = SpawnPlan::recursive(0.5, 2, 2);
        let seed = 9;
        let dy = run_pywren_full(&dag, &cfg, 8, seed);

        let expanded = pre_expand(&dag, cfg.spawn, seed);
        assert!(expanded.len() > dag.len(), "plan must actually expand");
        let mut static_cfg = cfg;
        static_cfg.spawn = SpawnPlan::default();
        let st = run_pywren_full(&expanded, &static_cfg, 8, seed);

        assert_eq!(dy.metrics, st.metrics);
        assert_eq!(dy.sim_events, st.sim_events);
        assert_eq!(dy.metrics.tasks_executed, expanded.len() as u64);
    }
}
