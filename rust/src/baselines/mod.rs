//! Baseline engines the paper compares against (§4.1).
//!
//! * [`numpywren`] — central task queue + *stateless* Lambda executors:
//!   every task's inputs and outputs round-trip through the KVS (the
//!   locality anti-pattern Figs. 3–4 and 13–16 quantify).
//! * [`pywren`] — numpywren's substrate: the centralized scheduler with a
//!   fixed invoker-thread pool; used for the scaling comparisons
//!   (Figs. 2 and 21).
//! * [`dask`] — serverful Dask distributed: central scheduler over a VM
//!   worker pool with data-local assignment (the paper's Dask-125 /
//!   Dask-1000 configurations).
//!
//! Every baseline is simulator-backed; the `*_full` entry points expose
//! the DES meters (`sim_events`, `peak_pending`) that `wukong bench` and
//! the conformance determinism check consume, while the plain `run_*`
//! wrappers return only [`crate::metrics::RunMetrics`] for the figure
//! sweeps.

pub mod dask;
pub mod numpywren;
pub mod pywren;

/// A baseline run's normalized meters plus DES statistics (the shared
/// sim-report shape).
pub type BaselineReport = crate::metrics::SimReport;

pub use dask::{run_dask, run_dask_full};
pub use numpywren::{run_numpywren, run_numpywren_full, run_numpywren_n};
pub use pywren::{pywren_launch_time, run_pywren, run_pywren_full};
