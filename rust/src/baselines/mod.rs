//! Baseline engines the paper compares against (§4.1).
//!
//! * [`numpywren`] — central task queue + *stateless* Lambda executors:
//!   every task's inputs and outputs round-trip through the KVS (the
//!   locality anti-pattern Figs. 3–4 and 13–16 quantify).
//! * [`pywren`] — numpywren's substrate: the centralized scheduler with a
//!   fixed invoker-thread pool; used for the scaling comparisons
//!   (Figs. 2 and 21).
//! * [`dask`] — serverful Dask distributed: central scheduler over a VM
//!   worker pool with data-local assignment (the paper's Dask-125 /
//!   Dask-1000 configurations).

pub mod dask;
pub mod numpywren;
pub mod pywren;

pub use dask::run_dask;
pub use numpywren::run_numpywren;
pub use pywren::{pywren_launch_time, run_pywren};
