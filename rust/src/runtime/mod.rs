//! PJRT runtime: load + execute the AOT-compiled JAX/Pallas artifacts.
//!
//! Wraps the `xla` crate (`PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`). The
//! interchange format is HLO *text* because the bundled xla_extension
//! 0.5.1 rejects jax ≥ 0.5's 64-bit-id serialized protos (text parsing
//! reassigns ids). Executables are compiled once per op and cached; the
//! Rust request path never touches Python.

use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// A dense f32 tensor (row-major), the value type flowing through the
/// real engine.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor {
            shape,
            data: vec![0.0; n],
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn bytes(&self) -> u64 {
        (self.data.len() * 4) as u64
    }

    /// Serialize: shape rank + dims + payload (little-endian).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16 + self.data.len() * 4);
        out.extend_from_slice(&(self.shape.len() as u32).to_le_bytes());
        for d in &self.shape {
            out.extend_from_slice(&(*d as u32).to_le_bytes());
        }
        for x in &self.data {
            out.extend_from_slice(&x.to_le_bytes());
        }
        out
    }

    pub fn from_bytes(b: &[u8]) -> Result<Tensor> {
        if b.len() < 4 {
            bail!("tensor blob too short");
        }
        let rank = u32::from_le_bytes(b[0..4].try_into()?) as usize;
        let mut shape = Vec::with_capacity(rank);
        let mut off = 4;
        for _ in 0..rank {
            if b.len() < off + 4 {
                bail!("tensor blob truncated header");
            }
            shape.push(u32::from_le_bytes(b[off..off + 4].try_into()?) as usize);
            off += 4;
        }
        let n: usize = shape.iter().product();
        if b.len() != off + n * 4 {
            bail!("tensor blob size mismatch");
        }
        let data = b[off..]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Tensor { shape, data })
    }
}

/// Manifest entry for one AOT op.
#[derive(Debug, Clone)]
pub struct OpSpec {
    pub name: String,
    pub file: String,
    pub input_shapes: Vec<Vec<usize>>,
    pub output_shapes: Vec<Vec<usize>>,
    pub flops: u64,
}

/// The artifact registry + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    ops: BTreeMap<String, OpSpec>,
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

fn parse_shapes(v: &Json) -> Result<Vec<Vec<usize>>> {
    v.as_arr()
        .ok_or_else(|| anyhow!("expected array"))?
        .iter()
        .map(|e| {
            e.get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("missing shape"))
                .map(|dims| {
                    dims.iter()
                        .map(|d| d.as_u64().unwrap_or(0) as usize)
                        .collect()
                })
        })
        .collect()
}

impl Runtime {
    /// Load the manifest in `dir` (default `artifacts/`) and create the
    /// PJRT CPU client. Executables compile lazily on first use.
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "{} missing — run `make artifacts` first",
                manifest_path.display()
            )
        })?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;
        let mut ops = BTreeMap::new();
        for (name, entry) in j
            .get("ops")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest has no ops"))?
        {
            ops.insert(
                name.clone(),
                OpSpec {
                    name: name.clone(),
                    file: entry
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("{name}: no file"))?
                        .to_string(),
                    input_shapes: parse_shapes(
                        entry.get("inputs").ok_or_else(|| anyhow!("inputs"))?,
                    )?,
                    output_shapes: parse_shapes(
                        entry.get("outputs").ok_or_else(|| anyhow!("outputs"))?,
                    )?,
                    flops: entry
                        .get("flops")
                        .and_then(Json::as_u64)
                        .unwrap_or(0),
                },
            );
        }
        Ok(Runtime {
            client: xla::PjRtClient::cpu()?,
            dir: dir.to_path_buf(),
            ops,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Known op names.
    pub fn op_names(&self) -> Vec<&str> {
        self.ops.keys().map(String::as_str).collect()
    }

    pub fn spec(&self, op: &str) -> Option<&OpSpec> {
        self.ops.get(op)
    }

    /// Compile (or fetch from cache) the executable for `op`.
    fn executable(&self, op: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.lock().unwrap().get(op) {
            return Ok(Arc::clone(e));
        }
        let spec = self
            .ops
            .get(op)
            .ok_or_else(|| anyhow!("unknown op {op:?}"))?;
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(self.client.compile(&comp)?);
        self.cache
            .lock()
            .unwrap()
            .insert(op.to_string(), Arc::clone(&exe));
        Ok(exe)
    }

    /// Eagerly compile every artifact (startup warmup; keeps compilation
    /// off the request path).
    pub fn warmup(&self) -> Result<()> {
        let names: Vec<String> = self.ops.keys().cloned().collect();
        for n in names {
            self.executable(&n)?;
        }
        Ok(())
    }

    /// Execute `op` on the given inputs; returns the output tensors.
    pub fn execute(&self, op: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let spec = self
            .ops
            .get(op)
            .ok_or_else(|| anyhow!("unknown op {op:?}"))?
            .clone();
        if inputs.len() != spec.input_shapes.len() {
            bail!(
                "{op}: expected {} inputs, got {}",
                spec.input_shapes.len(),
                inputs.len()
            );
        }
        for (i, (t, want)) in inputs.iter().zip(&spec.input_shapes).enumerate() {
            if &t.shape != want {
                bail!("{op}: input {i} shape {:?} != {:?}", t.shape, want);
            }
        }
        let exe = self.executable(op)?;
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(&t.data)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape: {e:?}"))
            })
            .collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = result.to_tuple()?;
        if parts.len() != spec.output_shapes.len() {
            bail!(
                "{op}: expected {} outputs, got {}",
                spec.output_shapes.len(),
                parts.len()
            );
        }
        parts
            .into_iter()
            .zip(&spec.output_shapes)
            .map(|(lit, shape)| {
                let data = lit.to_vec::<f32>()?;
                Ok(Tensor::new(shape.clone(), data))
            })
            .collect()
    }
}

/// Thread-safe runtime handle for the real engine.
///
/// The `xla` crate's PJRT client is `Rc`-based (single-threaded FFI); all
/// access is serialized behind one mutex and no xla type ever escapes the
/// lock (inputs/outputs cross as plain [`Tensor`]s), which makes the
/// `Send`/`Sync` assertion sound. The PJRT CPU client parallelizes each
/// executable internally, so serialized dispatch still uses the machine.
pub struct SharedRuntime(Mutex<Runtime>);

// SAFETY: the inner Runtime (and its Rc-based FFI handles) is only ever
// touched while holding the mutex, and no Rc/raw-pointer value crosses the
// lock boundary.
unsafe impl Send for SharedRuntime {}
unsafe impl Sync for SharedRuntime {}

impl SharedRuntime {
    /// Load + wrap (see [`Runtime::load`]).
    pub fn load(dir: &Path) -> Result<Arc<SharedRuntime>> {
        Ok(Arc::new(SharedRuntime(Mutex::new(Runtime::load(dir)?))))
    }

    /// Load from [`default_artifact_dir`], or `None` when the artifact
    /// manifest or the PJRT backend is unavailable — the single
    /// availability gate used by the real-engine trait adapters and the
    /// artifact-dependent tests (which skip with a message on `None`).
    pub fn try_load_default() -> Option<Arc<SharedRuntime>> {
        if !artifacts_available() {
            return None;
        }
        SharedRuntime::load(&default_artifact_dir()).ok()
    }

    /// Execute an op (serialized; PJRT parallelizes internally).
    pub fn execute(&self, op: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.0.lock().unwrap().execute(op, inputs)
    }

    /// Pre-compile every artifact.
    pub fn warmup(&self) -> Result<()> {
        self.0.lock().unwrap().warmup()
    }

    pub fn op_names(&self) -> Vec<String> {
        self.0
            .lock()
            .unwrap()
            .op_names()
            .iter()
            .map(|s| s.to_string())
            .collect()
    }

    pub fn flops(&self, op: &str) -> Option<u64> {
        self.0.lock().unwrap().spec(op).map(|s| s.flops)
    }
}

/// Default artifact directory (env `WUKONG_ARTIFACTS` overrides).
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("WUKONG_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// Whether the AOT artifact manifest is present. Real-engine tests and
/// the real-engine trait adapters skip cleanly when it is not (run
/// `make artifacts` to produce it).
pub fn artifacts_available() -> bool {
    default_artifact_dir().join("manifest.json").is_file()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_serde_roundtrip() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = t.to_bytes();
        assert_eq!(Tensor::from_bytes(&b).unwrap(), t);
    }

    #[test]
    fn tensor_rejects_corrupt_blob() {
        assert!(Tensor::from_bytes(&[1, 2]).is_err());
        let t = Tensor::new(vec![4], vec![0.0; 4]);
        let mut b = t.to_bytes();
        b.pop();
        assert!(Tensor::from_bytes(&b).is_err());
    }

    #[test]
    fn tensor_shape_product_enforced() {
        assert!(std::panic::catch_unwind(|| {
            Tensor::new(vec![2, 2], vec![0.0; 3])
        })
        .is_err());
    }

    // Full execute() coverage lives in rust/tests/ (requires artifacts).
}
