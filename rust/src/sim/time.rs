//! Virtual time: `u64` microseconds (integral ⇒ deterministic ordering).

/// Virtual timestamp / duration in microseconds.
pub type Time = u64;

/// Microseconds per second.
pub const MICROS_PER_SEC: Time = 1_000_000;

/// Convert seconds (f64) to virtual time, saturating and rounding.
pub fn secs(s: f64) -> Time {
    if s <= 0.0 {
        0
    } else {
        (s * MICROS_PER_SEC as f64).round() as Time
    }
}

/// Convert virtual time to seconds.
pub fn to_secs(t: Time) -> f64 {
    t as f64 / MICROS_PER_SEC as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        assert_eq!(secs(1.5), 1_500_000);
        assert!((to_secs(secs(0.25)) - 0.25).abs() < 1e-9);
    }

    #[test]
    fn negative_clamps_to_zero() {
        assert_eq!(secs(-1.0), 0);
    }
}
