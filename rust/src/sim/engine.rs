//! The event calendar: closures scheduled at virtual times.
//!
//! `Sim<W>` is generic over a world type `W` holding all entity state
//! (executors, storage shards, schedulers, metrics). Events are
//! `FnOnce(&mut W, &mut Sim<W>)`; an event may mutate the world and
//! schedule further events. Ties in time are broken by insertion order
//! (monotone sequence number), which makes runs bit-reproducible.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::time::Time;

struct Entry<W> {
    t: Time,
    seq: u64,
    f: Box<dyn FnOnce(&mut W, &mut Sim<W>)>,
}

impl<W> PartialEq for Entry<W> {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl<W> Eq for Entry<W> {}
impl<W> PartialOrd for Entry<W> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<W> Ord for Entry<W> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first.
        other
            .t
            .cmp(&self.t)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Discrete-event simulator over world `W`.
pub struct Sim<W> {
    now: Time,
    seq: u64,
    processed: u64,
    heap: BinaryHeap<Entry<W>>,
}

impl<W> Default for Sim<W> {
    fn default() -> Self {
        Self::new()
    }
}

impl<W> Sim<W> {
    pub fn new() -> Sim<W> {
        Sim {
            now: 0,
            seq: 0,
            processed: 0,
            heap: BinaryHeap::new(),
        }
    }

    /// Current virtual time.
    pub fn now(&self) -> Time {
        self.now
    }

    /// Total events processed so far (L3 perf metric: events/sec).
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Pending event count.
    pub fn pending(&self) -> usize {
        self.heap.len()
    }

    /// Schedule `f` at absolute time `t` (clamped to `now`).
    pub fn at(&mut self, t: Time, f: impl FnOnce(&mut W, &mut Sim<W>) + 'static) {
        let t = t.max(self.now);
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry {
            t,
            seq,
            f: Box::new(f),
        });
    }

    /// Schedule `f` after a delay of `dt`.
    pub fn after(
        &mut self,
        dt: Time,
        f: impl FnOnce(&mut W, &mut Sim<W>) + 'static,
    ) {
        self.at(self.now.saturating_add(dt), f);
    }

    /// Run until the calendar drains. Returns the final time.
    pub fn run(&mut self, world: &mut W) -> Time {
        while let Some(e) = self.heap.pop() {
            debug_assert!(e.t >= self.now, "time went backwards");
            self.now = e.t;
            self.processed += 1;
            (e.f)(world, self);
        }
        self.now
    }

    /// Run until `deadline` (events at exactly `deadline` included) or the
    /// calendar drains, whichever first.
    pub fn run_until(&mut self, world: &mut W, deadline: Time) -> Time {
        while let Some(top) = self.heap.peek() {
            if top.t > deadline {
                break;
            }
            let e = self.heap.pop().unwrap();
            self.now = e.t;
            self.processed += 1;
            (e.f)(world, self);
        }
        self.now = self.now.max(deadline.min(self.now.max(deadline)));
        self.now
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Default)]
    struct World {
        log: Vec<(Time, u32)>,
    }

    #[test]
    fn events_fire_in_time_order() {
        let mut sim: Sim<World> = Sim::new();
        let mut w = World::default();
        sim.at(30, |w, s| w.log.push((s.now(), 3)));
        sim.at(10, |w, s| w.log.push((s.now(), 1)));
        sim.at(20, |w, s| w.log.push((s.now(), 2)));
        sim.run(&mut w);
        assert_eq!(w.log, vec![(10, 1), (20, 2), (30, 3)]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut sim: Sim<World> = Sim::new();
        let mut w = World::default();
        for i in 0..10 {
            sim.at(5, move |w, _| w.log.push((5, i)));
        }
        sim.run(&mut w);
        let order: Vec<u32> = w.log.iter().map(|&(_, i)| i).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn events_can_schedule_events() {
        let mut sim: Sim<World> = Sim::new();
        let mut w = World::default();
        sim.at(1, |_, s| {
            s.after(9, |w: &mut World, s: &mut Sim<World>| {
                w.log.push((s.now(), 99))
            });
        });
        let end = sim.run(&mut w);
        assert_eq!(end, 10);
        assert_eq!(w.log, vec![(10, 99)]);
    }

    #[test]
    fn past_times_clamp_to_now() {
        let mut sim: Sim<World> = Sim::new();
        let mut w = World::default();
        sim.at(100, |w, s| {
            s.at(50, |w: &mut World, s: &mut Sim<World>| {
                w.log.push((s.now(), 1))
            });
            w.log.push((s.now(), 0));
        });
        sim.run(&mut w);
        assert_eq!(w.log, vec![(100, 0), (100, 1)]);
    }

    #[test]
    fn run_until_stops_at_deadline() {
        let mut sim: Sim<World> = Sim::new();
        let mut w = World::default();
        sim.at(10, |w, _| w.log.push((10, 1)));
        sim.at(20, |w, _| w.log.push((20, 2)));
        sim.run_until(&mut w, 15);
        assert_eq!(w.log, vec![(10, 1)]);
        assert_eq!(sim.pending(), 1);
    }

    #[test]
    fn processed_counts_events() {
        let mut sim: Sim<World> = Sim::new();
        let mut w = World::default();
        for i in 0..100 {
            sim.at(i, |_, _| {});
        }
        sim.run(&mut w);
        assert_eq!(sim.processed(), 100);
    }
}
