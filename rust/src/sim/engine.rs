//! The event calendar: typed events scheduled at virtual times.
//!
//! `Sim<E>` is a discrete-event calendar over a *typed* event payload `E`
//! (each engine defines its own small enum). Events are dispatched
//! through the [`Handler`] trait implemented by the engine's world, so
//! the hot loop moves plain enum values instead of boxing one heap
//! closure per event — the allocation that capped the old calendar well
//! below the million-events/sec regimes `wukong bench` sweeps. Ties in
//! time are broken by insertion order (monotone sequence number), which
//! keeps runs bit-reproducible under `wukong verify`.
//!
//! Since PR 9 the priority structure underneath is pluggable
//! ([`CalendarKind`], see `sim::calendar`): the default is a bucketed
//! calendar queue with O(1) steady-state enqueue/dequeue; the PR-2
//! binary heap remains selectable (`--set sim.calendar=heap`) as the
//! differential reference. The `seq` tie-breaker lives *here*, not in
//! the calendar, so both structures see the identical total order.

use super::calendar::{
    BucketCalendar, Calendar, CalendarKind, HeapCalendar,
};
use super::time::Time;

/// Event dispatch: the world interprets each typed event, mutating
/// itself and scheduling further events.
pub trait Handler {
    /// The event payload this world understands.
    type Ev;

    /// Handle one event at the calendar's current time (`sim.now()`).
    fn handle(&mut self, sim: &mut Sim<Self::Ev>, ev: Self::Ev);
}

/// Runtime-selected priority structure (enum dispatch keeps `Sim<E>`'s
/// public type unchanged — no generics ripple through `Handler`).
enum CalendarImpl<E> {
    Heap(HeapCalendar<E>),
    Bucket(BucketCalendar<E>),
}

impl<E> CalendarImpl<E> {
    fn push(&mut self, t: Time, seq: u64, ev: E) {
        match self {
            CalendarImpl::Heap(c) => c.push(t, seq, ev),
            CalendarImpl::Bucket(c) => c.push(t, seq, ev),
        }
    }

    fn pop(&mut self) -> Option<(Time, E)> {
        match self {
            CalendarImpl::Heap(c) => c.pop(),
            CalendarImpl::Bucket(c) => c.pop(),
        }
        .map(|e| (e.t, e.ev))
    }

    fn next_time(&mut self) -> Option<Time> {
        match self {
            CalendarImpl::Heap(c) => c.next_time(),
            CalendarImpl::Bucket(c) => c.next_time(),
        }
    }

    fn len(&self) -> usize {
        match self {
            CalendarImpl::Heap(c) => c.len(),
            CalendarImpl::Bucket(c) => c.len(),
        }
    }
}

/// Discrete-event simulator over typed events `E`.
pub struct Sim<E> {
    now: Time,
    seq: u64,
    processed: u64,
    peak_pending: usize,
    event_budget: u64,
    cal: CalendarImpl<E>,
}

impl<E> Default for Sim<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Sim<E> {
    /// Default calendar: bucketed queue, auto-sized bucket width.
    pub fn new() -> Sim<E> {
        Self::with_calendar(CalendarKind::default(), 0)
    }

    /// Pick the priority structure explicitly. `bucket_width_us` pins
    /// the bucket width (0 = auto-size; ignored by the heap). Engines
    /// reach this through `Config::sim` (`SimConfig::build`).
    pub fn with_calendar(kind: CalendarKind, bucket_width_us: Time) -> Sim<E> {
        let cal = match kind {
            CalendarKind::Heap => CalendarImpl::Heap(HeapCalendar::new()),
            CalendarKind::Bucket => CalendarImpl::Bucket(BucketCalendar::new(
                if bucket_width_us == 0 {
                    None
                } else {
                    Some(bucket_width_us)
                },
            )),
        };
        Sim {
            now: 0,
            seq: 0,
            processed: 0,
            peak_pending: 0,
            event_budget: 0,
            cal,
        }
    }

    /// Watchdog: cap the number of events this calendar may process
    /// (0 = unlimited, the default). Exceeding the budget panics, which
    /// `wukong verify` catches and reports as a violation — a livelocked
    /// engine (e.g. a recovery bug rescheduling itself forever) fails
    /// fast instead of hanging CI.
    pub fn set_event_budget(&mut self, budget: u64) {
        self.event_budget = budget;
    }

    /// Current virtual time.
    pub fn now(&self) -> Time {
        self.now
    }

    /// Total events processed so far (L3 perf metric: events/sec).
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Pending event count.
    pub fn pending(&self) -> usize {
        self.cal.len()
    }

    /// High-water mark of the pending-event count (calendar depth):
    /// `wukong bench` reports this as the run's memory-pressure proxy.
    pub fn peak_pending(&self) -> usize {
        self.peak_pending
    }

    /// Schedule `ev` at absolute time `t` (clamped to `now`).
    pub fn at(&mut self, t: Time, ev: E) {
        let t = t.max(self.now);
        let seq = self.seq;
        self.seq += 1;
        self.cal.push(t, seq, ev);
        if self.cal.len() > self.peak_pending {
            self.peak_pending = self.cal.len();
        }
    }

    /// Schedule `ev` after a delay of `dt`.
    pub fn after(&mut self, dt: Time, ev: E) {
        self.at(self.now.saturating_add(dt), ev);
    }

    /// Panic if the event budget is set and already spent (called
    /// before processing the next event).
    fn charge_budget(&self) {
        if self.event_budget != 0 && self.processed >= self.event_budget {
            panic!(
                "sim event budget exceeded ({} events): livelocked engine?",
                self.event_budget
            );
        }
    }

    /// Run until the calendar drains. Returns the final time.
    pub fn run<W: Handler<Ev = E>>(&mut self, world: &mut W) -> Time {
        while let Some((t, ev)) = self.cal.pop() {
            debug_assert!(t >= self.now, "time went backwards");
            self.charge_budget();
            self.now = t;
            self.processed += 1;
            world.handle(self, ev);
        }
        self.now
    }

    /// Run until `deadline` (events at exactly `deadline` included) or the
    /// calendar drains, whichever first. `now` always ends at `deadline`
    /// (time passes even when the calendar drains early).
    pub fn run_until<W: Handler<Ev = E>>(
        &mut self,
        world: &mut W,
        deadline: Time,
    ) -> Time {
        while let Some(top) = self.cal.next_time() {
            if top > deadline {
                break;
            }
            let (t, ev) = self.cal.pop().unwrap();
            self.charge_budget();
            self.now = t;
            self.processed += 1;
            world.handle(self, ev);
        }
        self.now = self.now.max(deadline);
        self.now
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Default)]
    struct World {
        log: Vec<(Time, u32)>,
    }

    enum Ev {
        /// Append `(now, i)` to the log.
        Log(u32),
        /// Schedule `Log(99)` nine ticks later.
        Chain,
        /// Schedule `Log(1)` in the past (t=50) and log a 0 now.
        PastClamp,
        /// Do nothing.
        Nop,
    }

    impl Handler for World {
        type Ev = Ev;

        fn handle(&mut self, sim: &mut Sim<Ev>, ev: Ev) {
            match ev {
                Ev::Log(i) => self.log.push((sim.now(), i)),
                Ev::Chain => sim.after(9, Ev::Log(99)),
                Ev::PastClamp => {
                    sim.at(50, Ev::Log(1));
                    self.log.push((sim.now(), 0));
                }
                Ev::Nop => {}
            }
        }
    }

    /// Both calendar kinds, so every semantic test below pins the heap
    /// and the bucket queue to identical behavior.
    fn both() -> [Sim<Ev>; 2] {
        [
            Sim::with_calendar(CalendarKind::Bucket, 0),
            Sim::with_calendar(CalendarKind::Heap, 0),
        ]
    }

    #[test]
    fn events_fire_in_time_order() {
        for mut sim in both() {
            let mut w = World::default();
            sim.at(30, Ev::Log(3));
            sim.at(10, Ev::Log(1));
            sim.at(20, Ev::Log(2));
            sim.run(&mut w);
            assert_eq!(w.log, vec![(10, 1), (20, 2), (30, 3)]);
        }
    }

    #[test]
    fn ties_break_by_insertion_order() {
        for mut sim in both() {
            let mut w = World::default();
            for i in 0..10 {
                sim.at(5, Ev::Log(i));
            }
            sim.run(&mut w);
            let order: Vec<u32> = w.log.iter().map(|&(_, i)| i).collect();
            assert_eq!(order, (0..10).collect::<Vec<_>>());
        }
    }

    #[test]
    fn events_can_schedule_events() {
        for mut sim in both() {
            let mut w = World::default();
            sim.at(1, Ev::Chain);
            let end = sim.run(&mut w);
            assert_eq!(end, 10);
            assert_eq!(w.log, vec![(10, 99)]);
        }
    }

    #[test]
    fn past_times_clamp_to_now() {
        for mut sim in both() {
            let mut w = World::default();
            sim.at(100, Ev::PastClamp);
            sim.run(&mut w);
            assert_eq!(w.log, vec![(100, 0), (100, 1)]);
        }
    }

    #[test]
    fn run_until_stops_at_deadline() {
        for mut sim in both() {
            let mut w = World::default();
            sim.at(10, Ev::Log(1));
            sim.at(20, Ev::Log(2));
            sim.run_until(&mut w, 15);
            assert_eq!(w.log, vec![(10, 1)]);
            assert_eq!(sim.pending(), 1);
            assert_eq!(sim.now(), 15);
        }
    }

    #[test]
    fn run_until_advances_now_when_calendar_drains_early() {
        // Pins the end-time semantics: `now` always lands on the
        // deadline when the calendar drains early. (The previous
        // `self.now.max(deadline.min(self.now.max(deadline)))` was
        // equivalent but obfuscated enough that the semantics had no
        // test; this guards the simplified `self.now.max(deadline)`.)
        let mut sim: Sim<Ev> = Sim::new();
        let mut w = World::default();
        sim.at(10, Ev::Log(1));
        let end = sim.run_until(&mut w, 100);
        assert_eq!(end, 100);
        assert_eq!(sim.now(), 100);
        assert_eq!(w.log, vec![(10, 1)]);
        // Also on a completely empty calendar.
        let mut empty: Sim<Ev> = Sim::new();
        assert_eq!(empty.run_until(&mut w, 7), 7);
    }

    #[test]
    fn processed_counts_events() {
        for mut sim in both() {
            let mut w = World::default();
            for i in 0..100 {
                sim.at(i, Ev::Nop);
            }
            sim.run(&mut w);
            assert_eq!(sim.processed(), 100);
        }
    }

    #[test]
    fn event_budget_panics_on_livelock() {
        for mut sim in both() {
            sim.set_event_budget(50);
            // Stand-in for a livelock: more events than the budget allows.
            for i in 0..100 {
                sim.at(i, Ev::Nop);
            }
            let mut w = World::default();
            let err =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    sim.run(&mut w);
                }))
                .unwrap_err();
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_default();
            assert!(
                msg.contains("sim event budget exceeded (50 events)"),
                "{msg}"
            );
        }
    }

    #[test]
    fn event_budget_zero_is_unlimited_and_exact_budget_passes() {
        for [mut sim_a, mut sim_b] in [both()] {
            let mut w = World::default();
            sim_a.set_event_budget(0);
            for i in 0..100 {
                sim_a.at(i, Ev::Nop);
            }
            sim_a.run(&mut w);
            assert_eq!(sim_a.processed(), 100);
            // Exactly-at-budget drains cleanly: the cap is on *exceeding*.
            sim_b.set_event_budget(100);
            for i in 0..100 {
                sim_b.at(i, Ev::Nop);
            }
            sim_b.run(&mut w);
            assert_eq!(sim_b.processed(), 100);
        }
    }

    #[test]
    fn peak_pending_tracks_calendar_depth() {
        for mut sim in both() {
            let mut w = World::default();
            for i in 0..42 {
                sim.at(i, Ev::Nop);
            }
            assert_eq!(sim.peak_pending(), 42);
            sim.run(&mut w);
            assert_eq!(sim.pending(), 0);
            assert_eq!(sim.peak_pending(), 42); // high-water mark survives
        }
    }

    #[test]
    fn default_calendar_is_the_bucket_queue() {
        let sim: Sim<Ev> = Sim::new();
        assert!(matches!(sim.cal, CalendarImpl::Bucket(_)));
        assert_eq!(CalendarKind::default(), CalendarKind::Bucket);
    }

    #[test]
    fn pinned_bucket_width_runs_identically() {
        // The `sim.bucket_width_us` knob changes geometry, never order.
        let mut auto: Sim<Ev> = Sim::with_calendar(CalendarKind::Bucket, 0);
        let mut pinned: Sim<Ev> = Sim::with_calendar(CalendarKind::Bucket, 3);
        let mut wa = World::default();
        let mut wp = World::default();
        for sim in [&mut auto, &mut pinned] {
            for i in 0..500u64 {
                sim.at((i * 7919) % 1000, Ev::Log(i as u32));
            }
        }
        assert_eq!(auto.run(&mut wa), pinned.run(&mut wp));
        assert_eq!(wa.log, wp.log);
        assert_eq!(auto.peak_pending(), pinned.peak_pending());
    }
}
