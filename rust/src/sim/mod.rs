//! Deterministic discrete-event simulation (DES) substrate.
//!
//! Every paper figure is regenerated on this simulator: virtual time in
//! microseconds, a typed-event calendar with FIFO tie-breaking (no
//! per-event allocation — see [`engine::Handler`]), and queueing-resource
//! helpers used to model KVS shards, NICs, invoker pools and Dask worker
//! cores. Determinism contract: same seed + same config ⇒ identical
//! event trace (tested in `rust/tests/`).

pub mod engine;
pub mod resource;
pub mod time;

pub use engine::{Handler, Sim};
pub use resource::{FifoResource, MultiResource};
pub use time::{secs, to_secs, Time, MICROS_PER_SEC};
