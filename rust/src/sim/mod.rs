//! Deterministic discrete-event simulation (DES) substrate.
//!
//! Every paper figure is regenerated on this simulator: virtual time in
//! microseconds, a typed-event calendar with FIFO tie-breaking (no
//! per-event allocation — see [`engine::Handler`]), and queueing-resource
//! helpers used to model KVS shards, NICs, invoker pools and Dask worker
//! cores. The priority structure under the calendar is runtime-selected
//! ([`calendar::CalendarKind`]): a bucketed calendar queue by default,
//! the PR-2 binary heap as the differential reference. Determinism
//! contract: same seed + same config ⇒ identical event trace (tested in
//! `rust/tests/`, incl. the heap-vs-bucket suite in `tests/calendar.rs`).

pub mod calendar;
pub mod engine;
pub mod resource;
pub mod scratch;
pub mod time;

pub use calendar::{BucketCalendar, Calendar, CalendarKind, HeapCalendar};
pub use engine::{Handler, Sim};
pub use resource::{FifoResource, MultiResource};
pub use scratch::{ReadyCounters, TaskScratch, TaskSlot};
pub use time::{secs, to_secs, Time, MICROS_PER_SEC};
