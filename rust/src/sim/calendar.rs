//! Priority structures behind the event calendar.
//!
//! Two interchangeable implementations of the same total order —
//! earliest `(t, seq)` pops first, so FIFO within a timestamp:
//!
//! * [`HeapCalendar`]: the PR-2 binary heap. O(log n) per op, kept as
//!   the reference half of the differential calendar test suite and
//!   selectable at runtime via `--set sim.calendar=heap`.
//! * [`BucketCalendar`]: a bucketed calendar queue (Brown '88 shape).
//!   Events inside the current "year" window land in per-bucket
//!   min-heaps indexed by `(t - year_start) / width`; events beyond it
//!   wait in an overflow heap. Steady-state enqueue/dequeue touch one
//!   small bucket instead of one log-depth heap. The year geometry
//!   (bucket count + width) is re-planned deterministically from the
//!   observed backlog whenever the window drains or overloads, so the
//!   structure adapts to clustered, uniform and far-future schedules
//!   without tuning. `sim.bucket_width_us` pins the width (0 = auto).
//!
//! Because `seq` is unique per entry, `(t, seq)` is a *total* order:
//! any structure that pops its global minimum reproduces the heap's pop
//! sequence exactly. The bucket queue pops the minimum because bucket
//! time ranges are disjoint and scanned in order, every in-window
//! event precedes every overflow event, and ties inside one bucket are
//! resolved by the same `Entry` ordering the heap uses. That argument
//! is what keeps every byte-identical determinism gate intact; the
//! differential suite in `rust/tests/calendar.rs` checks it anyway.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::time::Time;

/// Which calendar implementation a `Sim` run uses.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum CalendarKind {
    /// Bucketed calendar queue (the default since PR 9).
    #[default]
    Bucket,
    /// Binary heap (the PR-2 structure; differential reference).
    Heap,
}

/// One scheduled event.
pub struct Entry<E> {
    pub t: Time,
    pub seq: u64,
    pub ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first.
        other
            .t
            .cmp(&self.t)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The calendar contract `Sim<E>` runs on. `next_time` takes `&mut`
/// because the bucket queue may re-anchor its year window to find the
/// minimum (a structural but order-preserving change).
pub trait Calendar<E> {
    /// Insert an event. `seq` must be unique (the tie-breaker).
    fn push(&mut self, t: Time, seq: u64, ev: E);
    /// Remove and return the earliest `(t, seq)` event.
    fn pop(&mut self) -> Option<Entry<E>>;
    /// Timestamp of the earliest pending event.
    fn next_time(&mut self) -> Option<Time>;
    /// Pending event count.
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The PR-2 binary-heap calendar (reference implementation).
#[derive(Default)]
pub struct HeapCalendar<E> {
    heap: BinaryHeap<Entry<E>>,
}

impl<E> HeapCalendar<E> {
    pub fn new() -> HeapCalendar<E> {
        HeapCalendar {
            heap: BinaryHeap::new(),
        }
    }
}

impl<E> Calendar<E> for HeapCalendar<E> {
    fn push(&mut self, t: Time, seq: u64, ev: E) {
        self.heap.push(Entry { t, seq, ev });
    }

    fn pop(&mut self) -> Option<Entry<E>> {
        self.heap.pop()
    }

    fn next_time(&mut self) -> Option<Time> {
        self.heap.peek().map(|e| e.t)
    }

    fn len(&self) -> usize {
        self.heap.len()
    }
}

/// Smallest year window, in buckets.
const MIN_BUCKETS: usize = 8;
/// Largest year window, in buckets (bounds rebuild cost and memory).
const MAX_BUCKETS: usize = 1 << 16;
/// Grow the window when the in-year population exceeds this many
/// events per bucket on average.
const OVERLOAD_FACTOR: usize = 8;

/// Bucketed calendar queue. See the module docs for the ordering
/// argument; every mutation below preserves three invariants:
///
/// 1. every in-year entry `e` satisfies
///    `(e.t - year_start) / width == its bucket index`,
/// 2. every overflow entry maps past the last bucket,
/// 3. no non-empty bucket lies before `cursor`.
pub struct BucketCalendar<E> {
    buckets: Vec<BinaryHeap<Entry<E>>>,
    /// Bucket width in µs (≥ 1).
    width: Time,
    /// `Some` pins the width (`sim.bucket_width_us`); `None` = auto.
    fixed_width: Option<Time>,
    /// Virtual time mapped to bucket 0.
    year_start: Time,
    /// First bucket that may be non-empty.
    cursor: usize,
    /// Events mapping beyond the year window, pending redistribution.
    overflow: BinaryHeap<Entry<E>>,
    /// Total pending events (buckets + overflow).
    len: usize,
    /// Pending events inside the bucket window.
    in_year: usize,
    /// High-water mark of any pushed timestamp (width heuristic).
    max_t: Time,
}

impl<E> BucketCalendar<E> {
    /// `fixed_width`: `Some(w)` pins the bucket width to `w` µs
    /// (clamped to ≥ 1); `None` auto-sizes it from the observed
    /// event-time spread at each year re-plan.
    pub fn new(fixed_width: Option<Time>) -> BucketCalendar<E> {
        let fixed_width = fixed_width.map(|w| w.max(1));
        BucketCalendar {
            buckets: std::iter::repeat_with(BinaryHeap::new)
                .take(MIN_BUCKETS)
                .collect(),
            width: fixed_width.unwrap_or(1),
            fixed_width,
            year_start: 0,
            cursor: 0,
            overflow: BinaryHeap::new(),
            len: 0,
            in_year: 0,
            max_t: 0,
        }
    }

    /// Bucket index a timestamp maps to under the current geometry.
    /// Indices past the bucket array mean "overflow"; callers must have
    /// ensured `t >= year_start`.
    #[inline]
    fn index_of(&self, t: Time) -> usize {
        debug_assert!(t >= self.year_start);
        ((t - self.year_start) / self.width) as usize
    }

    /// Plan the year geometry for `n_pending` events starting at
    /// `base`: bucket count tracks the population (≈ one event per
    /// bucket), width tracks the live time span per event. Pure
    /// function of observed state — no clocks, no randomness — so the
    /// structure stays bit-deterministic.
    fn plan_geometry(&self, base: Time, n_pending: usize) -> (usize, Time) {
        let n_buckets = n_pending
            .next_power_of_two()
            .clamp(MIN_BUCKETS, MAX_BUCKETS);
        let width = match self.fixed_width {
            Some(w) => w,
            None => {
                let span = self
                    .max_t
                    .saturating_sub(base)
                    .saturating_add(1);
                (span / n_pending.max(1) as Time).max(1)
            }
        };
        (n_buckets, width)
    }

    /// Re-anchor the year at `base` with fresh geometry and re-place
    /// every pending entry. O(len); amortized against the pushes that
    /// triggered it.
    fn rebuild(&mut self, base: Time, n_pending: usize) {
        let mut all: Vec<Entry<E>> = Vec::with_capacity(self.len);
        for b in &mut self.buckets {
            all.extend(b.drain());
        }
        all.extend(self.overflow.drain());
        let (n_buckets, width) = self.plan_geometry(base, n_pending);
        self.buckets.clear();
        self.buckets.resize_with(n_buckets, BinaryHeap::new);
        self.width = width;
        self.year_start = base;
        self.cursor = 0;
        self.in_year = 0;
        for e in all {
            let idx = self.index_of(e.t);
            if idx < self.buckets.len() {
                self.in_year += 1;
                self.buckets[idx].push(e);
            } else {
                self.overflow.push(e);
            }
        }
    }

    /// All buckets drained but overflow holds events: start a new year
    /// anchored at the overflow minimum. Guarantees progress — the
    /// anchoring event always lands in bucket 0.
    fn advance_year(&mut self) {
        debug_assert!(self.in_year == 0 && !self.overflow.is_empty());
        let base = self.overflow.peek().unwrap().t;
        let n_pending = self.overflow.len();
        self.rebuild(base, n_pending);
    }
}

impl<E> Calendar<E> for BucketCalendar<E> {
    fn push(&mut self, t: Time, seq: u64, ev: E) {
        self.len += 1;
        if t > self.max_t {
            self.max_t = t;
        }
        if t < self.year_start {
            // Behind the window (a driver scheduling into the past of
            // an advanced year): re-anchor everything on the new
            // minimum. `Sim::at` clamps to `now` so engines never take
            // this path, but the raw structure stays correct for the
            // differential suite's arbitrary interleavings.
            self.rebuild(t, self.len);
        }
        let idx = self.index_of(t);
        if idx < self.buckets.len() {
            self.buckets[idx].push(Entry { t, seq, ev });
            self.in_year += 1;
            if idx < self.cursor {
                self.cursor = idx;
            }
            if self.in_year > self.buckets.len() * OVERLOAD_FACTOR
                && self.buckets.len() < MAX_BUCKETS
            {
                // Window overloaded: grow in place. Anchor at the
                // cursor's lower bound, which bounds every live entry
                // from below (invariants 1–3); on overflow fall back
                // to `year_start`, which always does.
                let base = self
                    .width
                    .checked_mul(self.cursor as Time)
                    .and_then(|off| self.year_start.checked_add(off))
                    .unwrap_or(self.year_start);
                self.rebuild(base, self.len);
            }
        } else {
            self.overflow.push(Entry { t, seq, ev });
        }
    }

    fn pop(&mut self) -> Option<Entry<E>> {
        if self.len == 0 {
            return None;
        }
        loop {
            while self.cursor < self.buckets.len()
                && self.buckets[self.cursor].is_empty()
            {
                self.cursor += 1;
            }
            if self.cursor < self.buckets.len() {
                let e = self.buckets[self.cursor].pop().unwrap();
                self.len -= 1;
                self.in_year -= 1;
                return Some(e);
            }
            self.advance_year();
        }
    }

    fn next_time(&mut self) -> Option<Time> {
        if self.len == 0 {
            return None;
        }
        loop {
            while self.cursor < self.buckets.len()
                && self.buckets[self.cursor].is_empty()
            {
                self.cursor += 1;
            }
            if self.cursor < self.buckets.len() {
                return Some(self.buckets[self.cursor].peek().unwrap().t);
            }
            self.advance_year();
        }
    }

    fn len(&self) -> usize {
        self.len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(c: &mut impl Calendar<u64>) -> Vec<(Time, u64, u64)> {
        let mut out = Vec::new();
        while let Some(e) = c.pop() {
            out.push((e.t, e.seq, e.ev));
        }
        out
    }

    #[test]
    fn bucket_pops_in_time_then_seq_order() {
        let mut c: BucketCalendar<u64> = BucketCalendar::new(None);
        for (seq, &t) in [30u64, 10, 20, 10, 10, 500, 0].iter().enumerate() {
            c.push(t, seq as u64, seq as u64);
        }
        let order = drain(&mut c);
        assert_eq!(
            order,
            vec![
                (0, 6, 6),
                (10, 1, 1),
                (10, 3, 3),
                (10, 4, 4),
                (20, 2, 2),
                (30, 0, 0),
                (500, 5, 5),
            ]
        );
        assert!(c.is_empty());
    }

    #[test]
    fn bucket_matches_heap_on_far_future_overflow() {
        let mut b: BucketCalendar<u64> = BucketCalendar::new(None);
        let mut h: HeapCalendar<u64> = HeapCalendar::new();
        // Clusters separated by huge gaps force overflow + re-anchoring.
        let mut seq = 0u64;
        for cluster in 0..5u64 {
            let base = cluster * 1_000_000_000_000;
            for i in 0..100u64 {
                let t = base + (i * 37) % 1000;
                b.push(t, seq, seq);
                h.push(t, seq, seq);
                seq += 1;
            }
        }
        assert_eq!(drain(&mut b), drain(&mut h));
    }

    #[test]
    fn bucket_handles_pushes_behind_the_window() {
        let mut c: BucketCalendar<u64> = BucketCalendar::new(None);
        c.push(1_000_000, 0, 0);
        assert_eq!(c.pop().map(|e| e.t), Some(1_000_000));
        // The year is now anchored past 0; push behind it.
        c.push(5, 1, 1);
        c.push(1_000_001, 2, 2);
        assert_eq!(c.pop().map(|e| (e.t, e.seq)), Some((5, 1)));
        assert_eq!(c.pop().map(|e| (e.t, e.seq)), Some((1_000_001, 2)));
        assert!(c.pop().is_none());
    }

    #[test]
    fn fixed_width_pins_bucket_width() {
        let mut c: BucketCalendar<u64> = BucketCalendar::new(Some(64));
        for seq in 0..1000u64 {
            c.push(seq * 13, seq, seq);
        }
        assert_eq!(c.width, 64);
        let popped = drain(&mut c);
        assert_eq!(popped.len(), 1000);
        assert!(popped.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(c.width, 64, "re-plans keep the pinned width");
    }

    #[test]
    fn zero_fixed_width_is_clamped_to_one() {
        let c: BucketCalendar<u64> = BucketCalendar::new(Some(0));
        assert_eq!(c.width, 1);
    }

    #[test]
    fn overload_grows_the_window() {
        let mut c: BucketCalendar<u64> = BucketCalendar::new(None);
        // Dense same-window pushes trip the OVERLOAD_FACTOR rebuild.
        for seq in 0..10_000u64 {
            c.push(seq % 7, seq, seq);
        }
        assert!(c.buckets.len() > MIN_BUCKETS);
        assert_eq!(c.len(), 10_000);
        let popped = drain(&mut c);
        assert_eq!(popped.len(), 10_000);
        assert!(popped.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn len_counts_buckets_and_overflow() {
        let mut c: BucketCalendar<u64> = BucketCalendar::new(None);
        assert!(c.is_empty());
        c.push(1, 0, 0);
        c.push(u64::MAX - 1, 1, 1);
        assert_eq!(c.len(), 2);
        c.pop();
        assert_eq!(c.len(), 1);
        c.pop();
        assert!(c.is_empty());
        assert!(c.pop().is_none());
    }
}
