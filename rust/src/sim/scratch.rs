//! Arena-style per-run scratch state.
//!
//! Two allocations the engines used to scatter across many `Vec`s:
//!
//! * [`TaskScratch`]: the Wukong engine's five per-task arrays
//!   (claimed, stored, executed, attempts, avail_at) packed into one
//!   slot arena — a run touches one contiguous allocation per task
//!   instead of five, and the whole scratch frees in one drop.
//! * [`ReadyCounters`]: remaining-parent counters over the CSR
//!   adjacency with a branch-light completion sweep, shared by the
//!   centralized baselines (numpywren, pywren, dask).

use crate::dag::{Dag, TaskId};

use super::time::Time;

const CLAIMED: u8 = 1;
const STORED: u8 = 2;

/// One arena slot of per-task engine scratch (16 bytes + padding):
/// retry/exec counters, the output-availability clock, and two flag
/// bits (claimed-by-an-executor, stored-to-KVS).
#[derive(Clone, Copy, Default)]
pub struct TaskSlot {
    /// Virtual time the task's output becomes readable.
    pub avail_at: Time,
    /// Completed executions (exactly-once gate asserts ≤ 1).
    pub executed: u32,
    /// Invocation attempts (retries included).
    pub attempts: u32,
    flags: u8,
}

impl TaskSlot {
    /// Has some executor claimed this task (fan-out dedup)?
    #[inline]
    pub fn claimed(&self) -> bool {
        self.flags & CLAIMED != 0
    }

    #[inline]
    pub fn set_claimed(&mut self) {
        self.flags |= CLAIMED;
    }

    /// Was the task's output written to the KVS (vs handed over
    /// locally via "becomes")?
    #[inline]
    pub fn stored(&self) -> bool {
        self.flags & STORED != 0
    }

    #[inline]
    pub fn set_stored(&mut self) {
        self.flags |= STORED;
    }
}

/// Per-task scratch arena: one `Vec<TaskSlot>` for the whole run.
pub struct TaskScratch {
    slots: Vec<TaskSlot>,
}

impl TaskScratch {
    pub fn new(n_tasks: usize) -> TaskScratch {
        TaskScratch {
            slots: vec![TaskSlot::default(); n_tasks],
        }
    }

    #[inline]
    pub fn slot(&self, t: TaskId) -> &TaskSlot {
        &self.slots[t as usize]
    }

    #[inline]
    pub fn slot_mut(&mut self, t: TaskId) -> &mut TaskSlot {
        &mut self.slots[t as usize]
    }

    /// Unpack the per-task execution counters (metrics assembly).
    pub fn executed_vec(&self) -> Vec<u32> {
        self.slots.iter().map(|s| s.executed).collect()
    }

    /// Unpack the per-task attempt counters (metrics assembly).
    pub fn attempts_vec(&self) -> Vec<u32> {
        self.slots.iter().map(|s| s.attempts).collect()
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Grow the arena to `n` slots (appended-epoch tasks). Growth is
    /// epoch-granular: dynamic runs size the arena to the full expanded
    /// task count when the epoch opens (`SpawnState::total_len`), which
    /// is exactly the size a statically pre-expanded run allocates — the
    /// differential gate depends on that equality. Never shrinks.
    pub fn grow_to(&mut self, n: usize) {
        if n > self.slots.len() {
            self.slots.resize(n, TaskSlot::default());
        }
    }
}

/// Remaining-parent counters over the CSR arrays.
///
/// `complete` walks `dag.children(t)` — one contiguous CSR slice — with
/// a wrapping decrement and a flag OR per child; the only branch in the
/// sweep is the enqueue of a newly-ready child, which is exactly the
/// work that cannot be elided.
pub struct ReadyCounters {
    remaining: Vec<u32>,
}

impl ReadyCounters {
    /// Counters initialized from the CSR indegrees.
    pub fn new(dag: &Dag) -> ReadyCounters {
        ReadyCounters {
            remaining: (0..dag.len() as TaskId)
                .map(|t| dag.indegree(t) as u32)
                .collect(),
        }
    }

    /// Remaining unfinished parents of `t`.
    #[inline]
    pub fn remaining(&self, t: TaskId) -> u32 {
        self.remaining[t as usize]
    }

    pub fn len(&self) -> usize {
        self.remaining.len()
    }

    pub fn is_empty(&self) -> bool {
        self.remaining.is_empty()
    }

    /// Grow to `n` counters for appended-epoch tasks, each initialized
    /// to `indegree`. Runtime-spawned tasks have exactly one parent
    /// (their spawner), so dynamic runs grow with `indegree = 1` — the
    /// value `ReadyCounters::new` would compute over the pre-expanded
    /// DAG. Never shrinks.
    pub fn grow_to(&mut self, n: usize, indegree: u32) {
        if n > self.remaining.len() {
            self.remaining.resize(n, indegree);
        }
    }

    /// Force `t`'s counter to zero (a spawned child enqueued directly by
    /// its completing spawner).
    #[inline]
    pub fn mark_ready(&mut self, t: TaskId) {
        self.remaining[t as usize] = 0;
    }

    /// Record `t` as complete: decrement every child's counter, invoke
    /// `enqueue` for each child that just became ready. Returns whether
    /// any child became ready.
    #[inline]
    pub fn complete(
        &mut self,
        dag: &Dag,
        t: TaskId,
        mut enqueue: impl FnMut(TaskId),
    ) -> bool {
        let mut newly = false;
        for &c in dag.children(t) {
            let left = self.remaining[c as usize].wrapping_sub(1);
            self.remaining[c as usize] = left;
            let ready = left == 0;
            newly |= ready;
            if ready {
                enqueue(c);
            }
        }
        newly
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::{DagBuilder, OpKind};

    #[test]
    fn slot_flags_are_independent() {
        let mut s = TaskScratch::new(3);
        s.slot_mut(1).set_claimed();
        assert!(s.slot(1).claimed());
        assert!(!s.slot(1).stored());
        s.slot_mut(1).set_stored();
        assert!(s.slot(1).claimed() && s.slot(1).stored());
        assert!(!s.slot(0).claimed() && !s.slot(2).stored());
    }

    #[test]
    fn counter_vecs_unpack_per_task() {
        let mut s = TaskScratch::new(3);
        s.slot_mut(0).executed += 1;
        s.slot_mut(2).attempts += 3;
        assert_eq!(s.executed_vec(), vec![1, 0, 0]);
        assert_eq!(s.attempts_vec(), vec![0, 0, 3]);
    }

    #[test]
    fn scratch_grows_by_epoch_and_keeps_existing_slots() {
        let mut s = TaskScratch::new(2);
        s.slot_mut(1).executed = 1;
        s.slot_mut(1).set_claimed();
        s.grow_to(5);
        assert_eq!(s.len(), 5);
        assert_eq!(s.slot(1).executed, 1);
        assert!(s.slot(1).claimed());
        assert_eq!(s.slot(4).executed, 0);
        assert!(!s.slot(4).claimed());
        s.grow_to(3); // never shrinks
        assert_eq!(s.len(), 5);
        assert_eq!(s.executed_vec(), vec![0, 1, 0, 0, 0]);
    }

    #[test]
    fn ready_counters_grow_with_unit_indegree() {
        let mut b = DagBuilder::new("pair");
        let a = b.task("a", OpKind::Generic, 1.0, 8);
        let x = b.task("b", OpKind::Generic, 1.0, 8);
        b.edge(a, x);
        let dag = b.build().unwrap();
        let mut ctr = ReadyCounters::new(&dag);
        ctr.grow_to(4, 1);
        assert_eq!(ctr.len(), 4);
        assert_eq!(ctr.remaining(2), 1);
        ctr.mark_ready(3);
        assert_eq!(ctr.remaining(3), 0);
        assert_eq!(ctr.remaining(x), 1); // base counters untouched
    }

    #[test]
    fn ready_counters_sweep_a_diamond() {
        // a → {b, c} → d
        let mut b = DagBuilder::new("diamond");
        let a = b.task("a", OpKind::Generic, 1.0, 8);
        let x = b.task("b", OpKind::Generic, 1.0, 8);
        let y = b.task("c", OpKind::Generic, 1.0, 8);
        let z = b.task("d", OpKind::Generic, 1.0, 8);
        b.edge(a, x).edge(a, y).edge(x, z).edge(y, z);
        let dag = b.build().unwrap();

        let mut ctr = ReadyCounters::new(&dag);
        assert_eq!(ctr.remaining(z), 2);
        let mut ready = Vec::new();
        assert!(ctr.complete(&dag, a, |c| ready.push(c)));
        assert_eq!(ready, vec![x, y]);
        assert!(!ctr.complete(&dag, x, |c| ready.push(c)));
        assert!(ctr.complete(&dag, y, |c| ready.push(c)));
        assert_eq!(ready, vec![x, y, z]);
    }
}
