//! Queueing resources: FIFO single-server and k-server stations.
//!
//! These model everything in the system with finite service capacity:
//! a Redis shard's wire (bandwidth × latency), an executor's NIC, the
//! invoker pool's processes, a Dask worker's cores, the numpywren central
//! queue. `acquire(now, service)` answers "when would this job start and
//! finish?", advancing the server's horizon — an O(log k) analytic stand-in
//! for simulating byte-level transfers.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::time::Time;

/// Single FIFO server: jobs are serviced back-to-back in arrival order.
#[derive(Debug, Clone, Default)]
pub struct FifoResource {
    free_at: Time,
    busy_total: Time,
    jobs: u64,
}

impl FifoResource {
    pub fn new() -> FifoResource {
        FifoResource::default()
    }

    /// Enqueue a job arriving at `now` with the given `service` demand.
    /// Returns `(start, end)` times.
    pub fn acquire(&mut self, now: Time, service: Time) -> (Time, Time) {
        let start = self.free_at.max(now);
        let end = start + service;
        self.free_at = end;
        self.busy_total += service;
        self.jobs += 1;
        (start, end)
    }

    /// Time at which the server next becomes idle.
    pub fn free_at(&self) -> Time {
        self.free_at
    }

    /// Total busy time accumulated (utilization metric).
    pub fn busy_total(&self) -> Time {
        self.busy_total
    }

    pub fn jobs(&self) -> u64 {
        self.jobs
    }
}

/// `k` identical FIFO servers; each job takes the earliest-free server.
#[derive(Debug, Clone)]
pub struct MultiResource {
    servers: BinaryHeap<Reverse<Time>>,
    k: usize,
    busy_total: Time,
    jobs: u64,
}

impl MultiResource {
    pub fn new(k: usize) -> MultiResource {
        assert!(k >= 1);
        MultiResource {
            servers: (0..k).map(|_| Reverse(0)).collect(),
            k,
            busy_total: 0,
            jobs: 0,
        }
    }

    /// Enqueue a job arriving at `now`; returns `(start, end)`.
    pub fn acquire(&mut self, now: Time, service: Time) -> (Time, Time) {
        let Reverse(free) = self.servers.pop().expect("k >= 1");
        let start = free.max(now);
        let end = start + service;
        self.servers.push(Reverse(end));
        self.busy_total += service;
        self.jobs += 1;
        (start, end)
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn busy_total(&self) -> Time {
        self.busy_total
    }

    pub fn jobs(&self) -> u64 {
        self.jobs
    }

    /// Earliest time any server is free (for admission estimates).
    pub fn next_free(&self) -> Time {
        self.servers.peek().map(|Reverse(t)| *t).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_serializes_jobs() {
        let mut r = FifoResource::new();
        assert_eq!(r.acquire(0, 10), (0, 10));
        assert_eq!(r.acquire(0, 10), (10, 20)); // queued behind job 1
        assert_eq!(r.acquire(50, 5), (50, 55)); // idle gap
        assert_eq!(r.busy_total(), 25);
        assert_eq!(r.jobs(), 3);
    }

    #[test]
    fn multi_overlaps_up_to_k() {
        let mut r = MultiResource::new(2);
        assert_eq!(r.acquire(0, 10), (0, 10));
        assert_eq!(r.acquire(0, 10), (0, 10)); // second server
        assert_eq!(r.acquire(0, 10), (10, 20)); // queued
        assert_eq!(r.jobs(), 3);
    }

    #[test]
    fn multi_picks_earliest_free() {
        let mut r = MultiResource::new(2);
        r.acquire(0, 100); // server A busy until 100
        r.acquire(0, 10); // server B busy until 10
        assert_eq!(r.acquire(20, 5), (20, 25)); // B is free at 20
    }

    #[test]
    fn k_one_equals_fifo() {
        let mut m = MultiResource::new(1);
        let mut f = FifoResource::new();
        let arrivals = [(0u64, 7u64), (3, 2), (100, 4), (100, 4)];
        for &(now, s) in &arrivals {
            assert_eq!(m.acquire(now, s), f.acquire(now, s));
        }
    }
}
