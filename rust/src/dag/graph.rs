//! The [`Dag`] container: builder, validation, topology queries, DOT
//! export.
//!
//! Adjacency is stored in CSR (compressed sparse row) form — one flat
//! `parents` array and one flat `children` array, each indexed by a
//! per-task offset range — and task names are interned into a single
//! string arena. A million-task DAG is therefore a handful of large
//! allocations instead of millions of per-node `Vec`s/`String`s, and
//! `parents(t)`/`children(t)` are contiguous slices the engines iterate
//! without cloning. Leaves and sinks are computed once at build time.

use std::collections::{HashMap, VecDeque};
use std::fmt::Write as _;

use super::task::{OpKind, TaskId, TaskNode};
use crate::sim::Time;

/// Sentinel for "no next sibling" in the delta's intrusive child lists.
const NO_SIB: u32 = u32::MAX;

/// A validated directed acyclic task graph (CSR adjacency layout).
#[derive(Debug, Clone)]
pub struct Dag {
    pub name: String,
    tasks: Vec<TaskNode>,
    /// Flat parent lists: task `t`'s parents are
    /// `parents[parent_off[t] .. parent_off[t + 1]]`.
    parents: Vec<TaskId>,
    parent_off: Vec<u32>,
    /// Flat child lists, same offset scheme.
    children: Vec<TaskId>,
    child_off: Vec<u32>,
    /// Interned task names: task `t`'s name is
    /// `names[name_off[t] .. name_off[t + 1]]`.
    names: String,
    name_off: Vec<u32>,
    /// Cached at build: tasks with no parents, ascending id.
    leaves: Vec<TaskId>,
    /// Cached at build: tasks with no children, ascending id.
    sinks: Vec<TaskId>,
}

impl Dag {
    pub fn tasks(&self) -> &[TaskNode] {
        &self.tasks
    }

    pub fn task(&self, id: TaskId) -> &TaskNode {
        &self.tasks[id as usize]
    }

    /// The task's interned human-readable name.
    pub fn task_name(&self, id: TaskId) -> &str {
        let a = self.name_off[id as usize] as usize;
        let b = self.name_off[id as usize + 1] as usize;
        &self.names[a..b]
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Parent ids of `id`, in edge-insertion order.
    pub fn parents(&self, id: TaskId) -> &[TaskId] {
        let a = self.parent_off[id as usize] as usize;
        let b = self.parent_off[id as usize + 1] as usize;
        &self.parents[a..b]
    }

    /// Child ids of `id`, in edge-insertion order.
    pub fn children(&self, id: TaskId) -> &[TaskId] {
        let a = self.child_off[id as usize] as usize;
        let b = self.child_off[id as usize + 1] as usize;
        &self.children[a..b]
    }

    /// In-degree (fan-in width) — an O(1) offset subtraction.
    pub fn indegree(&self, id: TaskId) -> usize {
        (self.parent_off[id as usize + 1] - self.parent_off[id as usize]) as usize
    }

    /// Out-degree (fan-out width).
    pub fn outdegree(&self, id: TaskId) -> usize {
        (self.child_off[id as usize + 1] - self.child_off[id as usize]) as usize
    }

    /// Tasks with no parents — the static schedules' roots (§3.2).
    /// Cached at build time (ascending id).
    pub fn leaves(&self) -> &[TaskId] {
        &self.leaves
    }

    /// Tasks with no children — final results, published to the client.
    /// Cached at build time (ascending id).
    pub fn sinks(&self) -> &[TaskId] {
        &self.sinks
    }

    pub fn n_edges(&self) -> usize {
        self.children.len()
    }

    pub fn total_flops(&self) -> f64 {
        self.tasks.iter().map(|t| t.flops).sum()
    }

    pub fn total_output_bytes(&self) -> u64 {
        self.tasks.iter().map(|t| t.out_bytes).sum()
    }

    /// Kahn topological order (exists because `DagBuilder` validated
    /// acyclicity).
    pub fn topo_order(&self) -> Vec<TaskId> {
        let mut indeg: Vec<usize> =
            (0..self.tasks.len() as TaskId).map(|t| self.indegree(t)).collect();
        let mut q: VecDeque<TaskId> = self.leaves.iter().copied().collect();
        let mut order = Vec::with_capacity(self.tasks.len());
        while let Some(t) = q.pop_front() {
            order.push(t);
            for &c in self.children(t) {
                indeg[c as usize] -= 1;
                if indeg[c as usize] == 0 {
                    q.push_back(c);
                }
            }
        }
        order
    }

    /// All nodes reachable from `start` (inclusive), DFS preorder — the
    /// paper's static schedule content for a leaf (§3.2).
    pub fn reachable_from(&self, start: TaskId) -> Vec<TaskId> {
        let mut seen = vec![false; self.tasks.len()];
        let mut stack = vec![start];
        let mut out = Vec::new();
        while let Some(t) = stack.pop() {
            if std::mem::replace(&mut seen[t as usize], true) {
                continue;
            }
            out.push(t);
            // push children in reverse so DFS visits them in order
            for &c in self.children(t).iter().rev() {
                if !seen[c as usize] {
                    stack.push(c);
                }
            }
        }
        out
    }

    /// Critical-path length under a given per-task duration function
    /// (lower bound on any engine's makespan; used by scaling tests).
    pub fn critical_path(&self, dur: impl Fn(&TaskNode) -> Time) -> Time {
        let order = self.topo_order();
        let mut finish = vec![0 as Time; self.tasks.len()];
        let mut best = 0;
        for &t in &order {
            let start = self
                .parents(t)
                .iter()
                .map(|&p| finish[p as usize])
                .max()
                .unwrap_or(0);
            finish[t as usize] = start + dur(self.task(t));
            best = best.max(finish[t as usize]);
        }
        best
    }

    /// Merge an epoch's staged appends into a fresh flat CSR DAG — the
    /// epoch *seal*. Steady-state traversal of the sealed DAG is exactly
    /// as flat as a built-from-scratch one, and two determinism surfaces
    /// are preserved byte-for-byte:
    ///
    /// - the base parent CSR is copied verbatim (engines' fetch loops
    ///   follow per-node parent order, which a rebuild through
    ///   `DagBuilder` could not recover — it is global edge-insertion
    ///   order, not derivable from the graph shape);
    /// - per-node child order is base children first, then staged
    ///   children in staged-id order — the exact order dynamic dispatch
    ///   discovers them in.
    ///
    /// Leaves are unchanged (every staged task has a parent); sinks are
    /// recomputed. Acyclicity holds by construction: `DagDelta::push`
    /// asserts every staged parent precedes its child, so ids remain a
    /// topological order of the appended region.
    pub fn sealed_with(&self, delta: &DagDelta) -> Dag {
        assert_eq!(
            delta.base_len(),
            self.len(),
            "delta was staged against a different base"
        );
        let n = self.len();
        let total = n + delta.len();

        let mut tasks = self.tasks.clone();
        tasks.extend_from_slice(&delta.tasks);

        let mut names = self.names.clone();
        let mut name_off = self.name_off.clone();
        for s in n..total {
            let _ = write!(names, "sp{s}");
            name_off.push(names.len() as u32);
        }

        // Parents: verbatim base CSR + one parent per staged task.
        let mut parents = self.parents.clone();
        let mut parent_off = self.parent_off.clone();
        for &p in &delta.parents {
            parents.push(p);
            parent_off.push(parents.len() as u32);
        }

        // Children: counting sort over base + staged edges.
        let mut child_off = vec![0u32; total + 1];
        for t in 0..n {
            child_off[t + 1] = self.outdegree(t as TaskId) as u32;
        }
        for &p in &delta.parents {
            child_off[p as usize + 1] += 1;
        }
        for i in 0..total {
            child_off[i + 1] += child_off[i];
        }
        let mut children = vec![0 as TaskId; child_off[total] as usize];
        let mut ccur = vec![0u32; total];
        for t in 0..n {
            let s = self.children(t as TaskId);
            let at = child_off[t] as usize;
            children[at..at + s.len()].copy_from_slice(s);
            ccur[t] = (at + s.len()) as u32;
        }
        for t in n..total {
            ccur[t] = child_off[t];
        }
        for (i, &p) in delta.parents.iter().enumerate() {
            children[ccur[p as usize] as usize] = (n + i) as TaskId;
            ccur[p as usize] += 1;
        }

        let sinks: Vec<TaskId> = (0..total as TaskId)
            .filter(|&t| child_off[t as usize] == child_off[t as usize + 1])
            .collect();

        Dag {
            name: self.name.clone(),
            tasks,
            parents,
            parent_off,
            children,
            child_off,
            names,
            name_off,
            leaves: self.leaves.clone(),
            sinks,
        }
    }

    /// Graphviz DOT rendering (debugging / docs).
    pub fn to_dot(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "digraph \"{}\" {{", self.name);
        for t in 0..self.tasks.len() as TaskId {
            let _ = writeln!(s, "  t{} [label=\"{}\"];", t, self.task_name(t));
        }
        for t in 0..self.tasks.len() as TaskId {
            for &c in self.children(t) {
                let _ = writeln!(s, "  t{t} -> t{c};");
            }
        }
        s.push_str("}\n");
        s
    }
}

/// An append-only staged-task layer over an epoch-frozen [`Dag`]: the
/// base CSR stays immutable while runtime-spawned tasks accumulate in the
/// delta, which answers the same O(1) degree / parent / child queries for
/// the staged region. At epoch seal, [`Dag::sealed_with`] merges the
/// delta into a fresh flat CSR so steady-state traversal never pays a
/// two-level lookup.
///
/// Staged tasks have exactly one parent (their spawner — base or an
/// earlier staged task); per-parent staged children are kept in push
/// order via an intrusive linked list (O(1) append, no per-parent `Vec`).
#[derive(Debug, Clone)]
pub struct DagDelta {
    base_len: u32,
    tasks: Vec<TaskNode>,
    /// Sole parent of each staged task, parallel to `tasks`.
    parents: Vec<TaskId>,
    /// Per parent: (first, last, count) of its staged children, in
    /// staged-index space.
    child_link: HashMap<TaskId, (u32, u32, u32)>,
    /// Next staged sibling under the same parent (`NO_SIB` = end).
    next_sib: Vec<u32>,
}

impl DagDelta {
    /// An empty delta staged against `base`.
    pub fn new(base: &Dag) -> DagDelta {
        DagDelta {
            base_len: base.len() as u32,
            tasks: Vec::new(),
            parents: Vec::new(),
            child_link: HashMap::new(),
            next_sib: Vec::new(),
        }
    }

    /// Append a staged task under `parent`; returns its (global) id.
    /// Parents must precede children, so ids stay a topological order.
    pub fn push(&mut self, parent: TaskId, node: TaskNode) -> TaskId {
        let idx = self.tasks.len() as u32;
        let id = self.base_len + idx;
        assert!(parent < id, "staged parent must precede its child");
        self.tasks.push(node);
        self.parents.push(parent);
        self.next_sib.push(NO_SIB);
        match self.child_link.get_mut(&parent) {
            Some(link) => {
                self.next_sib[link.1 as usize] = idx;
                link.1 = idx;
                link.2 += 1;
            }
            None => {
                self.child_link.insert(parent, (idx, idx, 1));
            }
        }
        id
    }

    /// Number of staged tasks.
    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Length of the base this delta is staged against.
    pub fn base_len(&self) -> usize {
        self.base_len as usize
    }

    /// Base + staged task count.
    pub fn total_len(&self) -> usize {
        self.base_len as usize + self.tasks.len()
    }

    /// The staged task's node (`t` must be a staged id).
    pub fn node(&self, t: TaskId) -> &TaskNode {
        &self.tasks[(t - self.base_len) as usize]
    }

    /// The staged task's sole parent.
    pub fn parent_of(&self, t: TaskId) -> TaskId {
        self.parents[(t - self.base_len) as usize]
    }

    /// In-degree contributed by the delta: 1 for staged ids, 0 for base.
    pub fn indegree(&self, t: TaskId) -> usize {
        usize::from(t >= self.base_len)
    }

    /// Out-degree contributed by the delta (staged children of `t`).
    pub fn outdegree(&self, t: TaskId) -> usize {
        self.child_link.get(&t).map_or(0, |&(_, _, c)| c as usize)
    }

    /// Staged children of `t` (base or staged), in push order.
    pub fn children_of(&self, t: TaskId) -> StagedChildren<'_> {
        StagedChildren {
            delta: self,
            cur: self.child_link.get(&t).map_or(NO_SIB, |&(f, _, _)| f),
        }
    }
}

/// Iterator over a task's staged children (see [`DagDelta::children_of`]).
pub struct StagedChildren<'a> {
    delta: &'a DagDelta,
    cur: u32,
}

impl Iterator for StagedChildren<'_> {
    type Item = TaskId;

    fn next(&mut self) -> Option<TaskId> {
        if self.cur == NO_SIB {
            return None;
        }
        let idx = self.cur;
        self.cur = self.delta.next_sib[idx as usize];
        Some(self.delta.base_len + idx)
    }
}

/// Incremental DAG constructor; `build()` validates and freezes the CSR
/// layout. Edges are collected as a flat list and converted with one
/// stable counting sort, so building a million-task DAG never allocates
/// per-node adjacency vectors.
#[derive(Debug)]
pub struct DagBuilder {
    name: String,
    tasks: Vec<TaskNode>,
    edges: Vec<(TaskId, TaskId)>,
    names: String,
    name_off: Vec<u32>,
}

impl Default for DagBuilder {
    fn default() -> Self {
        DagBuilder::new("")
    }
}

impl DagBuilder {
    pub fn new(name: &str) -> DagBuilder {
        DagBuilder {
            name: name.to_string(),
            tasks: Vec::new(),
            edges: Vec::new(),
            names: String::new(),
            name_off: vec![0],
        }
    }

    /// Add a task; returns its id. The name is appended to the arena —
    /// no per-task `String` is retained.
    pub fn task(
        &mut self,
        name: impl AsRef<str>,
        op: OpKind,
        flops: f64,
        out_bytes: u64,
    ) -> TaskId {
        let id = self.tasks.len() as TaskId;
        self.names.push_str(name.as_ref());
        self.name_off.push(self.names.len() as u32);
        self.tasks.push(TaskNode {
            op,
            flops,
            out_bytes,
            input_bytes: 0,
            dur_override: None,
        });
        id
    }

    /// Attach external input bytes to a (leaf) task.
    pub fn with_input(&mut self, id: TaskId, bytes: u64) -> &mut Self {
        self.tasks[id as usize].input_bytes = bytes;
        self
    }

    /// Fixed-duration override (sleep-task microbenchmarks).
    pub fn with_duration(&mut self, id: TaskId, d: Time) -> &mut Self {
        self.tasks[id as usize].dur_override = Some(d);
        self
    }

    /// Add a dependency edge `from -> to`.
    pub fn edge(&mut self, from: TaskId, to: TaskId) -> &mut Self {
        assert!(
            (from as usize) < self.tasks.len() && (to as usize) < self.tasks.len(),
            "edge references unknown task"
        );
        assert_ne!(from, to, "self-loop");
        self.edges.push((from, to));
        self
    }

    /// Validate and freeze into the CSR layout.
    pub fn build(self) -> Result<Dag, String> {
        let n = self.tasks.len();
        let n_edges = self.edges.len();

        // CSR construction: count, prefix-sum, stable fill (edge-insertion
        // order is preserved per node — engines depend on it for
        // deterministic dispatch order).
        let mut child_off = vec![0u32; n + 1];
        let mut parent_off = vec![0u32; n + 1];
        for &(f, t) in &self.edges {
            child_off[f as usize + 1] += 1;
            parent_off[t as usize + 1] += 1;
        }
        for i in 0..n {
            child_off[i + 1] += child_off[i];
            parent_off[i + 1] += parent_off[i];
        }
        let mut children = vec![0 as TaskId; n_edges];
        let mut parents = vec![0 as TaskId; n_edges];
        let mut ccur: Vec<u32> = child_off[..n].to_vec();
        let mut pcur: Vec<u32> = parent_off[..n].to_vec();
        for &(f, t) in &self.edges {
            children[ccur[f as usize] as usize] = t;
            ccur[f as usize] += 1;
            parents[pcur[t as usize] as usize] = f;
            pcur[t as usize] += 1;
        }

        // Duplicate edges would break dependency counting. The CSR fill
        // already grouped each node's out-edges, so scan per-node slices
        // (O(E log max_degree), one reused scratch buffer) instead of
        // clone-sorting the whole edge list.
        let mut scratch: Vec<TaskId> = Vec::new();
        for v in 0..n {
            let s = &children[child_off[v] as usize..child_off[v + 1] as usize];
            if s.len() > 1 {
                scratch.clear();
                scratch.extend_from_slice(s);
                scratch.sort_unstable();
                if scratch.windows(2).any(|w| w[0] == w[1]) {
                    return Err(format!("task {v} has duplicate out-edges"));
                }
            }
        }

        let leaves: Vec<TaskId> = (0..n as TaskId)
            .filter(|&t| parent_off[t as usize] == parent_off[t as usize + 1])
            .collect();
        let sinks: Vec<TaskId> = (0..n as TaskId)
            .filter(|&t| child_off[t as usize] == child_off[t as usize + 1])
            .collect();

        let dag = Dag {
            name: self.name,
            tasks: self.tasks,
            parents,
            parent_off,
            children,
            child_off,
            names: self.names,
            name_off: self.name_off,
            leaves,
            sinks,
        };
        // acyclicity: Kahn must consume every node
        let order = dag.topo_order();
        if order.len() != n {
            return Err(format!(
                "cycle detected: topo order covers {}/{} tasks",
                order.len(),
                n
            ));
        }
        Ok(dag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Dag {
        // a -> b, c -> d
        let mut b = DagBuilder::new("diamond");
        let a = b.task("a", OpKind::Generic, 1.0, 10);
        let x = b.task("b", OpKind::Generic, 1.0, 10);
        let y = b.task("c", OpKind::Generic, 1.0, 10);
        let d = b.task("d", OpKind::Generic, 1.0, 10);
        b.edge(a, x).edge(a, y).edge(x, d).edge(y, d);
        b.build().unwrap()
    }

    #[test]
    fn leaves_and_sinks() {
        let d = diamond();
        assert_eq!(d.leaves().to_vec(), vec![0]);
        assert_eq!(d.sinks().to_vec(), vec![3]);
        assert_eq!(d.n_edges(), 4);
    }

    #[test]
    fn csr_adjacency_matches_edge_insertion_order() {
        let d = diamond();
        assert_eq!(d.children(0), &[1, 2]);
        assert_eq!(d.children(1), &[3]);
        assert_eq!(d.parents(3), &[1, 2]);
        assert_eq!(d.parents(0), &[] as &[TaskId]);
        assert_eq!(d.indegree(3), 2);
        assert_eq!(d.outdegree(0), 2);
        assert_eq!(d.indegree(0), 0);
    }

    #[test]
    fn names_are_interned_and_addressable() {
        let d = diamond();
        assert_eq!(d.task_name(0), "a");
        assert_eq!(d.task_name(3), "d");
        let mut b = DagBuilder::new("named");
        let long = b.task(format!("t{}", 123), OpKind::Generic, 1.0, 1);
        let empty = b.task("", OpKind::Generic, 1.0, 1);
        let dag = b.build().unwrap();
        assert_eq!(dag.task_name(long), "t123");
        assert_eq!(dag.task_name(empty), "");
    }

    #[test]
    fn topo_order_respects_edges() {
        let d = diamond();
        let order = d.topo_order();
        let pos: Vec<usize> = (0..4)
            .map(|t| order.iter().position(|&x| x == t as TaskId).unwrap())
            .collect();
        assert!(pos[0] < pos[1] && pos[0] < pos[2]);
        assert!(pos[1] < pos[3] && pos[2] < pos[3]);
    }

    #[test]
    fn cycle_rejected() {
        let mut b = DagBuilder::new("cyc");
        let x = b.task("x", OpKind::Generic, 1.0, 1);
        let y = b.task("y", OpKind::Generic, 1.0, 1);
        b.edge(x, y).edge(y, x);
        assert!(b.build().is_err());
    }

    #[test]
    fn duplicate_edge_rejected() {
        let mut b = DagBuilder::new("dup");
        let x = b.task("x", OpKind::Generic, 1.0, 1);
        let y = b.task("y", OpKind::Generic, 1.0, 1);
        b.edge(x, y).edge(x, y);
        assert!(b.build().is_err());
    }

    #[test]
    fn reachable_from_is_the_static_schedule() {
        let d = diamond();
        let sched = d.reachable_from(0);
        assert_eq!(sched.len(), 4);
        assert_eq!(sched[0], 0); // starts at the leaf
        let from_b = d.reachable_from(1);
        assert_eq!(from_b, vec![1, 3]);
    }

    #[test]
    fn critical_path_diamond() {
        let d = diamond();
        assert_eq!(d.critical_path(|_| 10), 30); // a -> (b|c) -> d
    }

    #[test]
    fn dot_contains_all_edges_and_names() {
        let d = diamond();
        let dot = d.to_dot();
        assert_eq!(dot.matches("->").count(), 4);
        assert!(dot.contains("label=\"a\""));
    }

    fn node(out_bytes: u64) -> TaskNode {
        TaskNode {
            op: OpKind::Noop,
            flops: 0.0,
            out_bytes,
            input_bytes: 0,
            dur_override: None,
        }
    }

    #[test]
    fn delta_answers_degree_parent_child_queries() {
        let base = diamond();
        let mut delta = DagDelta::new(&base);
        let s0 = delta.push(1, node(8)); // staged under base task 1
        let s1 = delta.push(1, node(8));
        let s2 = delta.push(s0, node(8)); // staged under a staged task
        assert_eq!((s0, s1, s2), (4, 5, 6));
        assert_eq!(delta.len(), 3);
        assert_eq!(delta.total_len(), 7);
        assert_eq!(delta.parent_of(s0), 1);
        assert_eq!(delta.parent_of(s2), s0);
        assert_eq!(delta.indegree(1), 0); // base ids gain no delta parents
        assert_eq!(delta.indegree(s0), 1);
        assert_eq!(delta.outdegree(1), 2);
        assert_eq!(delta.outdegree(s0), 1);
        assert_eq!(delta.outdegree(3), 0);
        assert_eq!(delta.children_of(1).collect::<Vec<_>>(), vec![s0, s1]);
        assert_eq!(delta.children_of(s0).collect::<Vec<_>>(), vec![s2]);
        assert_eq!(delta.children_of(s2).count(), 0);
    }

    #[test]
    fn seal_merges_base_first_then_staged_in_id_order() {
        let base = diamond();
        let mut delta = DagDelta::new(&base);
        let s0 = delta.push(1, node(8));
        let s1 = delta.push(1, node(8));
        let s2 = delta.push(s0, node(8));
        let sealed = base.sealed_with(&delta);
        assert_eq!(sealed.len(), 7);
        assert_eq!(sealed.n_edges(), base.n_edges() + 3);
        // Base parent CSR verbatim; staged tasks get their single parent.
        for t in 0..base.len() as TaskId {
            assert_eq!(sealed.parents(t), base.parents(t));
        }
        assert_eq!(sealed.parents(s0), &[1]);
        assert_eq!(sealed.parents(s2), &[s0]);
        // Child order: base children first, then staged in id order.
        assert_eq!(sealed.children(1), &[3, s0, s1]);
        assert_eq!(sealed.children(s0), &[s2]);
        // Leaves unchanged; sinks recomputed over the merged graph.
        assert_eq!(sealed.leaves(), base.leaves());
        assert_eq!(sealed.sinks(), &[3, s1, s2]);
        // Names: base names intact, staged tasks named by id.
        assert_eq!(sealed.task_name(0), "a");
        assert_eq!(sealed.task_name(s0), "sp4");
        assert_eq!(sealed.task_name(s2), "sp6");
        // The merged graph is still a valid topology.
        assert_eq!(sealed.topo_order().len(), 7);
    }

    #[test]
    fn sealing_an_empty_delta_reproduces_the_base() {
        let base = diamond();
        let sealed = base.sealed_with(&DagDelta::new(&base));
        assert_eq!(sealed.len(), base.len());
        assert_eq!(sealed.leaves(), base.leaves());
        assert_eq!(sealed.sinks(), base.sinks());
        for t in 0..base.len() as TaskId {
            assert_eq!(sealed.children(t), base.children(t));
            assert_eq!(sealed.parents(t), base.parents(t));
            assert_eq!(sealed.task_name(t), base.task_name(t));
        }
    }

    #[test]
    #[should_panic(expected = "staged parent must precede its child")]
    fn delta_rejects_forward_parents() {
        let base = diamond();
        let mut delta = DagDelta::new(&base);
        delta.push(9, node(8)); // parent id beyond the staged id
    }
}
