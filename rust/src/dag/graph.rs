//! The [`Dag`] container: builder, validation, topology queries, DOT
//! export.

use std::collections::VecDeque;
use std::fmt::Write as _;

use super::task::{OpKind, TaskId, TaskNode};
use crate::sim::Time;

/// A validated directed acyclic task graph.
#[derive(Debug, Clone)]
pub struct Dag {
    pub name: String,
    tasks: Vec<TaskNode>,
}

impl Dag {
    pub fn tasks(&self) -> &[TaskNode] {
        &self.tasks
    }

    pub fn task(&self, id: TaskId) -> &TaskNode {
        &self.tasks[id as usize]
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Tasks with no parents — the static schedules' roots (§3.2).
    pub fn leaves(&self) -> Vec<TaskId> {
        (0..self.tasks.len() as TaskId)
            .filter(|&t| self.tasks[t as usize].parents.is_empty())
            .collect()
    }

    /// Tasks with no children — final results, published to the client.
    pub fn sinks(&self) -> Vec<TaskId> {
        (0..self.tasks.len() as TaskId)
            .filter(|&t| self.tasks[t as usize].children.is_empty())
            .collect()
    }

    pub fn n_edges(&self) -> usize {
        self.tasks.iter().map(|t| t.children.len()).sum()
    }

    pub fn total_flops(&self) -> f64 {
        self.tasks.iter().map(|t| t.flops).sum()
    }

    pub fn total_output_bytes(&self) -> u64 {
        self.tasks.iter().map(|t| t.out_bytes).sum()
    }

    /// Kahn topological order (exists because `DagBuilder` validated
    /// acyclicity).
    pub fn topo_order(&self) -> Vec<TaskId> {
        let mut indeg: Vec<usize> =
            self.tasks.iter().map(|t| t.parents.len()).collect();
        let mut q: VecDeque<TaskId> = (0..self.tasks.len() as TaskId)
            .filter(|&t| indeg[t as usize] == 0)
            .collect();
        let mut order = Vec::with_capacity(self.tasks.len());
        while let Some(t) = q.pop_front() {
            order.push(t);
            for &c in &self.tasks[t as usize].children {
                indeg[c as usize] -= 1;
                if indeg[c as usize] == 0 {
                    q.push_back(c);
                }
            }
        }
        order
    }

    /// All nodes reachable from `start` (inclusive), DFS preorder — the
    /// paper's static schedule content for a leaf (§3.2).
    pub fn reachable_from(&self, start: TaskId) -> Vec<TaskId> {
        let mut seen = vec![false; self.tasks.len()];
        let mut stack = vec![start];
        let mut out = Vec::new();
        while let Some(t) = stack.pop() {
            if std::mem::replace(&mut seen[t as usize], true) {
                continue;
            }
            out.push(t);
            // push children in reverse so DFS visits them in order
            for &c in self.tasks[t as usize].children.iter().rev() {
                if !seen[c as usize] {
                    stack.push(c);
                }
            }
        }
        out
    }

    /// Critical-path length under a given per-task duration function
    /// (lower bound on any engine's makespan; used by scaling tests).
    pub fn critical_path(&self, dur: impl Fn(&TaskNode) -> Time) -> Time {
        let order = self.topo_order();
        let mut finish = vec![0 as Time; self.tasks.len()];
        let mut best = 0;
        for &t in &order {
            let node = &self.tasks[t as usize];
            let start = node
                .parents
                .iter()
                .map(|&p| finish[p as usize])
                .max()
                .unwrap_or(0);
            finish[t as usize] = start + dur(node);
            best = best.max(finish[t as usize]);
        }
        best
    }

    /// Graphviz DOT rendering (debugging / docs).
    pub fn to_dot(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "digraph \"{}\" {{", self.name);
        for (i, t) in self.tasks.iter().enumerate() {
            let _ = writeln!(s, "  t{} [label=\"{}\"];", i, t.name);
        }
        for (i, t) in self.tasks.iter().enumerate() {
            for &c in &t.children {
                let _ = writeln!(s, "  t{} -> t{};", i, c);
            }
        }
        s.push_str("}\n");
        s
    }
}

/// Incremental DAG constructor; `build()` validates.
#[derive(Debug, Default)]
pub struct DagBuilder {
    name: String,
    tasks: Vec<TaskNode>,
}

impl DagBuilder {
    pub fn new(name: &str) -> DagBuilder {
        DagBuilder {
            name: name.to_string(),
            tasks: Vec::new(),
        }
    }

    /// Add a task; returns its id.
    pub fn task(
        &mut self,
        name: impl Into<String>,
        op: OpKind,
        flops: f64,
        out_bytes: u64,
    ) -> TaskId {
        let id = self.tasks.len() as TaskId;
        self.tasks.push(TaskNode {
            name: name.into(),
            op,
            flops,
            out_bytes,
            input_bytes: 0,
            dur_override: None,
            parents: Vec::new(),
            children: Vec::new(),
        });
        id
    }

    /// Attach external input bytes to a (leaf) task.
    pub fn with_input(&mut self, id: TaskId, bytes: u64) -> &mut Self {
        self.tasks[id as usize].input_bytes = bytes;
        self
    }

    /// Fixed-duration override (sleep-task microbenchmarks).
    pub fn with_duration(&mut self, id: TaskId, d: Time) -> &mut Self {
        self.tasks[id as usize].dur_override = Some(d);
        self
    }

    /// Add a dependency edge `from -> to`.
    pub fn edge(&mut self, from: TaskId, to: TaskId) -> &mut Self {
        assert!(
            (from as usize) < self.tasks.len() && (to as usize) < self.tasks.len(),
            "edge references unknown task"
        );
        assert_ne!(from, to, "self-loop");
        self.tasks[from as usize].children.push(to);
        self.tasks[to as usize].parents.push(from);
        self
    }

    /// Validate and freeze.
    pub fn build(self) -> Result<Dag, String> {
        let dag = Dag {
            name: self.name,
            tasks: self.tasks,
        };
        // acyclicity: Kahn must consume every node
        let order = dag.topo_order();
        if order.len() != dag.tasks.len() {
            return Err(format!(
                "cycle detected: topo order covers {}/{} tasks",
                order.len(),
                dag.tasks.len()
            ));
        }
        // duplicate edges would break dependency counting
        for (i, t) in dag.tasks.iter().enumerate() {
            let mut c = t.children.clone();
            c.sort_unstable();
            c.dedup();
            if c.len() != t.children.len() {
                return Err(format!("task {i} has duplicate out-edges"));
            }
        }
        Ok(dag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Dag {
        // a -> b, c -> d
        let mut b = DagBuilder::new("diamond");
        let a = b.task("a", OpKind::Generic, 1.0, 10);
        let x = b.task("b", OpKind::Generic, 1.0, 10);
        let y = b.task("c", OpKind::Generic, 1.0, 10);
        let d = b.task("d", OpKind::Generic, 1.0, 10);
        b.edge(a, x).edge(a, y).edge(x, d).edge(y, d);
        b.build().unwrap()
    }

    #[test]
    fn leaves_and_sinks() {
        let d = diamond();
        assert_eq!(d.leaves(), vec![0]);
        assert_eq!(d.sinks(), vec![3]);
        assert_eq!(d.n_edges(), 4);
    }

    #[test]
    fn topo_order_respects_edges() {
        let d = diamond();
        let order = d.topo_order();
        let pos: Vec<usize> = (0..4)
            .map(|t| order.iter().position(|&x| x == t as TaskId).unwrap())
            .collect();
        assert!(pos[0] < pos[1] && pos[0] < pos[2]);
        assert!(pos[1] < pos[3] && pos[2] < pos[3]);
    }

    #[test]
    fn cycle_rejected() {
        let mut b = DagBuilder::new("cyc");
        let x = b.task("x", OpKind::Generic, 1.0, 1);
        let y = b.task("y", OpKind::Generic, 1.0, 1);
        b.edge(x, y).edge(y, x);
        assert!(b.build().is_err());
    }

    #[test]
    fn duplicate_edge_rejected() {
        let mut b = DagBuilder::new("dup");
        let x = b.task("x", OpKind::Generic, 1.0, 1);
        let y = b.task("y", OpKind::Generic, 1.0, 1);
        b.edge(x, y).edge(x, y);
        assert!(b.build().is_err());
    }

    #[test]
    fn reachable_from_is_the_static_schedule() {
        let d = diamond();
        let sched = d.reachable_from(0);
        assert_eq!(sched.len(), 4);
        assert_eq!(sched[0], 0); // starts at the leaf
        let from_b = d.reachable_from(1);
        assert_eq!(from_b, vec![1, 3]);
    }

    #[test]
    fn critical_path_diamond() {
        let d = diamond();
        assert_eq!(d.critical_path(|_| 10), 30); // a -> (b|c) -> d
    }

    #[test]
    fn dot_contains_all_edges() {
        let d = diamond();
        let dot = d.to_dot();
        assert_eq!(dot.matches("->").count(), 4);
    }
}
