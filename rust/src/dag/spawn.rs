//! Runtime task spawning (dynamic DAGs): a `SpawnPlan` drawn from its own
//! salted RNG stream decides — per base task, at run start — whether that
//! task emits a recursive subtree of child tasks when it completes.
//!
//! Determinism contract (the differential gate in `tests/dynamic.rs`):
//! the expansion is a pure function of `(base dag, plan, seed)` — never of
//! completion order — so running a plan *dynamically* must be
//! byte-identical to running the statically pre-expanded DAG
//! ([`pre_expand`]). Two properties make that hold:
//!
//! 1. **Own stream.** Expansion decisions come from
//!    `Rng::new(seed ^ SPAWN_STREAM_SALT)` (the `FaultStream` /
//!    `CrashStream` pattern), drawn once per base task in task-id order at
//!    [`SpawnState::for_run`]. Zero-rate plans draw nothing, so plan-free
//!    and zero-rate runs are bit-identical.
//! 2. **DFS id pre-layout.** Spawned tasks get ids assigned up front: the
//!    expanding base task `b` (in id order) owns a contiguous block of
//!    staged ids laid out in preorder DFS, so every id-indexed per-task
//!    vector (`per_task_exec`, outcomes, MDS/KVS key spaces) matches the
//!    pre-expanded DAG exactly, regardless of when tasks actually spawn.
//!
//! Spawned tasks recurse deterministically: a staged task at depth `d`
//! spawns `fanout` children iff `d < depth` — no further random draws, so
//! a single f64 per base task fully determines the expansion.

use crate::dag::graph::{Dag, DagDelta};
use crate::dag::{OpKind, TaskId, TaskNode};
use crate::metrics::TaskOutcome;
use crate::platform::faults;
use crate::sim::secs;
use crate::util::Rng;

/// Seed salt for the spawn-decision stream (disjoint by construction from
/// `FAULT_STREAM_SALT` / `CRASH_STREAM_SALT` / the arrival stream).
pub const SPAWN_STREAM_SALT: u64 = 0x5BA3_9D0C_7E21_AF58;

/// A runtime-spawning plan: with probability `p_spawn`, a completing base
/// task emits `fanout` children, recursively to `depth` levels (so an
/// expanding task contributes `fanout + fanout² + … + fanout^depth`
/// subtasks). The default plan is inert (`p_spawn = 0`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpawnPlan {
    /// Per-base-task probability of expanding. `0.0` disables spawning
    /// and draws nothing from the RNG stream.
    pub p_spawn: f64,
    /// Children per expanding task (validated to `1..=1024` by `--set`).
    pub fanout: u32,
    /// Recursion depth (validated to `1..=8` by `--set`).
    pub depth: u32,
    /// Fixed duration of each spawned task, seconds.
    pub task_dur_s: f64,
    /// Output object size of each spawned task.
    pub out_bytes: u64,
}

impl Default for SpawnPlan {
    fn default() -> Self {
        SpawnPlan {
            p_spawn: 0.0,
            fanout: 2,
            depth: 1,
            task_dur_s: 0.0,
            out_bytes: 0,
        }
    }
}

impl SpawnPlan {
    /// A single-level plan spawning `fanout` children with rate `p`.
    pub fn with_rate(p_spawn: f64, fanout: u32) -> SpawnPlan {
        SpawnPlan {
            p_spawn,
            fanout,
            ..SpawnPlan::default()
        }
    }

    /// A recursive plan: rate `p`, `fanout` children, `depth` levels.
    pub fn recursive(p_spawn: f64, fanout: u32, depth: u32) -> SpawnPlan {
        SpawnPlan {
            p_spawn,
            fanout,
            depth,
            ..SpawnPlan::default()
        }
    }

    /// Can this plan ever spawn anything?
    pub fn is_live(&self) -> bool {
        self.p_spawn > 0.0 && self.fanout >= 1 && self.depth >= 1
    }

    /// The `TaskNode` every spawned task carries.
    fn node(&self) -> TaskNode {
        TaskNode {
            op: if self.task_dur_s > 0.0 {
                OpKind::Sleep
            } else {
                OpKind::Noop
            },
            flops: 0.0,
            out_bytes: self.out_bytes,
            input_bytes: 0,
            dur_override: Some(secs(self.task_dur_s)),
        }
    }
}

/// The frozen expansion of one run: which base tasks expand, and the DFS
/// id layout of every staged (to-be-spawned) task. Built once at run
/// start; engines query it with O(1)/O(fanout) calls on the hot path.
pub struct SpawnState {
    plan: SpawnPlan,
    base_len: usize,
    total: usize,
    /// Per base task: does it expand? Empty when the plan is inert.
    expands: Vec<bool>,
    /// Per base task: first staged id of its subtree (valid iff expands).
    block_start: Vec<u32>,
    /// `stride[d]` = size of the subtree rooted at a staged task of depth
    /// `d` including itself; `stride[depth] = 1`. Index 0 unused.
    stride: Vec<u64>,
    /// Per staged task (indexed by `id - base_len`): its spawner.
    stage_parent: Vec<TaskId>,
    /// Per staged task: its depth in the spawned subtree (1..=depth).
    stage_depth: Vec<u8>,
}

impl SpawnState {
    /// Draw the run's expansion decisions: one `f64` per base task, in
    /// task-id order, from the salted spawn stream. Inert plans draw
    /// nothing (bit-identity with plan-free runs).
    pub fn for_run(dag: &Dag, plan: SpawnPlan, seed: u64) -> SpawnState {
        let base_len = dag.len();
        if !plan.is_live() {
            return SpawnState {
                plan,
                base_len,
                total: base_len,
                expands: Vec::new(),
                block_start: Vec::new(),
                stride: Vec::new(),
                stage_parent: Vec::new(),
                stage_depth: Vec::new(),
            };
        }
        let mut rng = Rng::new(seed ^ SPAWN_STREAM_SALT);
        let expands: Vec<bool> =
            (0..base_len).map(|_| rng.f64() < plan.p_spawn).collect();

        // stride[d]: staged subtree size rooted at depth d (incl. root).
        let depth = plan.depth as usize;
        let f = plan.fanout as u64;
        let mut stride = vec![0u64; depth + 1];
        stride[depth] = 1;
        for d in (1..depth).rev() {
            stride[d] = 1 + f
                .checked_mul(stride[d + 1])
                .expect("spawn plan overflows task-id space");
        }
        let per_root = f
            .checked_mul(stride[1])
            .expect("spawn plan overflows task-id space");

        let staged: u64 =
            expands.iter().filter(|&&e| e).count() as u64 * per_root;
        let total = base_len as u64 + staged;
        assert!(
            total <= u32::MAX as u64,
            "spawn plan expands past the u32 task-id space ({total} tasks)"
        );

        let mut st = SpawnState {
            plan,
            base_len,
            total: total as usize,
            expands,
            block_start: vec![0; base_len],
            stride,
            stage_parent: vec![0; staged as usize],
            stage_depth: vec![0; staged as usize],
        };
        let mut next = base_len as u32;
        for b in 0..base_len {
            if !st.expands[b] {
                continue;
            }
            st.block_start[b] = next;
            st.fill(b as TaskId, 1, next);
            next += per_root as u32;
        }
        st
    }

    /// Preorder-DFS layout: children of `parent` at depth `d` occupy
    /// `first + i*stride[d]`, each immediately followed by its subtree.
    fn fill(&mut self, parent: TaskId, d: usize, first: u32) {
        let f = self.plan.fanout;
        for i in 0..f {
            let id = first + (i as u64 * self.stride[d]) as u32;
            self.stage_parent[id as usize - self.base_len] = parent;
            self.stage_depth[id as usize - self.base_len] = d as u8;
            if d < self.plan.depth as usize {
                self.fill(id, d + 1, id + 1);
            }
        }
    }

    pub fn plan(&self) -> SpawnPlan {
        self.plan
    }

    /// Does this run ever spawn? (Live plan; expansion may still be empty
    /// if no base task drew below `p_spawn` — queries stay correct.)
    pub fn is_live(&self) -> bool {
        self.plan.is_live()
    }

    pub fn base_len(&self) -> usize {
        self.base_len
    }

    /// Base + staged task count: the length every per-task structure is
    /// sized to at run start (epoch-granularity growth — staged ids are
    /// pre-laid-out, so sizing once at the epoch open is exact).
    pub fn total_len(&self) -> usize {
        self.total
    }

    pub fn staged_len(&self) -> usize {
        self.total - self.base_len
    }

    /// Is `t` a staged (runtime-spawned) task?
    pub fn is_staged(&self, t: TaskId) -> bool {
        (t as usize) >= self.base_len
    }

    /// The spawner of staged task `t` (its sole parent).
    pub fn parent_of(&self, t: TaskId) -> TaskId {
        self.stage_parent[t as usize - self.base_len]
    }

    /// The `TaskNode` of staged task `t` (all staged tasks share the
    /// plan's shape).
    pub fn node(&self, _t: TaskId) -> TaskNode {
        self.plan.node()
    }

    /// Children spawned when `t` completes. Empty for non-expanding base
    /// tasks, terminal-depth staged tasks, and inert plans (no alloc).
    pub fn spawned_children(&self, t: TaskId) -> Vec<TaskId> {
        if self.expands.is_empty() {
            return Vec::new();
        }
        let f = self.plan.fanout as usize;
        if (t as usize) < self.base_len {
            if !self.expands[t as usize] {
                return Vec::new();
            }
            let s = self.block_start[t as usize];
            (0..f).map(|i| s + (i as u64 * self.stride[1]) as u32).collect()
        } else {
            let d = self.stage_depth[t as usize - self.base_len] as usize;
            if d >= self.plan.depth as usize {
                return Vec::new();
            }
            let first = t + 1;
            (0..f)
                .map(|i| first + (i as u64 * self.stride[d + 1]) as u32)
                .collect()
        }
    }

    /// The contiguous staged block that can never run once `t` fails:
    /// `t`'s entire staged subtree (empty for non-expanding tasks).
    fn staged_block_of(&self, t: TaskId) -> (u32, u64) {
        if self.expands.is_empty() {
            return (0, 0);
        }
        if (t as usize) < self.base_len {
            if !self.expands[t as usize] {
                return (0, 0);
            }
            let per_root = self.plan.fanout as u64 * self.stride[1];
            (self.block_start[t as usize], per_root)
        } else {
            let d = self.stage_depth[t as usize - self.base_len] as usize;
            (t + 1, self.stride[d] - 1)
        }
    }

    /// Sink count of the expanded DAG: base sinks that don't expand, plus
    /// `fanout^depth` terminal staged tasks per expanding base task.
    /// Matches `pre_expand(..).sinks().len()` exactly (unit-tested).
    pub fn sinks_after(&self, dag: &Dag) -> usize {
        if self.expands.is_empty() {
            return dag.sinks().len();
        }
        let still_sinks = dag
            .sinks()
            .iter()
            .filter(|&&s| !self.expands[s as usize])
            .count();
        let expanding = self.expands.iter().filter(|&&e| e).count();
        let terminals = (self.plan.fanout as u64)
            .checked_pow(self.plan.depth)
            .expect("spawn plan overflows sink count") as usize;
        still_sinks + expanding * terminals
    }

    /// Spawn-aware failure cascade: like
    /// [`faults::propagate_failures`], but a failed task additionally
    /// dooms its staged subtree (which can never spawn). Equals the plain
    /// cascade over the pre-expanded DAG (the differential suite's
    /// outcome check). Idempotent; returns only newly-failed counts.
    pub fn propagate_failures(
        &self,
        dag: &Dag,
        direct: &[TaskId],
        outcome: &mut [TaskOutcome],
    ) -> u64 {
        if !self.is_live() {
            return faults::propagate_failures(dag, direct, outcome);
        }
        let mut newly = 0u64;
        let mut stack: Vec<TaskId> = direct.to_vec();
        while let Some(t) = stack.pop() {
            if outcome[t as usize] == TaskOutcome::Failed {
                continue;
            }
            outcome[t as usize] = TaskOutcome::Failed;
            newly += 1;
            let (start, count) = self.staged_block_of(t);
            for s in start as u64..start as u64 + count {
                let o = &mut outcome[s as usize];
                if *o != TaskOutcome::Failed {
                    *o = TaskOutcome::Failed;
                    newly += 1;
                }
            }
            if (t as usize) < self.base_len {
                for &c in dag.children(t) {
                    if outcome[c as usize] != TaskOutcome::Failed {
                        stack.push(c);
                    }
                }
            }
        }
        newly
    }

    /// Materialize the expansion as a staged-append delta over `dag`
    /// (pushed in id order, so per-parent child order matches dynamic
    /// dispatch order exactly).
    pub fn delta(&self, dag: &Dag) -> DagDelta {
        let mut delta = DagDelta::new(dag);
        for s in self.base_len..self.total {
            let id = delta.push(self.parent_of(s as TaskId), self.plan.node());
            debug_assert_eq!(id as usize, s);
        }
        delta
    }
}

/// The statically pre-expanded equivalent of running `plan` dynamically
/// on `dag` with `seed`: the differential suite's reference DAG.
pub fn pre_expand(dag: &Dag, plan: SpawnPlan, seed: u64) -> Dag {
    let spawn = SpawnState::for_run(dag, plan, seed);
    dag.sealed_with(&spawn.delta(dag))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::DagBuilder;

    fn diamond() -> Dag {
        let mut b = DagBuilder::new("d");
        let a = b.task("a", OpKind::Generic, 1e6, 100);
        let x = b.task("x", OpKind::Generic, 1e6, 100);
        let y = b.task("y", OpKind::Generic, 1e6, 100);
        let d = b.task("d", OpKind::Generic, 1e6, 100);
        b.edge(a, x).edge(a, y).edge(x, d).edge(y, d);
        b.build().unwrap()
    }

    #[test]
    fn inert_plans_draw_nothing_and_stage_nothing() {
        let dag = diamond();
        let st = SpawnState::for_run(&dag, SpawnPlan::default(), 7);
        assert!(!st.is_live());
        assert_eq!(st.total_len(), dag.len());
        assert_eq!(st.staged_len(), 0);
        assert_eq!(st.sinks_after(&dag), dag.sinks().len());
        for t in 0..dag.len() as TaskId {
            assert!(st.spawned_children(t).is_empty());
        }
    }

    #[test]
    fn expansion_is_a_pure_function_of_plan_and_seed() {
        let dag = diamond();
        let plan = SpawnPlan::recursive(0.7, 2, 2);
        let a = SpawnState::for_run(&dag, plan, 11);
        let b = SpawnState::for_run(&dag, plan, 11);
        assert_eq!(a.total_len(), b.total_len());
        for t in 0..a.total_len() as TaskId {
            assert_eq!(a.spawned_children(t), b.spawned_children(t));
        }
        // A different seed draws a (generally) different expansion.
        let c = SpawnState::for_run(&dag, SpawnPlan::recursive(0.5, 2, 2), 1);
        let d = SpawnState::for_run(&dag, SpawnPlan::recursive(0.5, 2, 2), 2);
        assert!(
            (0..dag.len()).any(|t| {
                c.spawned_children(t as TaskId)
                    != d.spawned_children(t as TaskId)
            }) || c.staged_len() == d.staged_len()
        );
    }

    #[test]
    fn dfs_layout_is_contiguous_per_expanding_task() {
        let dag = diamond();
        // p = 1: every base task expands, fanout 2, depth 2 → each base
        // task owns 2 + 4 = 6 staged ids.
        let st = SpawnState::for_run(&dag, SpawnPlan::recursive(1.0, 2, 2), 3);
        assert_eq!(st.staged_len(), 4 * 6);
        assert_eq!(st.total_len(), 4 + 24);
        for b in 0..4u32 {
            let kids = st.spawned_children(b);
            assert_eq!(kids.len(), 2);
            let block0 = 4 + b * 6;
            assert_eq!(kids, vec![block0, block0 + 3]);
            for &k in &kids {
                assert_eq!(st.parent_of(k), b);
                let gk = st.spawned_children(k);
                assert_eq!(gk, vec![k + 1, k + 2]);
                for &g in &gk {
                    assert_eq!(st.parent_of(g), k);
                    assert!(st.spawned_children(g).is_empty());
                }
            }
        }
    }

    #[test]
    fn sinks_after_matches_the_pre_expanded_dag() {
        let dag = diamond();
        for (p, f, d, seed) in
            [(1.0, 2, 2, 3u64), (0.5, 3, 1, 9), (0.25, 1, 4, 5), (0.0, 2, 2, 1)]
        {
            let plan = SpawnPlan::recursive(p, f, d);
            let st = SpawnState::for_run(&dag, plan, seed);
            let expanded = pre_expand(&dag, plan, seed);
            assert_eq!(st.total_len(), expanded.len());
            assert_eq!(st.sinks_after(&dag), expanded.sinks().len());
        }
    }

    #[test]
    fn pre_expanded_dag_wires_staged_parents_and_child_order() {
        let dag = diamond();
        let plan = SpawnPlan::recursive(1.0, 2, 2);
        let st = SpawnState::for_run(&dag, plan, 3);
        let exp = pre_expand(&dag, plan, 3);
        assert_eq!(exp.len(), st.total_len());
        // Base structure is untouched: same parents, leaves, per-node
        // parent order.
        for t in 0..dag.len() as TaskId {
            assert_eq!(exp.parents(t), dag.parents(t));
        }
        assert_eq!(exp.leaves(), dag.leaves());
        // Sealed children = base children first, then staged in id order.
        for t in 0..dag.len() as TaskId {
            let mut want: Vec<TaskId> = dag.children(t).to_vec();
            want.extend(st.spawned_children(t));
            assert_eq!(exp.children(t), &want[..]);
        }
        // Staged tasks: single parent = spawner; children per layout.
        for s in dag.len() as TaskId..exp.len() as TaskId {
            assert_eq!(exp.parents(s), &[st.parent_of(s)][..]);
            assert_eq!(exp.children(s), &st.spawned_children(s)[..]);
            assert_eq!(exp.task(s).out_bytes, plan.out_bytes);
        }
    }

    #[test]
    fn failure_cascade_matches_the_pre_expanded_cascade() {
        let dag = diamond();
        let plan = SpawnPlan::recursive(1.0, 2, 2);
        let st = SpawnState::for_run(&dag, plan, 3);
        let exp = pre_expand(&dag, plan, 3);
        for direct in [vec![0u32], vec![1], vec![3], vec![4], vec![1, 2]] {
            let mut dy = vec![TaskOutcome::Completed; st.total_len()];
            let mut pre = vec![TaskOutcome::Completed; exp.len()];
            let n_dy = st.propagate_failures(&dag, &direct, &mut dy);
            let n_pre = faults::propagate_failures(&exp, &direct, &mut pre);
            assert_eq!(n_dy, n_pre, "cascade count for {direct:?}");
            assert_eq!(dy, pre, "cascade set for {direct:?}");
        }
    }

    #[test]
    fn zero_rate_plan_equals_plan_free_expansion() {
        let dag = diamond();
        let exp = pre_expand(&dag, SpawnPlan::default(), 42);
        assert_eq!(exp.len(), dag.len());
        assert_eq!(exp.sinks(), dag.sinks());
        for t in 0..dag.len() as TaskId {
            assert_eq!(exp.children(t), dag.children(t));
            assert_eq!(exp.parents(t), dag.parents(t));
        }
    }
}
