//! DAG substrate: the task graph every engine executes.
//!
//! Mirrors the Dask task-graph role in the paper (§3.5): workload
//! generators in [`crate::workloads`] build a [`Dag`], the static-schedule
//! generator partitions it, and engines (Wukong, numpywren, Dask models,
//! plus the real engine) execute it.

pub mod graph;
pub mod spawn;
pub mod task;

pub use graph::{Dag, DagBuilder, DagDelta};
pub use spawn::{pre_expand, SpawnPlan, SpawnState, SPAWN_STREAM_SALT};
pub use task::{OpKind, TaskId, TaskNode};
