//! Task nodes: op kind and cost-model inputs.
//!
//! Adjacency (parents/children) and task names live in the [`super::Dag`]
//! container's CSR arrays and name arena — a `TaskNode` is pure per-task
//! cost data, so a million-task DAG is one flat `Vec<TaskNode>` with no
//! per-node heap allocations.

use crate::sim::Time;

/// Task index within its [`super::Dag`].
pub type TaskId = u32;

/// What a task computes. The sim engine uses only the cost annotations;
/// the real engine maps each kind to an AOT artifact (see
/// [`crate::runtime`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// No computation (scaling microbenchmarks).
    Noop,
    /// Fixed-duration sleep (paper's injected per-task delay).
    Sleep,
    /// Tree-reduction pairwise add.
    TrAdd,
    /// Tree-reduction final scalar sum.
    TrRoot,
    /// GEMM partial-product block multiply.
    GemmBlock,
    /// GEMM multiply-accumulate chain step.
    GemmAcc,
    /// Pairwise block add (K-reduction).
    BlockAdd,
    /// TSQR leaf factorization.
    QrFactor,
    /// TSQR merge of two stacked R factors.
    QrMerge,
    /// Extract the small R factor from a [Q, R] bundle (zero-flop).
    RExtract,
    /// TSQR Q back-propagation at a leaf.
    QApplyLeaf,
    /// TSQR Q back-propagation between internal levels.
    QApplyHalf,
    /// SVD1 Gram block (Aᵀ A).
    Gram,
    /// SVD1 eigensolve of the reduced Gram matrix.
    Svd1Finish,
    /// SVC per-partition gradient.
    SvcGrad,
    /// SVC weight update.
    SvcUpdate,
    /// Anything else flops-modeled (SVD2 randomized steps etc.).
    Generic,
}

/// One node of the workload DAG (cost annotations only; adjacency and
/// the interned name are queried through [`super::Dag`]).
#[derive(Debug, Clone, Copy)]
pub struct TaskNode {
    pub op: OpKind,
    /// Floating-point work (sim compute model: `flops / gflops`).
    pub flops: f64,
    /// Size of this task's output object in bytes.
    pub out_bytes: u64,
    /// Bytes of *external input* (initial partitions in the KVS) that this
    /// task reads in addition to its parents' outputs.
    pub input_bytes: u64,
    /// Fixed-duration override (microbenchmarks / injected delays).
    pub dur_override: Option<Time>,
}

impl TaskNode {
    /// Stable KVS key for this task's output object.
    pub fn obj_key(id: TaskId) -> u64 {
        // task-id → key namespace distinct from external inputs
        0x5755_4B4F_0000_0000u64 | id as u64
    }

    /// Stable KVS key for a task's external input partition.
    pub fn input_key(id: TaskId) -> u64 {
        0x494E_5055_0000_0000u64 | id as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn obj_keys_are_distinct_namespaces() {
        assert_ne!(TaskNode::obj_key(5), TaskNode::input_key(5));
        assert_ne!(TaskNode::obj_key(1), TaskNode::obj_key(2));
    }
}
