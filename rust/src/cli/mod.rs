//! Minimal command-line parser (clap is not in the offline crate set).
//!
//! Grammar: `wukong <command> [positional...] [--flag] [--key value]
//! [--set a.b=c ...]`. Options in [`VALUED`] consume the next argument
//! (missing value = error); any other `--name` is collected as a boolean
//! flag and validated by the command handlers; `--set` may repeat.

use std::collections::BTreeMap;

/// Options that take a value (everything else after `--` is a flag).
pub const VALUED: &[&str] =
    &["config", "runs", "seed", "out", "engine", "threads", "diff"];

/// Parsed command line.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    pub flags: Vec<String>,
    pub options: BTreeMap<String, String>,
    /// Repeated `--set key=value` config overrides.
    pub sets: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = it.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name == "set" {
                    let kv = it
                        .next()
                        .ok_or_else(|| "--set needs key=value".to_string())?;
                    let (k, v) = kv
                        .split_once('=')
                        .ok_or_else(|| format!("--set {kv:?}: expected key=value"))?;
                    out.sets.insert(k.to_string(), v.to_string());
                } else if VALUED.contains(&name) {
                    let v = it
                        .next()
                        .ok_or_else(|| format!("--{name} needs a value"))?;
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.command.is_empty() {
                out.command = a;
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }
}

/// Top-level usage text.
pub const USAGE: &str = "\
wukong — serverless parallel computing (SoCC '20 reproduction)

USAGE:
  wukong figure <id|all> [--quick] [--threads N] [--set a.b=c ...]
                                                       regenerate a paper figure (id=all fans
                                                       the sweeps out across a thread pool;
                                                       tables are identical to --threads 1)
  wukong run <workload> [--engine <name>] [--set a.b=c ...]
                                                       run one workload on the simulator
  wukong verify [--engine a,b,...] [--runs N] [--seed S] [--threads N]
                [--large] [--verbose] [--faults] [--crashes] [--serving]
                [--dynamic]
                                                       cross-engine differential conformance:
                                                       sweeps generated DAGs (incl. irregular
                                                       shapes) through every registered engine
                                                       and a policy-knob matrix, asserting
                                                       exactly-once, completion, per-seed
                                                       determinism and the locality ordering
                                                       (Wukong KVS bytes <= stateless bytes);
                                                       --faults adds the Sec 3.6 fault axis
                                                       (p_fail x max_retries per engine):
                                                       attempts <= 1+max_retries, every task
                                                       completed xor reported-failed, and
                                                       p_fail=0 bit-identical to fault-free;
                                                       --crashes adds the durable-KVS axis
                                                       (shard-crash plans x WAL/snapshot
                                                       profiles): a crashed-and-recovered run
                                                       must be byte-identical to the
                                                       uninterrupted run modulo the recovery
                                                       meters, and p_crash=0 fully
                                                       bit-identical; --serving adds the
                                                       multi-tenant axis (arrival-plan matrix
                                                       over the shared pool): every session
                                                       conserves jobs (admitted = completed
                                                       xor failed), replays byte-identically,
                                                       and a zero-rate stream is a no-op;
                                                       --dynamic adds the runtime-spawning
                                                       axis (spawn-plan matrix per engine):
                                                       every dynamic expansion must be
                                                       byte-identical to the statically
                                                       pre-expanded equivalent DAG, and a
                                                       zero-rate plan bit-identical to
                                                       plan-free; every run is capped by a
                                                       sim event budget (livelock watchdog);
                                                       cases fan out across --threads workers
                                                       with case-ordered (byte-identical)
                                                       aggregation; --large switches to the
                                                       scale corpus tier; exits non-zero on
                                                       any violation
  wukong bench [--quick] [--engine a,b,...] [--seed S] [--out FILE]
               [--diff BASELINE.json]
                                                       million-task hot-path benchmark: sweeps
                                                       the sim engines over fan-out/chain/TSQR
                                                       DAGs plus the multi-tenant jobstream
                                                       tier, reports wall-ms, events/sec and
                                                       peak pending-event depth, and writes
                                                       BENCH_<point>.json (the perf-trajectory
                                                       point + regression baseline); --diff
                                                       compares the fresh sweep against a
                                                       baseline BENCH_*.json and exits non-zero
                                                       on a >20% events/sec drop or superlinear
                                                       sim_events growth per (engine, workload)
                                                       row (CI runs the quick sweep through
                                                       this gate every push)
  wukong serve [--quick] [--threads N] [--out FILE] [--set a.b=c ...]
                                                       multi-tenant job-stream serving: a
                                                       Poisson/trace stream of DAG jobs from
                                                       many tenants multiplexed onto one
                                                       shared Lambda pool + KVS (job-scoped
                                                       keys, warm-executor reuse, FIFO or
                                                       weighted-fair admission); prints
                                                       per-tenant p50/p99 latency, queueing
                                                       delay, executor-hours and billed cost;
                                                       --out writes the report JSON; --quick
                                                       caps the stream at 120 jobs; exits
                                                       non-zero if jobs are not conserved
  wukong dag <workload>                                print a workload DAG (DOT)
  wukong list                                          list figures + workloads
  wukong serve-real [--quick]                          real-engine demo (PJRT compute)

ENGINES:
  wukong | numpywren | pywren | dask125 | dask1000  (all behind the unified
  Engine trait; `verify` defaults to every one of them)

WORKLOADS:
  tr | gemm | tsqr | svd1 | svd2 | svc  (paper-default parameters)

OPTIONS:
  --config <file>   INI config (see configs/default.ini)
  --set a.b=c       override any config key (repeatable)
  --runs <n>        repetitions (figures) / DAG cases (verify)
  --seed <s>        base RNG seed
  --threads <n>     worker threads for figure/verify sweeps (0 = auto)
  --out <file>      output path (bench JSON)
  --diff <file>     baseline BENCH_*.json to gate against (bench)
  --quick           shrunk problem sizes (tests/smoke/bench)
  --large           scale-tier corpus (verify)
  --faults          sweep the fault axis (verify; see faults.p_fail /
                    faults.max_retries under --set for single runs)
  --crashes         sweep the durable-KVS crash-recovery axis (verify)
  --serving         sweep the multi-tenant serving axis (verify)
  --dynamic         sweep the dynamic-DAG runtime-spawning axis (verify;
                    see spawn.* under --set for single runs)
  --verbose         per-case lines (verify; streamed live with
                    --threads 1, printed in case order otherwise)

CONFIG KEYS (selection; any key accepts --set):
  faults.p_fail / faults.max_retries      Sec 3.6 executor-fault plan
                                          (p_fail must be in [0, 1])
  crashes.p_crash / crashes.max_crashes   per-op shard-crash plan
                                          (p_crash must be in [0, 1])
  spawn.p_spawn                           per-task runtime-spawn probability
                                          (must be in [0, 1]; 0 = static
                                          DAG, a guaranteed bit-identical
                                          no-op)
  spawn.fanout                            children per expanding task
                                          (must be in [1, 1024])
  spawn.depth                             spawn recursion depth
                                          (must be in [1, 8])
  spawn.task_dur_s                        spawned-task duration (s; must be
                                          non-negative; 0 = no-op subtasks)
  spawn.out_bytes                         spawned-task output size (bytes)
  storage.wal_fsync_s                     synchronous WAL append cost (s)
  storage.snapshot_every_ops              snapshot cadence in WAL records
                                          (0 = never snapshot)
  storage.replay_op_s                     per-op WAL/snapshot replay cost
  storage.recovery_base_s                 fixed per-recovery stall
  arrival.mode                            serve job stream: poisson | trace
  arrival.rate                            Poisson arrival rate (jobs/s;
                                          must be non-negative; 0 = empty
                                          stream, a guaranteed no-op)
  arrival.jobs                            jobs in the stream (default 1000)
  arrival.trace_gap_s                     deterministic trace inter-arrival
  tenants.count                           tenants sharing the pool
  tenants.policy                          admission order: fifo | wfair
  tenants.weight_skew                     wfair weight slope across tenants
                                          (tenant i weighs 1 + skew*i)
  event_budget                            sim event ceiling (0 = none;
                                          verify always sets a watchdog)
  sim.calendar                            event-calendar structure:
                                          bucket (default) | heap; both
                                          produce byte-identical runs
  sim.bucket_width_us                     pin the bucket width in sim
                                          microseconds (0 = auto-size
                                          from the observed event-time
                                          spread; ignored by heap)
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_command_and_positional() {
        let a = parse("figure fig14 --quick");
        assert_eq!(a.command, "figure");
        assert_eq!(a.positional, vec!["fig14"]);
        assert!(a.flag("quick"));
    }

    #[test]
    fn parses_sets_and_options() {
        let a = parse(
            "run tsqr --engine dask125 --set lambda.gflops=30 --set seed=1",
        );
        assert_eq!(a.opt("engine"), Some("dask125"));
        assert_eq!(a.sets.get("lambda.gflops").map(String::as_str), Some("30"));
        assert_eq!(a.sets.len(), 2);
    }

    #[test]
    fn rejects_malformed_set() {
        assert!(Args::parse(
            ["figure".into(), "--set".into(), "nope".into()].into_iter()
        )
        .is_err());
    }

    #[test]
    fn missing_option_value_is_error() {
        assert!(
            Args::parse(["run".into(), "--engine".into()].into_iter()).is_err()
        );
    }

    #[test]
    fn every_valued_option_without_value_is_an_error() {
        for name in VALUED {
            let err = Args::parse(["run".into(), format!("--{name}")])
                .expect_err(name);
            assert!(err.contains(name), "{name}: {err}");
            assert!(err.contains("needs a value"), "{name}: {err}");
        }
    }

    #[test]
    fn every_valued_option_round_trips() {
        let argv: Vec<String> = std::iter::once("run".to_string())
            .chain(VALUED.iter().flat_map(|name| {
                [format!("--{name}"), format!("val-{name}")]
            }))
            .collect();
        let a = Args::parse(argv).unwrap();
        for name in VALUED {
            assert_eq!(a.opt(name), Some(format!("val-{name}").as_str()));
        }
        assert_eq!(a.options.len(), VALUED.len());
    }

    #[test]
    fn set_without_any_argument_is_an_error() {
        let err = Args::parse(["figure".into(), "--set".into()]).unwrap_err();
        assert!(err.contains("needs key=value"), "{err}");
    }

    #[test]
    fn set_value_may_itself_contain_equals() {
        let a = parse("run --set a.b=c=d");
        assert_eq!(a.sets.get("a.b").map(String::as_str), Some("c=d"));
    }

    #[test]
    fn repeated_set_keys_last_one_wins() {
        let a = parse("run --set seed=1 --set seed=2");
        assert_eq!(a.sets.get("seed").map(String::as_str), Some("2"));
        assert_eq!(a.sets.len(), 1);
    }

    #[test]
    fn unknown_double_dash_names_are_collected_as_flags() {
        // Unknown flags are *not* parse errors: command handlers decide
        // (e.g. `verify --verbose`, future flags stay forward-compatible).
        let a = parse("verify --verbose --definitely-unknown");
        assert!(a.flag("verbose"));
        assert!(a.flag("definitely-unknown"));
        assert!(!a.flag("quick"));
        assert!(a.options.is_empty());
    }

    #[test]
    fn first_bare_word_is_command_rest_are_positional() {
        let a = parse("figure fig14 fig15 --quick extra");
        assert_eq!(a.command, "figure");
        assert_eq!(a.positional, vec!["fig14", "fig15", "extra"]);
    }

    #[test]
    fn empty_argv_parses_to_empty_command() {
        let a = Args::parse(Vec::<String>::new()).unwrap();
        assert_eq!(a.command, "");
        assert!(a.positional.is_empty() && a.flags.is_empty());
    }
}
