//! Minimal command-line parser (clap is not in the offline crate set).
//!
//! Grammar: `wukong <command> [positional...] [--flag] [--key value]
//! [--set a.b=c ...]`. Unknown flags are errors; `--set` may repeat.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    pub flags: Vec<String>,
    pub options: BTreeMap<String, String>,
    /// Repeated `--set key=value` config overrides.
    pub sets: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = it.into_iter().peekable();
        // options that take a value
        const VALUED: &[&str] = &["config", "runs", "seed", "out", "engine"];
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name == "set" {
                    let kv = it
                        .next()
                        .ok_or_else(|| "--set needs key=value".to_string())?;
                    let (k, v) = kv
                        .split_once('=')
                        .ok_or_else(|| format!("--set {kv:?}: expected key=value"))?;
                    out.sets.insert(k.to_string(), v.to_string());
                } else if VALUED.contains(&name) {
                    let v = it
                        .next()
                        .ok_or_else(|| format!("--{name} needs a value"))?;
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.command.is_empty() {
                out.command = a;
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }
}

/// Top-level usage text.
pub const USAGE: &str = "\
wukong — serverless parallel computing (SoCC '20 reproduction)

USAGE:
  wukong figure <id|all> [--quick] [--set a.b=c ...]   regenerate a paper figure
  wukong run <workload> [--engine wukong|numpywren|dask1000|dask125]
                         [--set a.b=c ...]             run one workload on the simulator
  wukong dag <workload>                                print a workload DAG (DOT)
  wukong list                                          list figures + workloads
  wukong serve [--quick]                               real-engine demo (PJRT compute)

WORKLOADS:
  tr | gemm | tsqr | svd1 | svd2 | svc  (paper-default parameters)

OPTIONS:
  --config <file>   INI config (see configs/default.ini)
  --set a.b=c       override any config key (repeatable)
  --quick           shrunk problem sizes (tests/smoke)
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_command_and_positional() {
        let a = parse("figure fig14 --quick");
        assert_eq!(a.command, "figure");
        assert_eq!(a.positional, vec!["fig14"]);
        assert!(a.flag("quick"));
    }

    #[test]
    fn parses_sets_and_options() {
        let a = parse(
            "run tsqr --engine dask125 --set lambda.gflops=30 --set seed=1",
        );
        assert_eq!(a.opt("engine"), Some("dask125"));
        assert_eq!(a.sets.get("lambda.gflops").map(String::as_str), Some("30"));
        assert_eq!(a.sets.len(), 2);
    }

    #[test]
    fn rejects_malformed_set() {
        assert!(Args::parse(
            ["figure".into(), "--set".into(), "nope".into()].into_iter()
        )
        .is_err());
    }

    #[test]
    fn missing_option_value_is_error() {
        assert!(
            Args::parse(["run".into(), "--engine".into()].into_iter()).is_err()
        );
    }
}
