//! Concurrency timelines: executor-count deltas → vCPU/cost-over-time
//! series (Figs. 19–20).

use crate::sim::{to_secs, Time};

/// Event-sourced concurrency counter.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Timeline {
    deltas: Vec<(Time, i64)>,
}

impl Timeline {
    /// Record a concurrency change (`+1` executor start, `-1` finish).
    pub fn add(&mut self, t: Time, delta: i64) {
        self.deltas.push((t, delta));
    }

    fn sorted(&self) -> Vec<(Time, i64)> {
        let mut d = self.deltas.clone();
        d.sort_by_key(|&(t, _)| t);
        d
    }

    /// Peak simultaneous count.
    pub fn peak(&self) -> i64 {
        let mut cur = 0i64;
        let mut peak = 0i64;
        for (_, d) in self.sorted() {
            cur += d;
            peak = peak.max(cur);
        }
        peak
    }

    /// Integral of the count over time, in unit-seconds (×vCPUs/executor
    /// gives core-seconds, Fig. 17).
    pub fn integral_s(&self) -> f64 {
        let d = self.sorted();
        let mut cur = 0i64;
        let mut last = 0 as Time;
        let mut acc = 0.0;
        for (t, delta) in d {
            acc += cur as f64 * to_secs(t - last);
            cur += delta;
            last = t;
        }
        acc
    }

    /// Step series sampled at `step` intervals from 0 to `end`:
    /// `(t_seconds, active_count)`.
    pub fn series(&self, step: Time, end: Time) -> Vec<(f64, i64)> {
        let d = self.sorted();
        let mut out = Vec::new();
        let mut cur = 0i64;
        let mut i = 0;
        let mut t = 0 as Time;
        loop {
            while i < d.len() && d[i].0 <= t {
                cur += d[i].1;
                i += 1;
            }
            out.push((to_secs(t), cur));
            if t >= end {
                break;
            }
            t = (t + step).min(end);
        }
        out
    }

    pub fn is_empty(&self) -> bool {
        self.deltas.is_empty()
    }

    /// Merge another timeline in (multi-engine aggregation).
    pub fn merge(&mut self, other: &Timeline) {
        self.deltas.extend_from_slice(&other.deltas);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::secs;

    #[test]
    fn peak_counts_overlap() {
        let mut tl = Timeline::default();
        tl.add(secs(0.0), 1);
        tl.add(secs(1.0), 1);
        tl.add(secs(2.0), -1);
        tl.add(secs(3.0), -1);
        assert_eq!(tl.peak(), 2);
    }

    #[test]
    fn integral_is_area_under_curve() {
        let mut tl = Timeline::default();
        tl.add(secs(0.0), 2); // 2 executors for 5 s = 10 unit-seconds
        tl.add(secs(5.0), -2);
        assert!((tl.integral_s() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn series_steps() {
        let mut tl = Timeline::default();
        tl.add(secs(0.0), 1);
        tl.add(secs(2.0), -1);
        let s = tl.series(secs(1.0), secs(3.0));
        assert_eq!(s.len(), 4);
        assert_eq!(s[0].1, 1);
        assert_eq!(s[1].1, 1);
        assert_eq!(s[2].1, 0);
        assert_eq!(s[3].1, 0);
    }

    #[test]
    fn out_of_order_adds_are_sorted() {
        let mut tl = Timeline::default();
        tl.add(secs(5.0), -1);
        tl.add(secs(0.0), 1);
        assert_eq!(tl.peak(), 1);
        assert!((tl.integral_s() - 5.0).abs() < 1e-9);
    }
}
