//! Run metrics: makespan, I/O, time breakdowns (Fig. 22), vCPU/cost
//! timelines (Figs. 19–20), CPU-seconds (Fig. 17) and billing (Fig. 18).

pub mod timeline;

use crate::platform::{Billing, Prices};
use crate::storage::{DurabilityMetrics, KvsMetrics};
pub use timeline::Timeline;

/// Terminal per-task resolution under a fault plan (§3.6). Every task
/// ends in exactly one of these states — the conformance harness
/// asserts the partition is total (nothing silently lost) and that
/// `Completed` tasks executed effectively-once.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskOutcome {
    /// The task's body ran to completion (exactly one effective run).
    Completed,
    /// The task was reported failed: its own retry budget was exhausted,
    /// or an ancestor's was — either way it never produced output.
    Failed,
}

/// Aggregate seconds per activity category (paper Fig. 22's bars).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Breakdown {
    /// Invoking other executors (incl. delegated fan-outs).
    pub invoke_s: f64,
    /// Reading intermediate objects from the KVS.
    pub kvs_read_s: f64,
    /// Writing intermediate objects to the KVS.
    pub kvs_write_s: f64,
    /// Executing task bodies.
    pub execute_s: f64,
    /// Serialization/deserialization.
    pub serde_s: f64,
    /// Publishing messages (MDS/counter/proxy traffic).
    pub publish_s: f64,
}

impl Breakdown {
    pub fn total(&self) -> f64 {
        self.invoke_s
            + self.kvs_read_s
            + self.kvs_write_s
            + self.execute_s
            + self.serde_s
            + self.publish_s
    }
}

/// Everything one engine run reports.
///
/// `PartialEq` is part of the determinism contract: two runs of any
/// sim-path engine with the same DAG, config and seed must produce
/// *identical* metrics (asserted by `wukong verify` and
/// `rust/tests/conformance.rs`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RunMetrics {
    /// End-to-end job time (s).
    pub makespan_s: f64,
    /// Per-category aggregate time across all executors.
    pub breakdown: Breakdown,
    /// Exact KVS byte/op counters.
    pub kvs: KvsMetrics,
    /// Executor-count timeline (×vCPUs per executor for vCPU plots).
    pub timeline: Timeline,
    /// Tenant-side billing meter.
    pub billing: Billing,
    /// Lambda invocations (or worker-task dispatches for serverful).
    pub invocations: u64,
    /// Tasks executed (must equal the DAG size exactly — tested).
    pub tasks_executed: u64,
    /// Distinct executors used.
    pub executors_used: u64,
    /// Peak concurrent executors.
    pub peak_concurrency: usize,
    /// Total active-executor core-seconds (Fig. 17).
    pub cpu_seconds: f64,
    /// Executors that died with an exhausted retry budget (§3.6): when
    /// nonzero the job is failed, mirroring AWS's retry-twice contract.
    pub failed_executors: u64,
    /// Per-task execution counts, indexed by `TaskId`. Every engine fills
    /// this (len == DAG size); the conformance harness asserts each entry
    /// is exactly 1 (the paper's exactly-once claim, §3.3).
    pub per_task_exec: Vec<u32>,
    /// Tasks whose terminal outcome is [`TaskOutcome::Failed`] — directly
    /// failed tasks plus everything downstream of them. Fault-free runs
    /// report 0; `tasks_executed + failed_tasks == dag.len()` always.
    pub failed_tasks: u64,
    /// Per-task execution *attempts* (incl. failed ones), indexed by
    /// `TaskId`. Bounded by `1 + max_retries` under any fault plan;
    /// equal to `per_task_exec` when no faults fire.
    pub per_task_attempts: Vec<u32>,
    /// Terminal per-task outcome, indexed by `TaskId` (len == DAG size).
    pub per_task_outcome: Vec<TaskOutcome>,
    /// Durability-tier meters (KVS + MDS WAL/snapshot/recovery). The
    /// WAL/snapshot fields are data-plane (identical between a crashed
    /// and a crash-free run over the same ops); `recoveries`,
    /// `replayed_ops` and `stall_s` are the *only* metrics a shard
    /// crash may perturb — `verify --crashes` asserts exactly that.
    pub durability: DurabilityMetrics,
    /// Inline task-payload bytes passed through the proxy's invoker
    /// pool (wukong only; 0 for engines without a proxy).
    pub proxy_inline_bytes: u64,
}

impl RunMetrics {
    /// Total dollars under the default price book.
    pub fn dollars(&self) -> f64 {
        self.billing.total(&Prices::default())
    }

    /// Executor-hours consumed by the run: the executor-count timeline's
    /// area (executor-seconds) over 3600. The serving layer rolls this
    /// up per tenant for capacity/billing reports.
    pub fn executor_hours(&self) -> f64 {
        self.timeline.integral_s() / 3600.0
    }
}

/// Normalized metrics plus DES meters for one simulator-backed run —
/// shared by the Wukong engine (`coordinator::WukongReport`) and every
/// baseline (`baselines::BaselineReport`), so a meter added for
/// `wukong bench` is plumbed exactly once.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub metrics: RunMetrics,
    /// Events processed by the DES (L3 perf: events/sec).
    pub sim_events: u64,
    /// High-water mark of the pending-event calendar depth.
    pub peak_pending: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn executor_hours_is_timeline_area_over_3600() {
        let mut m = RunMetrics::default();
        assert_eq!(m.executor_hours(), 0.0);
        // 2 executors for 1800 virtual seconds = 1 executor-hour.
        m.timeline.add(0, 2);
        m.timeline.add(crate::sim::secs(1800.0), -2);
        assert!((m.executor_hours() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn breakdown_total_sums_categories() {
        let b = Breakdown {
            invoke_s: 1.0,
            kvs_read_s: 2.0,
            kvs_write_s: 3.0,
            execute_s: 4.0,
            serde_s: 5.0,
            publish_s: 6.0,
        };
        assert_eq!(b.total(), 21.0);
    }
}
