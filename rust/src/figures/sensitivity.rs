//! Extension: the sensitivity analysis the paper *omits* (§4.1: "A
//! sensitivity analysis of these two configuration knobs is omitted due
//! to space constraint") — Wukong exposes exactly two user knobs, the
//! input partition size and the Fargate (KVS shard) count; these sweeps
//! quantify both, plus the clustering-threshold `t` ablation.

use crate::config::Config;
use crate::coordinator::run_wukong;
use crate::util::table::Table;
use crate::workloads::{svd, tsqr};

use super::Figure;

/// `sens1`: input partition size (TSQR leaf block rows) at fixed problem
/// size. Small partitions ⇒ more parallelism but more invocations and
/// counter traffic; large partitions ⇒ fewer, longer tasks.
pub fn sens_partition(cfg: &Config, quick: bool) -> Figure {
    let rows: usize = if quick { 1 << 18 } else { 1 << 22 };
    let blocks: &[usize] = if quick {
        &[1024, 4096]
    } else {
        &[1024, 2048, 4096, 8192, 16384]
    };
    let mut t = Table::new(vec![
        "block rows",
        "leaves",
        "tasks",
        "makespan (s)",
        "executors",
        "cost ($)",
    ]);
    for &br in blocks {
        let p = tsqr::TsqrParams {
            rows,
            cols: 128,
            block_rows: br,
            with_q: false,
        };
        let dag = tsqr::dag(p);
        let mut c = cfg.clone();
        c.wukong.clustering_threshold = 1 << 20;
        let m = run_wukong(&dag, &c, cfg.seed).metrics;
        t.row(vec![
            br.to_string(),
            p.nb().to_string(),
            dag.len().to_string(),
            format!("{:.2}", m.makespan_s),
            m.executors_used.to_string(),
            format!("{:.4}", m.dollars()),
        ]);
    }
    Figure {
        id: "sens1",
        caption: "Sensitivity (extension): input partition size — \
                  parallelism vs invocation overhead",
        table: t,
    }
}

/// `sens2`: Fargate storage-cluster size (KVS shard count) on the
/// I/O-heavy SVD2 workload. The paper picked 75 nodes as "performant and
/// cost-effective"; this sweep shows the knee.
pub fn sens_shards(cfg: &Config, quick: bool) -> Figure {
    let shards: &[usize] = if quick {
        &[1, 25]
    } else {
        &[1, 5, 25, 75, 150, 300]
    };
    let dag = svd::svd2(svd::Svd2Params::paper(if quick { 10 } else { 50 }));
    let mut t = Table::new(vec![
        "fargate shards",
        "makespan (s)",
        "KVS busy (s)",
        "cost ($)",
    ]);
    for &n in shards {
        let mut c = cfg.clone();
        c.wukong.clustering_threshold = 1 << 20;
        c.storage.n_shards = n;
        let r = run_wukong(&dag, &c, cfg.seed);
        let m = r.metrics;
        t.row(vec![
            n.to_string(),
            format!("{:.2}", m.makespan_s),
            format!(
                "{:.1}",
                m.breakdown.kvs_read_s + m.breakdown.kvs_write_s
            ),
            format!("{:.4}", m.dollars()),
        ]);
    }
    Figure {
        id: "sens2",
        caption: "Sensitivity (extension): Fargate shard count — \
                  diminishing returns past the bandwidth knee, rising cost",
        table: t,
    }
}

/// `sens3`: the clustering threshold `t` (§3.3's example is 200 MB) on
/// SVD2 — too high and big objects go through the KVS; too low adds
/// delayed-I/O waits for tiny objects.
pub fn sens_threshold(cfg: &Config, quick: bool) -> Figure {
    let ts: &[(u64, &str)] = &[
        (64 * 1024, "64 KB"),
        (1 << 20, "1 MB"),
        (16 << 20, "16 MB"),
        (200 << 20, "200 MB"),
        (u64::MAX, "inf (off)"),
    ];
    let dag = svd::svd2(svd::Svd2Params::paper(if quick { 10 } else { 50 }));
    let mut t = Table::new(vec![
        "threshold t",
        "makespan (s)",
        "KVS written",
        "executors",
    ]);
    for &(thr, label) in ts {
        let mut c = cfg.clone();
        c.wukong.clustering_threshold = thr;
        let m = run_wukong(&dag, &c, cfg.seed).metrics;
        t.row(vec![
            label.to_string(),
            format!("{:.2}", m.makespan_s),
            crate::util::stats::human_bytes(m.kvs.bytes_written as f64),
            m.executors_used.to_string(),
        ]);
    }
    Figure {
        id: "sens3",
        caption: "Sensitivity (extension): clustering threshold t — the \
                  knob the paper cites at 200 MB",
        table: t,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_sweep_runs() {
        let f = sens_partition(&Config::default(), true);
        assert_eq!(f.table.n_rows(), 2);
    }

    #[test]
    fn shard_sweep_shows_diminishing_returns() {
        let f = sens_shards(&Config::default(), true);
        // more shards must not be slower
        let rows: Vec<f64> = f
            .table
            .render()
            .lines()
            .skip(2)
            .map(|l| l.split('|').nth(2).unwrap().trim().parse().unwrap())
            .collect();
        assert!(rows[1] <= rows[0] * 1.05, "{rows:?}");
    }

    #[test]
    fn threshold_extremes_differ_in_io() {
        let f = sens_threshold(&Config::default(), true);
        assert_eq!(f.table.n_rows(), 5);
    }
}
