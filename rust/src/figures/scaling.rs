//! Figures 2 and 21: scale-out and strong/weak/serverless scaling.

use crate::baselines::{pywren_launch_time, run_pywren};
use crate::config::Config;
use crate::coordinator::run_wukong;
use crate::sim::secs;
use crate::util::table::Table;
use crate::workloads::micro;

use super::{avg, Figure};

/// Fig. 2: (Num)PyWren's ability to schedule N no-op tasks on N Lambdas,
/// vs Wukong on the same workload.
pub fn fig2(cfg: &Config, quick: bool) -> Figure {
    let ns: &[usize] = if quick {
        &[100, 500]
    } else {
        &[100, 1_000, 2_000, 5_000, 10_000]
    };
    let mut t = Table::new(vec![
        "no-op tasks",
        "pywren launch (s)",
        "pywren e2e (s)",
        "wukong e2e (s)",
    ]);
    for &n in ns {
        let mut c = cfg.clone();
        c.lambda.concurrency_limit = c.lambda.concurrency_limit.max(n);
        let dag = micro::serverless(n, 0);
        let launch = pywren_launch_time(&c, n);
        let pw = avg(&c, quick, |s| run_pywren(&dag, &c, n, s).makespan_s);
        let wk = avg(&c, quick, |s| run_wukong(&dag, &c, s).metrics.makespan_s);
        t.row(vec![
            n.to_string(),
            format!("{launch:.2}"),
            format!("{pw:.2}"),
            format!("{wk:.2}"),
        ]);
    }
    Figure {
        id: "fig2",
        caption: "PyWren no-op scale-out (paper: ~2 min to 10k Lambdas; \
                  Wukong: seconds)",
        table: t,
    }
}

/// Fig. 21(a)–(l): strong / weak / serverless scaling, Wukong vs
/// (Num)PyWren, for per-task delays of 0/100/250/500 ms.
pub fn fig21(cfg: &Config, quick: bool) -> Figure {
    let delays_ms: &[u64] = if quick { &[0, 250] } else { &[0, 100, 250, 500] };
    let mut t = Table::new(vec![
        "mode",
        "delay (ms)",
        "lambdas",
        "wukong (s)",
        "pywren (s)",
    ]);
    let strong_n: &[usize] = if quick {
        &[100, 500]
    } else {
        &[500, 1_000, 2_000, 5_000]
    };
    let weak_n: &[usize] = if quick {
        &[100, 250]
    } else {
        &[250, 500, 750, 1_000]
    };
    let sls_n: &[usize] = if quick {
        &[100, 500]
    } else {
        &[1_000, 2_500, 5_000, 10_000]
    };
    let total_strong = if quick { 1_000 } else { 10_000 };

    for &d in delays_ms {
        let dur = secs(d as f64 / 1000.0);
        for &n in strong_n {
            let dag = micro::strong(total_strong, n, dur);
            let (wk, pw) = pair(cfg, quick, &dag, n);
            t.row(vec![
                "strong".into(),
                d.to_string(),
                n.to_string(),
                format!("{wk:.2}"),
                format!("{pw:.2}"),
            ]);
        }
        for &n in weak_n {
            let dag = micro::weak(n, 10, dur);
            let (wk, pw) = pair(cfg, quick, &dag, n);
            t.row(vec![
                "weak".into(),
                d.to_string(),
                n.to_string(),
                format!("{wk:.2}"),
                format!("{pw:.2}"),
            ]);
        }
        for &n in sls_n {
            let dag = micro::serverless(n, dur);
            let (wk, pw) = pair(cfg, quick, &dag, n);
            t.row(vec![
                "serverless".into(),
                d.to_string(),
                n.to_string(),
                format!("{wk:.2}"),
                format!("{pw:.2}"),
            ]);
        }
    }
    Figure {
        id: "fig21",
        caption: "Strong/weak/serverless scaling: Wukong near-ideal, \
                  (Num)PyWren degrades with Lambda count",
        table: t,
    }
}

fn pair(cfg: &Config, quick: bool, dag: &crate::dag::Dag, n: usize) -> (f64, f64) {
    let mut c = cfg.clone();
    c.lambda.concurrency_limit = c.lambda.concurrency_limit.max(n);
    let wk = avg(&c, quick, |s| run_wukong(dag, &c, s).metrics.makespan_s);
    let pw = avg(&c, quick, |s| run_pywren(dag, &c, n, s).makespan_s);
    (wk, pw)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_pywren_slower_than_wukong_at_scale() {
        let fig = fig2(&Config::default(), true);
        assert_eq!(fig.table.n_rows(), 2);
    }

    #[test]
    fn wukong_serverless_scaling_beats_pywren() {
        // The headline: N tasks on N Lambdas — Wukong ~seconds, PyWren
        // grows with N.
        let cfg = Config::default();
        let dag = micro::serverless(2_000, 0);
        let wk = run_wukong(&dag, &cfg, 1).metrics.makespan_s;
        let pw = run_pywren(&dag, &cfg, 2_000, 1).makespan_s;
        assert!(
            wk < pw,
            "wukong {wk:.2}s should beat pywren {pw:.2}s at 2k lambdas"
        );
        assert!(wk < 10.0, "wukong should scale out in seconds, got {wk:.2}");
    }

    #[test]
    fn wukong_weak_scaling_is_flat() {
        // Near-ideal weak scaling: 2x the executors, ~same makespan.
        let cfg = Config::default();
        let d1 = micro::weak(250, 10, secs(0.1));
        let d2 = micro::weak(1_000, 10, secs(0.1));
        let t1 = run_wukong(&d1, &cfg, 1).metrics.makespan_s;
        let t2 = run_wukong(&d2, &cfg, 1).metrics.makespan_s;
        assert!(
            t2 < t1 * 2.0,
            "weak scaling blew up: {t1:.2}s -> {t2:.2}s"
        );
    }
}
