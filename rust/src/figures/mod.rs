//! Figure harness: regenerates every table/figure of the paper's
//! evaluation (§4) on the simulator.
//!
//! Each `figN` function returns a [`Table`] whose rows are the same
//! series the paper plots. Absolute numbers differ (our substrate is a
//! calibrated simulator, not AWS), but the *shapes* — who wins, by what
//! factor, where crossovers fall — are the reproduction targets recorded
//! in EXPERIMENTS.md. Run via `wukong figure <id>` or `cargo bench`.

pub mod ablation;
pub mod amplification;
pub mod cost;
pub mod end_to_end;
pub mod scaling;
pub mod sensitivity;

use crate::config::Config;
use crate::util::table::Table;

/// A regenerated figure: id, caption, and the data table.
pub struct Figure {
    pub id: &'static str,
    pub caption: &'static str,
    pub table: Table,
}

/// All figure ids, in paper order.
pub fn all_ids() -> Vec<&'static str> {
    vec![
        "fig2", "fig3", "fig4", "fig9", "fig10", "fig11", "fig12", "fig13",
        "fig14", "fig15", "fig16", "fig17", "fig18", "fig19", "fig20",
        "fig21", "fig22", "fig23", "sens1", "sens2", "sens3",
    ]
}

/// Run one figure. `quick` shrinks problem sizes/repetitions (used by the
/// test suite and the smoke bench; the full sizes run in `cargo bench` /
/// the CLI).
pub fn run(id: &str, cfg: &Config, quick: bool) -> Option<Figure> {
    match id {
        "fig2" => Some(scaling::fig2(cfg, quick)),
        "fig3" => Some(amplification::fig3(cfg, quick)),
        "fig4" => Some(amplification::fig4(cfg, quick)),
        "fig9" => Some(end_to_end::fig9(cfg, quick)),
        "fig10" => Some(end_to_end::fig10(cfg, quick)),
        "fig11" => Some(end_to_end::fig11(cfg, quick)),
        "fig12" => Some(end_to_end::fig12(cfg, quick)),
        "fig13" => Some(end_to_end::fig13(cfg, quick)),
        "fig14" => Some(end_to_end::fig14(cfg, quick)),
        "fig15" => Some(end_to_end::fig15(cfg, quick)),
        "fig16" => Some(end_to_end::fig16(cfg, quick)),
        "fig17" => Some(cost::fig17(cfg, quick)),
        "fig18" => Some(cost::fig18(cfg, quick)),
        "fig19" => Some(cost::fig19(cfg, quick)),
        "fig20" => Some(cost::fig20(cfg, quick)),
        "fig21" => Some(scaling::fig21(cfg, quick)),
        "fig22" => Some(ablation::fig22(cfg, quick)),
        "fig23" => Some(ablation::fig23(cfg, quick)),
        "sens1" => Some(sensitivity::sens_partition(cfg, quick)),
        "sens2" => Some(sensitivity::sens_shards(cfg, quick)),
        "sens3" => Some(sensitivity::sens_threshold(cfg, quick)),
        _ => None,
    }
}

/// Run several figures concurrently across
/// [`crate::util::threadpool::ordered_map`], returning them in input
/// order. Every figure sweep is a pure function of `(cfg, quick)`, so
/// the fan-out changes wall time only — the rendered tables are
/// identical to a sequential `threads = 1` run (index-ordered
/// aggregation). A panic inside a figure is re-raised on the calling
/// thread after the pool drains.
pub fn run_many(
    ids: &[&'static str],
    cfg: &Config,
    quick: bool,
    threads: usize,
) -> Vec<Figure> {
    for id in ids {
        assert!(
            all_ids().contains(id),
            "unknown figure id {id:?} (validate before run_many)"
        );
    }
    let ids: Vec<&'static str> = ids.to_vec();
    let cfg = cfg.clone();
    crate::util::threadpool::ordered_map(ids.len(), threads, move |i| {
        run(ids[i], &cfg, quick).expect("id validated above")
    })
}

/// Mean of `runs` repetitions of `f(seed)`.
pub(crate) fn avg(cfg: &Config, quick: bool, mut f: impl FnMut(u64) -> f64) -> f64 {
    let runs = if quick { 1 } else { cfg.runs.max(1) };
    let mut acc = 0.0;
    for r in 0..runs {
        acc += f(cfg.seed + r as u64);
    }
    acc / runs as f64
}

pub(crate) fn fmt_b(x: f64) -> String {
    crate::util::stats::human_bytes(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_id_runs_quick() {
        let cfg = Config::default();
        for id in all_ids() {
            let fig = run(id, &cfg, true).unwrap_or_else(|| panic!("{id}"));
            assert!(!fig.table.is_empty(), "{id} produced no rows");
        }
    }

    #[test]
    fn unknown_id_is_none() {
        assert!(run("fig99", &Config::default(), true).is_none());
    }

    #[test]
    fn run_many_matches_sequential_output() {
        let cfg = Config::default();
        let ids = ["fig2", "fig3", "fig22"];
        let par = run_many(&ids, &cfg, true, 3);
        let seq = run_many(&ids, &cfg, true, 1);
        assert_eq!(par.len(), 3);
        for (p, s) in par.iter().zip(&seq) {
            assert_eq!(p.id, s.id);
            assert_eq!(p.table.render(), s.table.render());
        }
    }
}
