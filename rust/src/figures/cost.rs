//! Figures 17–20: CPU time, monetary cost, and vCPU/cost timelines.

use crate::baselines::{run_dask, run_numpywren};
use crate::config::{Config, DaskConfig};
use crate::coordinator::run_wukong;
use crate::metrics::RunMetrics;
use crate::sim::secs;
use crate::util::table::Table;
use crate::workloads::{gemm, svd, tsqr};

use super::end_to_end::{single_redis, wukong_cfg};
use super::Figure;

fn svd1_sizes(quick: bool) -> &'static [f64] {
    if quick {
        &[0.25, 1.0]
    } else {
        &[0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0]
    }
}

/// Fig. 17: SVD1 total CPU time (core-seconds).
pub fn fig17(cfg: &Config, quick: bool) -> Figure {
    let mut t = Table::new(vec![
        "rows",
        "wukong (core-s)",
        "dask-1000 (core-s)",
        "dask-125 (core-s)",
    ]);
    let wcfg = wukong_cfg(cfg);
    for &m in svd1_sizes(quick) {
        let dag = svd::svd1(svd::Svd1Params::paper(m));
        let wk = run_wukong(&dag, &wcfg, cfg.seed).metrics;
        let d1000 = run_dask(&dag, cfg, &DaskConfig::workers_1000(), cfg.seed);
        let d125 = run_dask(&dag, cfg, &DaskConfig::workers_125(), cfg.seed);
        t.row(vec![
            format!("{m}M"),
            format!("{:.0}", wk.cpu_seconds),
            format!("{:.0}", d1000.cpu_seconds),
            format!("{:.0}", d125.cpu_seconds),
        ]);
    }
    Figure {
        id: "fig17",
        caption: "SVD1 CPU time: Wukong's pay-per-use beats Dask-1000 \
                  everywhere, Dask-125 at large sizes",
        table: t,
    }
}

/// Fig. 18: SVD1 monetary cost.
pub fn fig18(cfg: &Config, quick: bool) -> Figure {
    let mut t = Table::new(vec![
        "rows",
        "wukong ($)",
        "dask-1000 ($)",
        "dask-125 ($)",
    ]);
    let wcfg = wukong_cfg(cfg);
    for &m in svd1_sizes(quick) {
        let dag = svd::svd1(svd::Svd1Params::paper(m));
        let wk = run_wukong(&dag, &wcfg, cfg.seed).metrics;
        let d1000 = run_dask(&dag, cfg, &DaskConfig::workers_1000(), cfg.seed);
        let d125 = run_dask(&dag, cfg, &DaskConfig::workers_125(), cfg.seed);
        t.row(vec![
            format!("{m}M"),
            format!("{:.4}", wk.dollars()),
            format!("{:.4}", d1000.dollars()),
            format!("{:.4}", d125.dollars()),
        ]);
    }
    Figure {
        id: "fig18",
        caption: "SVD1 cost: Wukong grows slower with problem size than \
                  Dask",
        table: t,
    }
}

fn timeline_rows(t: &mut Table, name: &str, m: &RunMetrics, vcpus_per_exec: f64) {
    // Sample vCPU count at quartiles of the makespan + cumulative cost.
    let end = secs(m.makespan_s);
    let series = m.timeline.series(end / 4 + 1, end);
    let vcpu_at = |frac: f64| -> i64 {
        let idx = ((series.len() - 1) as f64 * frac) as usize;
        (series[idx].1 as f64 * vcpus_per_exec) as i64
    };
    t.row(vec![
        name.to_string(),
        format!("{:.2}", m.makespan_s),
        vcpu_at(0.25).to_string(),
        vcpu_at(0.5).to_string(),
        vcpu_at(0.75).to_string(),
        format!("{}", (m.timeline.peak() as f64 * vcpus_per_exec) as i64),
        format!("{:.0}", m.cpu_seconds),
        format!("{:.4}", m.dollars()),
    ]);
}

fn timeline_figure(
    cfg: &Config,
    dag: &crate::dag::Dag,
    npw_workers: &[usize],
    id: &'static str,
    caption: &'static str,
) -> Figure {
    let mut t = Table::new(vec![
        "config",
        "makespan (s)",
        "vCPU@25%",
        "vCPU@50%",
        "vCPU@75%",
        "peak vCPU",
        "core-s",
        "cost ($)",
    ]);
    let wcfg = single_redis(&wukong_cfg(cfg));
    let wk = run_wukong(dag, &wcfg, cfg.seed).metrics;
    timeline_rows(&mut t, "wukong 1-redis", &wk, 2.0);
    for &n in npw_workers {
        let mut c = single_redis(cfg);
        c.numpywren.n_workers = n;
        let m = run_numpywren(dag, &c, cfg.seed);
        timeline_rows(&mut t, &format!("numpywren-{n}"), &m, 2.0);
    }
    for (name, dcfg) in [
        ("dask-1000", DaskConfig::workers_1000()),
        ("dask-125", DaskConfig::workers_125()),
    ] {
        let m = run_dask(dag, cfg, &dcfg, cfg.seed);
        timeline_rows(&mut t, name, &m, 1.0);
    }
    Figure {
        id,
        caption,
        table: t,
    }
}

/// Fig. 19: GEMM 25k×25k vCPU usage + cost timeline.
pub fn fig19(cfg: &Config, quick: bool) -> Figure {
    let nk = if quick { 10 } else { 25 };
    let dag = gemm::dag(gemm::GemmParams::paper(nk));
    timeline_figure(
        cfg,
        &dag,
        &[50, 169, 338],
        "fig19",
        "GEMM vCPU/cost timeline: Wukong cheaper + fewer vCPUs than every \
         numpywren configuration",
    )
}

/// Fig. 20: TSQR 4M vCPU usage + cost timeline.
pub fn fig20(cfg: &Config, quick: bool) -> Figure {
    let rows_m = if quick { 1.0 } else { 4.0 };
    let dag = tsqr::dag(tsqr::TsqrParams::paper(rows_m));
    timeline_figure(
        cfg,
        &dag,
        &[128, 256],
        "fig20",
        "TSQR vCPU/cost timeline: Wukong ~14x cheaper than the best \
         numpywren configuration",
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wukong_cheaper_than_numpywren_on_tsqr() {
        let cfg = Config::default();
        let dag = tsqr::dag(tsqr::TsqrParams {
            rows: 1 << 21,
            cols: 128,
            block_rows: 4096,
            with_q: false,
        });
        let wk = run_wukong(&dag, &single_redis(&wukong_cfg(&cfg)), 1).metrics;
        let mut c = single_redis(&cfg);
        c.numpywren.n_workers = 128;
        let np = run_numpywren(&dag, &c, 1);
        assert!(
            wk.dollars() < np.dollars(),
            "wukong ${:.4} should undercut numpywren ${:.4}",
            wk.dollars(),
            np.dollars()
        );
    }

    #[test]
    fn dask_cost_scales_with_makespan_not_work() {
        // Dask bills allocated VMs for the duration — tiny jobs still pay.
        let cfg = Config::default();
        let dag = svd::svd1(svd::Svd1Params {
            rows: 64 * 1024,
            cols: 128,
            block_rows: 16 * 1024,
        });
        let d = run_dask(&dag, &cfg, &DaskConfig::workers_125(), 1);
        assert!(d.dollars() > 0.0);
    }
}
