//! Figures 3–4: numpywren read/write amplification on GEMM and TSQR.
//!
//! The paper's motivation figures: stateless executors push every
//! intermediate through storage, so GEMM reads >25× its input and writes
//! >20× its output; TSQR writes orders of magnitude more than its output
//! (every Q block). Byte counts here are *metered exactly* by the KVS
//! model, not estimated.

use crate::baselines::run_numpywren;
use crate::config::Config;
use crate::util::table::Table;
use crate::workloads::{gemm, tsqr};

use super::{fmt_b, Figure};

/// Fig. 3: numpywren GEMM amplification across problem sizes.
pub fn fig3(cfg: &Config, quick: bool) -> Figure {
    let sizes: &[usize] = if quick { &[5, 10] } else { &[5, 10, 15, 20, 25] };
    let mut t = Table::new(vec![
        "n (k)",
        "input",
        "read",
        "read amp",
        "output",
        "written",
        "write amp",
    ]);
    for &nk in sizes {
        let p = gemm::GemmParams::paper(nk);
        let dag = gemm::dag(p);
        let (input, output) = gemm::io_bytes(p);
        let m = run_numpywren(&dag, cfg, cfg.seed);
        t.row(vec![
            nk.to_string(),
            fmt_b(input as f64),
            fmt_b(m.kvs.bytes_read as f64),
            format!("{:.2}x", m.kvs.bytes_read as f64 / input as f64),
            fmt_b(output as f64),
            fmt_b(m.kvs.bytes_written as f64),
            format!("{:.2}x", m.kvs.bytes_written as f64 / output as f64),
        ]);
    }
    Figure {
        id: "fig3",
        caption: "numpywren GEMM read/write amplification (paper: >25x \
                  read, >20x write at 25k)",
        table: t,
    }
}

/// Fig. 4: numpywren TSQR amplification.
pub fn fig4(cfg: &Config, quick: bool) -> Figure {
    let sizes: &[f64] = if quick { &[0.5, 1.0] } else { &[1.0, 2.0, 4.0, 8.0] };
    let mut t = Table::new(vec![
        "rows (M)",
        "input",
        "read",
        "read amp",
        "output R",
        "written",
        "write amp",
    ]);
    for &m_rows in sizes {
        let p = tsqr::TsqrParams::paper(m_rows);
        let dag = tsqr::dag(p);
        let (input, _) = tsqr::io_bytes(p);
        // The paper's TSQR "output" for amplification is the final R
        // factor alone (cols × cols) — hence the 65M× figure.
        let r_out = (p.cols * p.cols) as u64 * crate::workloads::ELEM;
        let m = run_numpywren(&dag, cfg, cfg.seed);
        t.row(vec![
            format!("{m_rows:.1}"),
            fmt_b(input as f64),
            fmt_b(m.kvs.bytes_read as f64),
            format!("{:.2}x", m.kvs.bytes_read as f64 / input as f64),
            fmt_b(r_out as f64),
            fmt_b(m.kvs.bytes_written as f64),
            format!("{:.0}x", m.kvs.bytes_written as f64 / r_out as f64),
        ]);
    }
    Figure {
        id: "fig4",
        caption: "numpywren TSQR amplification (paper: writes ~65M x the \
                  final R factor)",
        table: t,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_amplification_shape_holds() {
        // numpywren must read several times its input (partials re-read
        // through the add tree) and write more than its output.
        let cfg = Config::default();
        let p = gemm::GemmParams::paper(10);
        let dag = gemm::dag(p);
        let (input, output) = gemm::io_bytes(p);
        let m = run_numpywren(&dag, &cfg, 1);
        assert!(m.kvs.bytes_read as f64 > 1.5 * input as f64);
        assert!(m.kvs.bytes_written as f64 > 2.0 * output as f64);
    }

    #[test]
    fn tsqr_write_amplification_is_huge() {
        let cfg = Config::default();
        let p = tsqr::TsqrParams {
            rows: 1 << 20,
            cols: 128,
            block_rows: 4096,
            with_q: false,
        };
        let dag = tsqr::dag(p);
        let r_out = (128 * 128 * 4) as f64;
        let m = run_numpywren(&dag, &cfg, 1);
        // hundreds of Q blocks × MBs vs a 64 KB R
        assert!(m.kvs.bytes_written as f64 / r_out > 1000.0);
    }
}
