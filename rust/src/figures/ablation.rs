//! Figures 22–23: factor analysis of task clustering + delayed I/O.

use crate::config::Config;
use crate::coordinator::run_wukong;
use crate::util::table::Table;
use crate::workloads::svd;

use super::end_to_end::wukong_cfg;
use super::Figure;

fn svd2_dag(quick: bool) -> crate::dag::Dag {
    svd::svd2(svd::Svd2Params::paper(if quick { 10 } else { 50 }))
}

/// Fig. 22: SVD2 aggregated execution-time breakdown with and without
/// clustering + delayed I/O.
pub fn fig22(cfg: &Config, quick: bool) -> Figure {
    let dag = svd2_dag(quick);
    let mut on = wukong_cfg(cfg);
    on.wukong.use_clustering = true;
    on.wukong.use_delayed_io = true;
    let mut off = wukong_cfg(cfg);
    off.wukong.use_clustering = false;
    off.wukong.use_delayed_io = false;

    let m_on = run_wukong(&dag, &on, cfg.seed).metrics;
    let m_off = run_wukong(&dag, &off, cfg.seed).metrics;

    let mut t = Table::new(vec![
        "activity",
        "optimizations ON (s)",
        "optimizations OFF (s)",
        "ratio",
    ]);
    let rows = [
        ("task invocation", m_on.breakdown.invoke_s, m_off.breakdown.invoke_s),
        (
            "redis I/O",
            m_on.breakdown.kvs_read_s + m_on.breakdown.kvs_write_s,
            m_off.breakdown.kvs_read_s + m_off.breakdown.kvs_write_s,
        ),
        ("task execution", m_on.breakdown.execute_s, m_off.breakdown.execute_s),
        ("serde", m_on.breakdown.serde_s, m_off.breakdown.serde_s),
        (
            "publishing messages",
            m_on.breakdown.publish_s,
            m_off.breakdown.publish_s,
        ),
    ];
    for (name, a, b) in rows {
        t.row(vec![
            name.to_string(),
            format!("{a:.2}"),
            format!("{b:.2}"),
            format!("{:.2}x", b / a.max(1e-9)),
        ]);
    }
    t.row(vec![
        "end-to-end".to_string(),
        format!("{:.2}", m_on.makespan_s),
        format!("{:.2}", m_off.makespan_s),
        format!("{:.2}x", m_off.makespan_s / m_on.makespan_s.max(1e-9)),
    ]);
    Figure {
        id: "fig22",
        caption: "SVD2 time breakdown: clustering + delayed I/O collapse \
                  Redis I/O (paper: 27.8x) and invocation time (7.2x)",
        table: t,
    }
}

/// Fig. 23: stacked factor analysis — ElastiCache baseline → Fargate
/// multi-Redis → + clustering → + delayed I/O.
pub fn fig23(cfg: &Config, quick: bool) -> Figure {
    let dag = svd2_dag(quick);

    let mut base = wukong_cfg(cfg);
    base.storage = base.storage.clone().elasticache();
    base.wukong.use_clustering = false;
    base.wukong.use_delayed_io = false;

    let mut fargate = wukong_cfg(cfg);
    fargate.wukong.use_clustering = false;
    fargate.wukong.use_delayed_io = false;

    let mut clustered = wukong_cfg(cfg);
    clustered.wukong.use_clustering = true;
    clustered.wukong.use_delayed_io = false;

    let mut full = wukong_cfg(cfg);
    full.wukong.use_clustering = true;
    full.wukong.use_delayed_io = true;

    let configs = [
        ("ElastiCache baseline", base),
        ("+ Fargate multi-Redis", fargate),
        ("+ task clustering", clustered),
        ("+ delayed I/O (all)", full),
    ];
    let mut t = Table::new(vec![
        "configuration",
        "makespan (s)",
        "vs previous",
        "vs baseline",
    ]);
    let mut prev: Option<f64> = None;
    let mut baseline: Option<f64> = None;
    for (name, c) in configs {
        let m = run_wukong(&dag, &c, cfg.seed).metrics.makespan_s;
        let vs_prev = prev
            .map(|p| format!("{:+.1}%", (p - m) / p * 100.0))
            .unwrap_or_else(|| "-".into());
        let vs_base = baseline
            .map(|b| format!("{:.2}x", b / m))
            .unwrap_or_else(|| "1.00x".into());
        t.row(vec![name.to_string(), format!("{m:.2}"), vs_prev, vs_base]);
        prev = Some(m);
        baseline = baseline.or(Some(m));
    }
    Figure {
        id: "fig23",
        caption: "Factor analysis (paper: Fargate +20.85%, clustering \
                  +48.82%, delayed I/O +46.21%; 4.6x total)",
        table: t,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn optimizations_reduce_makespan_and_io() {
        let cfg = Config::default();
        let dag = svd2_dag(true);
        let mut on = wukong_cfg(&cfg);
        on.wukong.use_clustering = true;
        on.wukong.use_delayed_io = true;
        let mut off = wukong_cfg(&cfg);
        off.wukong.use_clustering = false;
        off.wukong.use_delayed_io = false;
        let m_on = run_wukong(&dag, &on, 1).metrics;
        let m_off = run_wukong(&dag, &off, 1).metrics;
        assert!(m_on.makespan_s < m_off.makespan_s);
        assert!(m_on.kvs.bytes_written < m_off.kvs.bytes_written);
    }

    #[test]
    fn each_factor_helps() {
        // The fig23 staircase must be monotonically improving.
        let cfg = Config::default();
        let fig = fig23(&cfg, true);
        assert_eq!(fig.table.n_rows(), 4);
    }
}
