//! Figures 9–16: end-to-end workload comparisons and I/O volumes.

use crate::baselines::{run_dask, run_numpywren};
use crate::config::{Config, DaskConfig};
use crate::coordinator::run_wukong;
use crate::dag::Dag;
use crate::sim::secs;
use crate::util::table::Table;
use crate::workloads::{gemm, svc, svd, tr, tsqr};

use super::{avg, fmt_b, Figure};

/// Wukong configured the way the big-object workloads run it: the
/// clustering threshold `t` tuned below the Q/B panel sizes (a
/// user-exposed knob; §3.3 cites 200 MB as *an example*).
pub(crate) fn wukong_cfg(cfg: &Config) -> Config {
    let mut c = cfg.clone();
    c.wukong.clustering_threshold = 1024 * 1024;
    c
}

pub(crate) fn single_redis(cfg: &Config) -> Config {
    let mut c = cfg.clone();
    c.storage = c.storage.clone().single_redis();
    c
}

pub(crate) fn s3(cfg: &Config) -> Config {
    let mut c = cfg.clone();
    c.storage = c.storage.clone().s3();
    c
}

/// Dask OOM heuristic: a worker must hold one in-flight working set per
/// busy core; the paper's Dask-1000 (3 GB workers) dies on the large
/// SVD2 problems while Dask-125 (24 GB) survives (Fig. 11's crosses).
pub(crate) fn dask_oom(dag: &Dag, dcfg: &DaskConfig) -> bool {
    let peak_ws = (0..dag.len() as u32)
        .map(|t| {
            let node = dag.task(t);
            let parents: u64 = dag
                .parents(t)
                .iter()
                .map(|&p| dag.task(p).out_bytes)
                .sum();
            node.input_bytes + parents + node.out_bytes
        })
        .max()
        .unwrap_or(0);
    let cores = dcfg.cores_per_worker.min(4) as f64;
    // 1.2x: serialization buffers + the Dask worker's own overhead.
    cores * peak_ws as f64 * 1.2 > dcfg.mem_per_worker_gb * 1e9
}

/// Fig. 9: TR (N=1024) under injected per-task delays.
pub fn fig9(cfg: &Config, quick: bool) -> Figure {
    let delays_ms: &[u64] = if quick { &[0, 250] } else { &[0, 100, 250, 500] };
    let mut t = Table::new(vec![
        "delay (ms)",
        "wukong (s)",
        "dask-1000 (s)",
        "dask-125 (s)",
    ]);
    let n = if quick { 256 } else { 1024 };
    for &d in delays_ms {
        let dag = tr::dag(tr::TrParams {
            n,
            chunk: 1,
            delay: Some(secs(d as f64 / 1000.0)),
        });
        let wk = avg(cfg, quick, |s| run_wukong(&dag, cfg, s).metrics.makespan_s);
        let d1000 = avg(cfg, quick, |s| {
            run_dask(&dag, cfg, &DaskConfig::workers_1000(), s).makespan_s
        });
        let d125 = avg(cfg, quick, |s| {
            run_dask(&dag, cfg, &DaskConfig::workers_125(), s).makespan_s
        });
        t.row(vec![
            d.to_string(),
            format!("{wk:.2}"),
            format!("{d1000:.2}"),
            format!("{d125:.2}"),
        ]);
    }
    Figure {
        id: "fig9",
        caption: "TR vs per-task delay: Dask wins the no-op case; Wukong \
                  overtakes Dask-1000 at >=250 ms tasks",
        table: t,
    }
}

fn three_way(
    cfg: &Config,
    quick: bool,
    label: &str,
    dags: Vec<(String, Dag)>,
    caption: &'static str,
    id: &'static str,
) -> Figure {
    let mut t = Table::new(vec![
        label,
        "wukong (s)",
        "dask-1000 (s)",
        "dask-125 (s)",
    ]);
    let wcfg = wukong_cfg(cfg);
    for (size, dag) in dags {
        let wk = avg(cfg, quick, |s| run_wukong(&dag, &wcfg, s).metrics.makespan_s);
        let d1000 = if dask_oom(&dag, &DaskConfig::workers_1000()) {
            "OOM".to_string()
        } else {
            format!(
                "{:.2}",
                avg(cfg, quick, |s| run_dask(
                    &dag,
                    cfg,
                    &DaskConfig::workers_1000(),
                    s
                )
                .makespan_s)
            )
        };
        let d125 = if dask_oom(&dag, &DaskConfig::workers_125()) {
            "OOM".to_string()
        } else {
            format!(
                "{:.2}",
                avg(cfg, quick, |s| run_dask(
                    &dag,
                    cfg,
                    &DaskConfig::workers_125(),
                    s
                )
                .makespan_s)
            )
        };
        t.row(vec![size, format!("{wk:.2}"), d1000, d125]);
    }
    Figure {
        id,
        caption,
        table: t,
    }
}

/// Fig. 10: SVD1 (tall-skinny) across problem sizes.
pub fn fig10(cfg: &Config, quick: bool) -> Figure {
    let sizes: &[f64] = if quick {
        &[0.25, 1.0]
    } else {
        &[0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0]
    };
    let dags = sizes
        .iter()
        .map(|&m| {
            (
                format!("{m}M"),
                svd::svd1(svd::Svd1Params::paper(m)),
            )
        })
        .collect();
    three_way(
        cfg,
        quick,
        "rows",
        dags,
        "SVD1: Wukong beats Dask-1000, trails Dask-125",
        "fig10",
    )
}

/// Fig. 11: SVD2 (square, randomized) across problem sizes.
pub fn fig11(cfg: &Config, quick: bool) -> Figure {
    let sizes: &[usize] = if quick {
        &[10, 50]
    } else {
        &[10, 25, 50, 100, 150, 200, 256]
    };
    let dags = sizes
        .iter()
        .map(|&nk| {
            (
                format!("{nk}k"),
                svd::svd2(svd::Svd2Params::paper(nk)),
            )
        })
        .collect();
    three_way(
        cfg,
        quick,
        "n",
        dags,
        "SVD2: Wukong scales past Dask-1000's memory ceiling (OOM marks)",
        "fig11",
    )
}

/// Fig. 12: SVC across sample counts.
pub fn fig12(cfg: &Config, quick: bool) -> Figure {
    let sizes: &[f64] = if quick {
        &[0.5, 2.0]
    } else {
        &[0.5, 1.0, 2.0, 4.0, 8.0]
    };
    let dags = sizes
        .iter()
        .map(|&m| (format!("{m}M"), svc::dag(svc::SvcParams::paper(m))))
        .collect();
    three_way(
        cfg,
        quick,
        "samples",
        dags,
        "SVC: gap to Dask closes as the problem grows",
        "fig12",
    )
}

fn four_way_serverless(
    cfg: &Config,
    quick: bool,
    label: &str,
    dags: Vec<(String, Dag)>,
    caption: &'static str,
    id: &'static str,
) -> (Figure, Vec<(String, [crate::storage::KvsMetrics; 2])>) {
    let mut t = Table::new(vec![
        label,
        "wukong multi-redis (s)",
        "wukong 1-redis (s)",
        "numpywren s3 (s)",
        "numpywren 1-redis (s)",
    ]);
    let mut ios = Vec::new();
    for (size, dag) in dags {
        let wk_multi_cfg = wukong_cfg(cfg);
        let wk_multi = run_wukong(&dag, &wk_multi_cfg, cfg.seed);
        let wk_single = run_wukong(&dag, &single_redis(&wk_multi_cfg), cfg.seed);
        let np_s3 = run_numpywren(&dag, &s3(cfg), cfg.seed);
        let np_single = run_numpywren(&dag, &single_redis(cfg), cfg.seed);
        let _ = quick;
        t.row(vec![
            size.clone(),
            format!("{:.2}", wk_multi.metrics.makespan_s),
            format!("{:.2}", wk_single.metrics.makespan_s),
            format!("{:.2}", np_s3.makespan_s),
            format!("{:.2}", np_single.makespan_s),
        ]);
        ios.push((size, [wk_multi.metrics.kvs, np_s3.kvs]));
    }
    (
        Figure {
            id,
            caption,
            table: t,
        },
        ios,
    )
}

fn gemm_dags(quick: bool) -> Vec<(String, Dag)> {
    let sizes: &[usize] = if quick { &[5, 15] } else { &[5, 10, 15, 20, 25] };
    sizes
        .iter()
        .map(|&nk| {
            (
                format!("{nk}k"),
                gemm::dag(gemm::GemmParams::paper(nk)),
            )
        })
        .collect()
}

fn tsqr_dags(quick: bool) -> Vec<(String, Dag)> {
    let sizes: &[f64] = if quick {
        &[1.0, 4.1]
    } else {
        &[1.0, 2.0, 4.1, 8.4, 16.7]
    };
    sizes
        .iter()
        .map(|&m| {
            (
                format!("{m}M"),
                tsqr::dag(tsqr::TsqrParams::paper(m)),
            )
        })
        .collect()
}

/// Fig. 13: GEMM end-to-end, Wukong vs numpywren.
pub fn fig13(cfg: &Config, quick: bool) -> Figure {
    four_way_serverless(
        cfg,
        quick,
        "n",
        gemm_dags(quick),
        "GEMM: hard for serverless, but Wukong well ahead of numpywren",
        "fig13",
    )
    .0
}

/// Fig. 14: TSQR end-to-end (log scale in the paper).
pub fn fig14(cfg: &Config, quick: bool) -> Figure {
    four_way_serverless(
        cfg,
        quick,
        "rows",
        tsqr_dags(quick),
        "TSQR: Wukong up to ~68x faster than numpywren (single-Redis \
         pairing)",
        "fig14",
    )
    .0
}

fn io_figure(
    cfg: &Config,
    quick: bool,
    label: &str,
    dags: Vec<(String, Dag)>,
    caption: &'static str,
    id: &'static str,
) -> Figure {
    let mut t = Table::new(vec![
        label,
        "wukong read",
        "wukong written",
        "numpywren read",
        "numpywren written",
        "write ratio",
    ]);
    let wcfg = wukong_cfg(cfg);
    for (size, dag) in dags {
        let _ = quick;
        let wk = run_wukong(&dag, &wcfg, cfg.seed).metrics.kvs;
        let np = run_numpywren(&dag, &s3(cfg), cfg.seed).kvs;
        t.row(vec![
            size,
            fmt_b(wk.bytes_read as f64),
            fmt_b(wk.bytes_written as f64),
            fmt_b(np.bytes_read as f64),
            fmt_b(np.bytes_written as f64),
            format!(
                "{:.0}x",
                np.bytes_written as f64 / (wk.bytes_written.max(1)) as f64
            ),
        ]);
    }
    Figure {
        id,
        caption,
        table: t,
    }
}

/// Fig. 15: GEMM I/O volumes.
pub fn fig15(cfg: &Config, quick: bool) -> Figure {
    io_figure(
        cfg,
        quick,
        "n",
        gemm_dags(quick),
        "GEMM I/O: Wukong reads ~45-50% less, writes up to 85% less",
        "fig15",
    )
}

/// Fig. 16: TSQR I/O volumes.
pub fn fig16(cfg: &Config, quick: bool) -> Figure {
    io_figure(
        cfg,
        quick,
        "rows",
        tsqr_dags(quick),
        "TSQR I/O: numpywren writes ~4 orders of magnitude more",
        "fig16",
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tsqr_wukong_beats_numpywren_single_redis() {
        // The paper's 68x headline pairing (we assert the direction and
        // a large factor, not the absolute value).
        let cfg = Config::default();
        let dag = tsqr::dag(tsqr::TsqrParams {
            rows: 1 << 21,
            cols: 128,
            block_rows: 4096,
            with_q: false,
        });
        let wk = run_wukong(&dag, &single_redis(&wukong_cfg(&cfg)), 1)
            .metrics
            .makespan_s;
        let np = run_numpywren(&dag, &single_redis(&cfg), 1).makespan_s;
        assert!(
            np > 3.0 * wk,
            "expected numpywren ({np:.1}s) >> wukong ({wk:.1}s)"
        );
    }

    #[test]
    fn tsqr_write_reduction_is_orders_of_magnitude() {
        let cfg = Config::default();
        let dag = tsqr::dag(tsqr::TsqrParams {
            rows: 1 << 21,
            cols: 128,
            block_rows: 4096,
            with_q: false,
        });
        let wk = run_wukong(&dag, &wukong_cfg(&cfg), 1).metrics.kvs;
        let np = run_numpywren(&dag, &cfg, 1).kvs;
        let ratio = np.bytes_written as f64 / wk.bytes_written.max(1) as f64;
        // The stateless Q-bundle writes dominate: we reproduce ~1.5 orders
        // of magnitude of the paper's 4 (see EXPERIMENTS.md for analysis).
        assert!(ratio > 25.0, "write ratio only {ratio:.1}x");
    }

    #[test]
    fn gemm_wukong_reduces_io() {
        let cfg = Config::default();
        let dag = gemm::dag(gemm::GemmParams::paper(10));
        let wk = run_wukong(&dag, &wukong_cfg(&cfg), 1).metrics.kvs;
        let np = run_numpywren(&dag, &cfg, 1).kvs;
        assert!(wk.bytes_read < np.bytes_read);
        assert!(wk.bytes_written < np.bytes_written);
    }

    #[test]
    fn dask_oom_fires_for_thin_workers_on_big_panels() {
        let dag = svd::svd2(svd::Svd2Params::paper(200));
        assert!(dask_oom(&dag, &DaskConfig::workers_1000()));
        assert!(!dask_oom(&dag, &DaskConfig::workers_125()));
    }
}
