//! `wukong bench --diff BASELINE.json` — the automated perf-regression
//! gate.
//!
//! Compares a freshly measured `BENCH_*.json` sweep against a committed
//! baseline, row by row (matched on `(engine, workload)`), and fails
//! when either of two things happened since the baseline was captured:
//!
//! 1. **Throughput regression** — `events_per_sec` dropped by more than
//!    [`MAX_EVENTS_PER_SEC_DROP`] (20%). Wall-clock throughput is noisy,
//!    so the threshold is deliberately loose; the committed CI baseline
//!    additionally uses a conservative floor (see ROADMAP.md).
//! 2. **Superlinear event growth** — `sim_events` grew faster than the
//!    task count did, by more than [`MAX_SUPERLINEAR_GROWTH`] (25%)
//!    beyond the linear scaling `base_events × (cur_tasks /
//!    base_tasks)`. This is the machine-independent half of the gate: a
//!    calendar or engine change that starts emitting O(n log n) or O(n²)
//!    events per task trips it even on an arbitrarily fast machine.
//!
//! A baseline row with no matching current row is a failure (an engine
//! silently dropping out of the sweep must not pass the gate); a current
//! row with no baseline is informational only. Mixing `--quick` and
//! full-mode files is a hard error rather than a failure — the task
//! budgets differ ~100×, so every row would trip the growth check for
//! the wrong reason.

use crate::util::json::Json;

/// Maximum tolerated fractional drop in `events_per_sec` per row.
pub const MAX_EVENTS_PER_SEC_DROP: f64 = 0.20;

/// Maximum tolerated fractional excess of `sim_events` over linear
/// scaling in the task count.
pub const MAX_SUPERLINEAR_GROWTH: f64 = 0.25;

/// The outcome of one baseline/current comparison.
#[derive(Debug, Clone)]
pub struct BenchDiff {
    /// One human-readable line per compared (or unmatched) row.
    pub lines: Vec<String>,
    /// The subset of rows that failed the gate, with reasons.
    pub failures: Vec<String>,
}

impl BenchDiff {
    /// True when every baseline row was matched and within thresholds.
    pub fn passed(&self) -> bool {
        self.failures.is_empty()
    }
}

/// One parsed `(engine, workload)` row, only the gated fields.
#[derive(Debug, Clone, PartialEq)]
struct Row {
    engine: String,
    workload: String,
    tasks: f64,
    sim_events: f64,
    events_per_sec: f64,
}

fn str_key(label: &str, j: &Json, key: &str) -> Result<String, String> {
    j.get(key)
        .and_then(|v| v.as_str())
        .map(|s| s.to_string())
        .ok_or_else(|| format!("{label}: missing string key \"{key}\""))
}

fn num_key(label: &str, j: &Json, key: &str) -> Result<f64, String> {
    j.get(key)
        .and_then(|v| v.as_f64())
        .ok_or_else(|| format!("{label}: missing numeric key \"{key}\""))
}

fn bool_key(label: &str, j: &Json, key: &str) -> Result<bool, String> {
    j.get(key)
        .and_then(|v| v.as_bool())
        .ok_or_else(|| format!("{label}: missing boolean key \"{key}\""))
}

/// Parse and schema-check one `BENCH_*.json` document. Returns the
/// `quick` flag and the gated rows.
fn parse_bench(label: &str, text: &str) -> Result<(bool, Vec<Row>), String> {
    let top = Json::parse(text)
        .map_err(|e| format!("{label}: invalid JSON: {e}"))?;
    let schema = str_key(label, &top, "bench")?;
    if schema != "wukong-sim-hotpath" {
        return Err(format!(
            "{label}: \"bench\" is \"{schema}\" \
             (expected \"wukong-sim-hotpath\")"
        ));
    }
    let quick = bool_key(label, &top, "quick")?;
    let recs = top
        .get("records")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| format!("{label}: missing array key \"records\""))?;
    let mut rows = Vec::with_capacity(recs.len());
    for (i, r) in recs.iter().enumerate() {
        let ctx = format!("{label}: records[{i}]");
        rows.push(Row {
            engine: str_key(&ctx, r, "engine")?,
            workload: str_key(&ctx, r, "workload")?,
            tasks: num_key(&ctx, r, "tasks")?,
            sim_events: num_key(&ctx, r, "sim_events")?,
            events_per_sec: num_key(&ctx, r, "events_per_sec")?,
        });
    }
    Ok((quick, rows))
}

/// Compare `current_text` against `baseline_text` (both `BENCH_*.json`
/// documents). `Err` means the inputs are unusable (bad JSON, schema
/// mismatch, quick/full mix); `Ok` carries per-row verdicts — check
/// [`BenchDiff::passed`].
pub fn diff_benches(
    baseline_text: &str,
    current_text: &str,
) -> Result<BenchDiff, String> {
    let (base_quick, base_rows) = parse_bench("baseline", baseline_text)?;
    let (cur_quick, cur_rows) = parse_bench("current", current_text)?;
    if base_quick != cur_quick {
        return Err(format!(
            "quick-mode mismatch: baseline quick={base_quick}, \
             current quick={cur_quick} (task budgets differ ~100x; \
             compare like with like)"
        ));
    }
    let mut diff = BenchDiff {
        lines: Vec::new(),
        failures: Vec::new(),
    };
    for b in &base_rows {
        let key = format!("{} {}", b.engine, b.workload);
        let Some(c) = cur_rows
            .iter()
            .find(|c| c.engine == b.engine && c.workload == b.workload)
        else {
            let msg = format!(
                "[{key}] present in baseline but missing from current run"
            );
            diff.lines.push(format!("{msg}: FAIL"));
            diff.failures.push(msg);
            continue;
        };
        let mut reasons = Vec::new();
        let eps_floor = b.events_per_sec * (1.0 - MAX_EVENTS_PER_SEC_DROP);
        if c.events_per_sec < eps_floor {
            reasons.push(format!(
                "events_per_sec {:.0} -> {:.0} (floor {:.0}, \
                 >{}% regression)",
                b.events_per_sec,
                c.events_per_sec,
                eps_floor,
                (MAX_EVENTS_PER_SEC_DROP * 100.0) as u32
            ));
        }
        // Superlinear growth: normalize by the task-count ratio so a
        // deliberate budget increase (tasks x10, events x10) passes.
        let task_ratio = if b.tasks > 0.0 { c.tasks / b.tasks } else { 1.0 };
        let events_ceiling =
            b.sim_events * task_ratio * (1.0 + MAX_SUPERLINEAR_GROWTH);
        if c.sim_events > events_ceiling {
            reasons.push(format!(
                "sim_events {:.0} -> {:.0} \
                 (ceiling {:.0} at tasks x{:.2}, superlinear growth)",
                b.sim_events, c.sim_events, events_ceiling, task_ratio
            ));
        }
        if reasons.is_empty() {
            diff.lines.push(format!(
                "[{key}] events/sec {:.0} -> {:.0}, \
                 sim_events {:.0} -> {:.0}: ok",
                b.events_per_sec,
                c.events_per_sec,
                b.sim_events,
                c.sim_events
            ));
        } else {
            let msg = format!("[{key}] {}", reasons.join("; "));
            diff.lines.push(format!("{msg}: FAIL"));
            diff.failures.push(msg);
        }
    }
    for c in &cur_rows {
        let known = base_rows
            .iter()
            .any(|b| b.engine == c.engine && b.workload == c.workload);
        if !known {
            diff.lines.push(format!(
                "[{} {}] new record (no baseline): skipped",
                c.engine, c.workload
            ));
        }
    }
    Ok(diff)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    /// Build a schema-valid `BENCH_*.json` document from
    /// `(engine, workload, tasks, sim_events, events_per_sec)` rows.
    fn fixture(quick: bool, rows: &[(&str, &str, f64, f64, f64)]) -> String {
        let recs: Vec<Json> = rows
            .iter()
            .map(|(e, w, tasks, ev, eps)| {
                let mut m = BTreeMap::new();
                m.insert("engine".to_string(), Json::Str(e.to_string()));
                m.insert("workload".to_string(), Json::Str(w.to_string()));
                m.insert("tasks".to_string(), Json::Num(*tasks));
                m.insert("sim_events".to_string(), Json::Num(*ev));
                m.insert("events_per_sec".to_string(), Json::Num(*eps));
                Json::Obj(m)
            })
            .collect();
        let mut top = BTreeMap::new();
        top.insert(
            "bench".to_string(),
            Json::Str("wukong-sim-hotpath".to_string()),
        );
        top.insert("pr".to_string(), Json::Str("TEST".to_string()));
        top.insert("quick".to_string(), Json::Bool(quick));
        top.insert("seed".to_string(), Json::Num(42.0));
        top.insert("records".to_string(), Json::Arr(recs));
        Json::Obj(top).to_string()
    }

    const BASE: &[(&str, &str, f64, f64, f64)] = &[
        ("wukong", "fanout", 1_000_000.0, 4_000_000.0, 3.0e6),
        ("wukong", "chain", 1_000_000.0, 3_000_000.0, 2.5e6),
        ("dask125", "fanout", 50_000.0, 300_000.0, 8.0e5),
    ];

    #[test]
    fn identical_runs_pass() {
        let b = fixture(false, BASE);
        let d = diff_benches(&b, &b).unwrap();
        assert!(d.passed(), "{:?}", d.failures);
        assert_eq!(d.lines.len(), BASE.len());
        assert!(d.lines.iter().all(|l| l.ends_with(": ok")));
    }

    #[test]
    fn small_noise_within_threshold_passes() {
        let b = fixture(false, BASE);
        // 10% slower: inside the 20% tolerance band.
        let c = fixture(
            false,
            &[
                ("wukong", "fanout", 1_000_000.0, 4_000_000.0, 2.7e6),
                ("wukong", "chain", 1_000_000.0, 3_000_000.0, 2.25e6),
                ("dask125", "fanout", 50_000.0, 300_000.0, 7.2e5),
            ],
        );
        assert!(diff_benches(&b, &c).unwrap().passed());
    }

    #[test]
    fn twenty_five_percent_regression_fails() {
        // The acceptance fixture: a synthetic 25% events/sec drop on one
        // row must trip the gate and name the row and the key.
        let b = fixture(false, BASE);
        let c = fixture(
            false,
            &[
                ("wukong", "fanout", 1_000_000.0, 4_000_000.0, 2.25e6),
                ("wukong", "chain", 1_000_000.0, 3_000_000.0, 2.5e6),
                ("dask125", "fanout", 50_000.0, 300_000.0, 8.0e5),
            ],
        );
        let d = diff_benches(&b, &c).unwrap();
        assert!(!d.passed());
        assert_eq!(d.failures.len(), 1);
        assert!(d.failures[0].contains("wukong fanout"), "{}", d.failures[0]);
        assert!(d.failures[0].contains("events_per_sec"), "{}", d.failures[0]);
    }

    #[test]
    fn superlinear_event_growth_fails_even_when_fast() {
        let b = fixture(false, BASE);
        // Same task count, 2x the events, and *faster* wall-clock — the
        // machine-independent check still catches it.
        let c = fixture(
            false,
            &[
                ("wukong", "fanout", 1_000_000.0, 8_000_000.0, 9.0e6),
                ("wukong", "chain", 1_000_000.0, 3_000_000.0, 2.5e6),
                ("dask125", "fanout", 50_000.0, 300_000.0, 8.0e5),
            ],
        );
        let d = diff_benches(&b, &c).unwrap();
        assert!(!d.passed());
        assert_eq!(d.failures.len(), 1);
        assert!(d.failures[0].contains("sim_events"), "{}", d.failures[0]);
        assert!(d.failures[0].contains("superlinear"), "{}", d.failures[0]);
    }

    #[test]
    fn linear_scale_up_passes_the_growth_check() {
        let b = fixture(false, BASE);
        // 10x the tasks, 10x the events: linear, allowed.
        let c = fixture(
            false,
            &[
                ("wukong", "fanout", 10_000_000.0, 40_000_000.0, 3.0e6),
                ("wukong", "chain", 1_000_000.0, 3_000_000.0, 2.5e6),
                ("dask125", "fanout", 50_000.0, 300_000.0, 8.0e5),
            ],
        );
        assert!(diff_benches(&b, &c).unwrap().passed());
    }

    #[test]
    fn missing_engine_row_fails() {
        let b = fixture(false, BASE);
        let c = fixture(
            false,
            &[
                ("wukong", "fanout", 1_000_000.0, 4_000_000.0, 3.0e6),
                ("wukong", "chain", 1_000_000.0, 3_000_000.0, 2.5e6),
            ],
        );
        let d = diff_benches(&b, &c).unwrap();
        assert!(!d.passed());
        assert_eq!(d.failures.len(), 1);
        assert!(d.failures[0].contains("dask125 fanout"), "{}", d.failures[0]);
        assert!(d.failures[0].contains("missing"), "{}", d.failures[0]);
    }

    #[test]
    fn extra_current_rows_are_informational_only() {
        let b = fixture(false, &BASE[..2]);
        let c = fixture(false, BASE);
        let d = diff_benches(&b, &c).unwrap();
        assert!(d.passed());
        assert!(d
            .lines
            .iter()
            .any(|l| l.contains("dask125 fanout") && l.contains("skipped")));
    }

    #[test]
    fn schema_mismatch_is_a_hard_error() {
        let good = fixture(false, BASE);
        // Wrong "bench" marker.
        let wrong = good.replace("wukong-sim-hotpath", "other-bench");
        let err = diff_benches(&wrong, &good).unwrap_err();
        assert!(err.contains("baseline"), "{err}");
        assert!(err.contains("wukong-sim-hotpath"), "{err}");
        // Not JSON at all.
        let err = diff_benches(&good, "not json {").unwrap_err();
        assert!(err.contains("current"), "{err}");
        // Missing "records".
        let err =
            diff_benches(&good, r#"{"bench":"wukong-sim-hotpath","quick":false}"#)
                .unwrap_err();
        assert!(err.contains("\"records\""), "{err}");
    }

    #[test]
    fn missing_record_field_names_the_key() {
        let good = fixture(false, BASE);
        let broken = r#"{"bench":"wukong-sim-hotpath","quick":false,
            "records":[{"engine":"wukong","workload":"fanout",
            "tasks":100,"events_per_sec":1.0}]}"#;
        let err = diff_benches(&good, broken).unwrap_err();
        assert!(err.contains("\"sim_events\""), "{err}");
        assert!(err.contains("records[0]"), "{err}");
    }

    #[test]
    fn quick_flag_mismatch_is_a_hard_error() {
        let b = fixture(false, BASE);
        let c = fixture(true, BASE);
        let err = diff_benches(&b, &c).unwrap_err();
        assert!(err.contains("quick-mode mismatch"), "{err}");
    }
}
