//! `wukong bench` — the hot-path scale benchmark and perf-regression
//! gate.
//!
//! Sweeps the sim-path engines over three DAG families at million-task
//! scale — flat fan-out (serverless scaling), a single chain (pure
//! "becomes" locality), and the paper's TSQR workload shape — and
//! reports, per `(engine, workload)`: wall milliseconds, DES events
//! processed, events/sec, peak pending-event calendar depth, and the
//! simulated makespan. A fourth *job-stream* tier measures the
//! multi-tenant serving layer (thousands of corpus DAG jobs multiplexed
//! over one shared pool), adding jobs/sec and p99 job latency to the
//! row. A fifth *dynamic fan-out* tier (PR 10) measures the
//! runtime-spawning hot path: a flat fan-out whose every task expands
//! into a 21-task subtree mid-run, completion-checked against the
//! statically pre-expanded task count. Results are written as `BENCH_<point>.json`; each PR
//! appends a `BENCH_*.json` point so the perf trajectory is recorded and
//! regressions are caught automatically by `wukong bench --diff
//! BASELINE.json` (see [`diff`]), which fails on a >20% events/sec drop
//! or superlinear `sim_events` growth per `(engine, workload)` row (see
//! ROADMAP.md §Performance & benchmarking).
//!
//! The decentralized Wukong engine runs the full 1,000,000-task DAGs;
//! the centralized baselines get smaller budgets because their *models*
//! are inherently heavier per decision (Dask's locality assignment scans
//! every worker per task; numpywren/pywren hold per-worker state and
//! poll a shared queue) — the point of the gate is events/sec per
//! engine, not identical task counts.

pub mod diff;

use std::collections::BTreeMap;
use std::time::Instant;

use crate::config::Config;
use crate::dag::{pre_expand, Dag, SpawnPlan};
#[allow(unused_imports)]
use crate::engine::Engine;
use crate::engine::select_engines;
use crate::serving::{run_serving, ArrivalPlan};
use crate::util::json::Json;
use crate::workloads::{micro, tsqr};

/// The trajectory point this build records. Bump once per PR that
/// re-baselines perf — the JSON `pr` field and the default output
/// filename both derive from it.
pub const TRAJECTORY_POINT: &str = "PR10";

/// Default output path: `BENCH_<point>.json` at the invocation cwd.
pub fn default_out_path() -> String {
    format!("BENCH_{TRAJECTORY_POINT}.json")
}

/// Options for one bench sweep (CLI flags map 1:1).
#[derive(Debug, Clone)]
pub struct BenchOptions {
    /// Shrink every task budget ~100× (CI smoke mode).
    pub quick: bool,
    /// Engine names to exercise; empty = every sim-path engine.
    pub engines: Vec<String>,
    /// Run seed (the sweep itself is deterministic in virtual time; wall
    /// time is not).
    pub seed: u64,
}

impl Default for BenchOptions {
    fn default() -> Self {
        BenchOptions {
            quick: false,
            engines: Vec::new(),
            seed: 42,
        }
    }
}

/// One `(engine, workload)` measurement.
#[derive(Debug, Clone)]
pub struct BenchRecord {
    pub engine: &'static str,
    pub workload: &'static str,
    pub tasks: usize,
    pub wall_ms: f64,
    pub sim_events: u64,
    pub events_per_sec: f64,
    pub peak_pending: usize,
    pub makespan_s: f64,
    /// Virtual-time job throughput (jobstream tier only; 0 otherwise).
    pub jobs_per_sec: f64,
    /// p99 end-to-end job latency (jobstream tier only; 0 otherwise).
    pub p99_job_latency_s: f64,
}

/// Per-engine task budget for the flat fan-out family.
fn fanout_tasks(engine: &str, quick: bool) -> usize {
    let full = match engine {
        "wukong" => 1_000_000,
        "numpywren" | "pywren" => 100_000,
        _ => 50_000, // dask*: O(workers) scan per assignment
    };
    if quick {
        (full / 100).max(64)
    } else {
        full
    }
}

/// Per-engine task budget for the single-chain family.
fn chain_tasks(engine: &str, quick: bool) -> usize {
    let full = match engine {
        "wukong" => 1_000_000,
        "numpywren" | "pywren" => 50_000,
        _ => 20_000,
    };
    if quick {
        (full / 100).max(64)
    } else {
        full
    }
}

/// Per-engine TSQR leaf count (tasks ≈ 4 × leaves in R-only mode).
fn tsqr_leaves(engine: &str, quick: bool) -> usize {
    let full = match engine {
        "wukong" => 1 << 18, // 262144 leaves ⇒ ~1.05M tasks
        _ => 1 << 12,        // the paper's 16.7M-row shape
    };
    if quick {
        (full / 256).max(4)
    } else {
        full
    }
}

fn tsqr_dag(leaves: usize) -> Dag {
    tsqr::dag(tsqr::TsqrParams {
        rows: leaves * 4096,
        cols: 128,
        block_rows: 4096,
        with_q: false,
    })
}

/// The bench workload families, in run order.
const WORKLOADS: &[&str] = &["fanout", "chain", "tsqr"];

/// Build one bench DAG lazily (one DAG alive at a time — a million-task
/// DAG is ~10⁸ bytes of CSR + cost arrays, so eager construction of all
/// three would triple peak memory and pollute the measurements).
fn bench_dag(engine: &str, workload: &str, quick: bool) -> Dag {
    match workload {
        "fanout" => micro::serverless(fanout_tasks(engine, quick), 0),
        "chain" => micro::chains(micro::MicroParams {
            n_chains: 1,
            chain_len: chain_tasks(engine, quick),
            task_dur: 0,
        }),
        "tsqr" => tsqr_dag(tsqr_leaves(engine, quick)),
        other => unreachable!("unknown bench workload {other}"),
    }
}

/// The bench substrate config: paper defaults with the Lambda
/// concurrency cap lifted so the fan-out family measures the calendar,
/// not admission-throttle modeling.
fn bench_config() -> Config {
    let mut cfg = Config::default();
    cfg.lambda.concurrency_limit = 2_000_000;
    cfg
}

/// Execute the sweep. Errors on unknown engine names or on a run that
/// fails its completion sanity check (a broken engine must not produce a
/// perf baseline).
pub fn run_bench(opts: &BenchOptions) -> Result<Vec<BenchRecord>, String> {
    let engines = select_engines(&opts.engines)?;
    let cfg = bench_config();
    let mut records = Vec::new();
    for engine in &engines {
        for &workload in WORKLOADS {
            let dag = bench_dag(engine.name(), workload, opts.quick);
            let t0 = Instant::now();
            let rep = engine.run(&dag, &cfg, opts.seed);
            let wall = t0.elapsed();
            if rep.metrics.tasks_executed as usize != dag.len() {
                return Err(format!(
                    "bench [{} {workload}]: {}/{} tasks executed",
                    engine.name(),
                    rep.metrics.tasks_executed,
                    dag.len()
                ));
            }
            let sim_events = rep.sim_events.unwrap_or(0);
            let wall_s = wall.as_secs_f64().max(1e-9);
            records.push(BenchRecord {
                engine: engine.name(),
                workload,
                tasks: dag.len(),
                wall_ms: wall_s * 1e3,
                sim_events,
                events_per_sec: sim_events as f64 / wall_s,
                peak_pending: rep.peak_pending.unwrap_or(0),
                makespan_s: rep.metrics.makespan_s,
                jobs_per_sec: 0.0,
                p99_job_latency_s: 0.0,
            });
        }
    }
    // Dynamic fan-out tier: the runtime-spawning hot path. A flat
    // fan-out whose every base task expands at runtime into a 21-task
    // subtree (certain recursive plan: p=1, fanout 4, depth 2), so the
    // calendar and the per-task arrays grow mid-run instead of being
    // fixed at admission. Completion is checked against the statically
    // pre-expanded task count — the differential anchor, enforced even
    // in the perf gate.
    if let Some(engine) = engines.iter().find(|e| e.name() == "wukong") {
        let base_tasks = if opts.quick { 2_500 } else { 50_000 };
        let dag = micro::serverless(base_tasks, 0);
        let plan = SpawnPlan::recursive(1.0, 4, 2);
        let mut dcfg = bench_config();
        dcfg.spawn = plan;
        let expanded_len = pre_expand(&dag, plan, opts.seed).len();
        let t0 = Instant::now();
        let rep = engine.run(&dag, &dcfg, opts.seed);
        let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
        if rep.metrics.tasks_executed as usize != expanded_len {
            return Err(format!(
                "bench [wukong dynfan]: {}/{expanded_len} tasks executed",
                rep.metrics.tasks_executed
            ));
        }
        let sim_events = rep.sim_events.unwrap_or(0);
        records.push(BenchRecord {
            engine: "wukong",
            workload: "dynfan",
            tasks: expanded_len,
            wall_ms: wall_s * 1e3,
            sim_events,
            events_per_sec: sim_events as f64 / wall_s,
            peak_pending: rep.peak_pending.unwrap_or(0),
            makespan_s: rep.metrics.makespan_s,
            jobs_per_sec: 0.0,
            p99_job_latency_s: 0.0,
        });
    }
    // Job-stream tier: a multi-tenant serving session multiplexing
    // thousands of corpus DAG jobs (the wukong sim engine inside) over
    // one shared pool — the serving layer's own hot path, measured
    // wall-clock like every other row.
    if engines.iter().any(|e| e.name() == "wukong") {
        let jobs = if opts.quick { 200 } else { 10_000 };
        let mut scfg = bench_config();
        scfg.arrival = ArrivalPlan::poisson(100.0, jobs);
        let t0 = Instant::now();
        let rep = run_serving(&scfg, opts.seed, 0);
        let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
        if !rep.conserves_jobs() {
            return Err(format!(
                "bench [wukong jobstream]: jobs not conserved \
                 ({} admitted, {} completed + {} failed)",
                rep.admitted, rep.completed, rep.failed
            ));
        }
        records.push(BenchRecord {
            engine: "wukong",
            workload: "jobstream",
            tasks: rep.total_tasks as usize,
            wall_ms: wall_s * 1e3,
            sim_events: rep.total_events,
            events_per_sec: rep.total_events as f64 / wall_s,
            peak_pending: rep.peak_slots,
            makespan_s: rep.horizon_s,
            jobs_per_sec: if rep.horizon_s > 0.0 {
                rep.completed as f64 / rep.horizon_s
            } else {
                0.0
            },
            p99_job_latency_s: rep.p99_latency_s,
        });
    }
    Ok(records)
}

/// Serialize a sweep to the `BENCH_*.json` schema (one object per
/// record; top-level metadata for cross-PR comparison).
pub fn to_json(records: &[BenchRecord], opts: &BenchOptions) -> String {
    let recs: Vec<Json> = records
        .iter()
        .map(|r| {
            let mut m = BTreeMap::new();
            m.insert("engine".to_string(), Json::Str(r.engine.to_string()));
            m.insert("workload".to_string(), Json::Str(r.workload.to_string()));
            m.insert("tasks".to_string(), Json::Num(r.tasks as f64));
            m.insert("wall_ms".to_string(), Json::Num(r.wall_ms));
            m.insert("sim_events".to_string(), Json::Num(r.sim_events as f64));
            m.insert(
                "events_per_sec".to_string(),
                Json::Num(r.events_per_sec),
            );
            m.insert(
                "peak_pending".to_string(),
                Json::Num(r.peak_pending as f64),
            );
            m.insert("makespan_s".to_string(), Json::Num(r.makespan_s));
            m.insert(
                "jobs_per_sec".to_string(),
                Json::Num(r.jobs_per_sec),
            );
            m.insert(
                "p99_job_latency_s".to_string(),
                Json::Num(r.p99_job_latency_s),
            );
            Json::Obj(m)
        })
        .collect();
    let mut top = BTreeMap::new();
    top.insert(
        "bench".to_string(),
        Json::Str("wukong-sim-hotpath".to_string()),
    );
    top.insert(
        "pr".to_string(),
        Json::Str(TRAJECTORY_POINT.to_string()),
    );
    top.insert("quick".to_string(), Json::Bool(opts.quick));
    top.insert("seed".to_string(), Json::Num(opts.seed as f64));
    top.insert("records".to_string(), Json::Arr(recs));
    Json::Obj(top).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budgets_full_mode_hits_a_million_tasks_on_wukong() {
        assert_eq!(fanout_tasks("wukong", false), 1_000_000);
        assert_eq!(chain_tasks("wukong", false), 1_000_000);
        // TSQR R-only: ~4 tasks per leaf ⇒ the 2^18-leaf shape crosses 1M.
        assert!(tsqr_leaves("wukong", false) * 4 >= 1_000_000);
        // Baselines get smaller (but still large) budgets.
        assert!(fanout_tasks("dask125", false) >= 10_000);
        assert!(fanout_tasks("numpywren", false) >= 50_000);
    }

    #[test]
    fn quick_mode_shrinks_every_budget() {
        for e in ["wukong", "numpywren", "pywren", "dask125", "dask1000"] {
            assert!(fanout_tasks(e, true) * 10 < fanout_tasks(e, false));
            assert!(chain_tasks(e, true) * 10 < chain_tasks(e, false));
            assert!(tsqr_leaves(e, true) < tsqr_leaves(e, false));
        }
    }

    #[test]
    fn unknown_engine_is_an_error() {
        let err = run_bench(&BenchOptions {
            engines: vec!["warp-drive".into()],
            ..BenchOptions::default()
        })
        .unwrap_err();
        assert!(err.contains("unknown engine"), "{err}");
    }

    #[test]
    fn default_out_path_tracks_the_trajectory_point() {
        assert_eq!(
            default_out_path(),
            format!("BENCH_{TRAJECTORY_POINT}.json")
        );
        assert!(default_out_path().starts_with("BENCH_"));
    }

    #[test]
    fn json_schema_round_trips() {
        let rec = BenchRecord {
            engine: "wukong",
            workload: "fanout",
            tasks: 1_000_000,
            wall_ms: 1234.5,
            sim_events: 5_000_000,
            events_per_sec: 4.05e6,
            peak_pending: 1_000_000,
            makespan_s: 2.5,
            jobs_per_sec: 12.5,
            p99_job_latency_s: 0.75,
        };
        let text = to_json(&[rec], &BenchOptions::default());
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.get("pr").unwrap().as_str(), Some(TRAJECTORY_POINT));
        let recs = j.get("records").unwrap().as_arr().unwrap();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].get("engine").unwrap().as_str(), Some("wukong"));
        assert_eq!(recs[0].get("tasks").unwrap().as_u64(), Some(1_000_000));
        assert_eq!(
            recs[0].get("peak_pending").unwrap().as_u64(),
            Some(1_000_000)
        );
        assert_eq!(recs[0].get("jobs_per_sec").unwrap().as_f64(), Some(12.5));
        assert_eq!(
            recs[0].get("p99_job_latency_s").unwrap().as_f64(),
            Some(0.75)
        );
    }

    #[test]
    fn quick_smoke_on_the_wukong_engine() {
        // A tiny end-to-end sweep: completion-checked runs over all three
        // DAG families plus the dynamic fan-out and multi-tenant
        // jobstream tiers (debug-build friendly sizes).
        let recs = run_bench(&BenchOptions {
            quick: true,
            engines: vec!["wukong".into()],
            seed: 7,
        })
        .unwrap();
        assert_eq!(recs.len(), 5);
        for r in &recs {
            assert!(r.sim_events > 0, "{:?}", r);
            assert!(r.events_per_sec > 0.0);
            assert!(r.peak_pending > 0);
            assert!(r.tasks >= 64);
        }
        let dy = &recs[3];
        assert_eq!(dy.workload, "dynfan");
        // 2,500 base tasks × the 21-task subtree (1 + 4 + 16) at p=1.
        assert_eq!(dy.tasks, 2_500 * 21);
        let js = recs.last().unwrap();
        assert_eq!(js.workload, "jobstream");
        assert!(js.jobs_per_sec > 0.0);
        assert!(js.p99_job_latency_s > 0.0);
        // The DAG-family and dynfan rows never fill the jobstream-only
        // columns.
        assert!(recs[..4].iter().all(|r| r.jobs_per_sec == 0.0));
    }
}
