//! The real execution engine: the same Wukong policies as the simulator,
//! but on OS threads with *real* compute (PJRT execution of the AOT
//! JAX/Pallas artifacts) and a real in-memory KVS.
//!
//! An "executor" is a thread-pool job (the pool size models the Lambda
//! concurrency limit); invocation latency and KVS wire latency are
//! injected from the same platform constants the simulator uses, scaled
//! by `latency_scale` so examples run quickly on one machine. Numerics
//! are end-to-end real: the TSQR example checks Q·R = A and QᵀQ = I
//! through the full decentralized execution.

pub mod compute;
pub mod real_numpywren;
pub mod real_wukong;
pub mod traits;

pub use compute::{seed_inputs, TaskComputer};
pub use real_numpywren::run_real_numpywren;
pub use real_wukong::{run_real_wukong, RealConfig, RealReport};
pub use traits::{
    engine_by_name, select_engines, sim_engine_names, sim_registry, Engine,
    EngineCaps, EngineReport, RealNumpywrenEngine, RealWukongEngine, SimDask,
    SimNumpywren, SimPywren, SimWukong,
};
