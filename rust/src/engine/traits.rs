//! The unified [`Engine`] abstraction: one contract that every execution
//! backend — the discrete-event Wukong engine, the numpywren / PyWren /
//! Dask baseline models, and the real PJRT engines — implements behind a
//! thin adapter.
//!
//! The paper's methodology drives *the exact same input DAG* through
//! several engines and compares normalized meters (makespan, KVS bytes,
//! per-task execution counts). Before this trait existed each engine had
//! an ad-hoc entry point (`run_wukong`, `run_numpywren`, `run_dask`, ...)
//! and nothing enforced that they agree; the [`crate::verify`] harness
//! now sweeps a DAG corpus through every registered engine via this
//! trait and asserts the cross-engine invariants (exactly-once,
//! completion, per-seed determinism, Wukong bytes ≤ stateless bytes).
//!
//! Sim-path engines are pure functions of `(dag, config, seed)` and are
//! always registered; the real engines need AOT artifacts + a PJRT
//! backend and are only constructible when those are present
//! ([`RealWukongEngine::try_new`]).

use std::sync::Arc;

use crate::baselines::{run_dask_full, run_numpywren_full, run_pywren_full};
use crate::config::{Config, DaskConfig};
use crate::coordinator::run_wukong;
use crate::dag::Dag;
use crate::metrics::{RunMetrics, TaskOutcome};
use crate::runtime::SharedRuntime;
use crate::storage::real_kvs::RealKvs;

use super::compute::seed_inputs;
use super::real_numpywren::run_real_numpywren;
use super::real_wukong::{run_real_wukong, RealConfig, RealReport};

/// What an engine is, structurally — used by the conformance harness to
/// decide which invariants apply (e.g. the locality-ordering bound only
/// binds engines that meter KVS traffic).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EngineCaps {
    /// Scheduling decisions are made by the executors themselves (§3.3)
    /// rather than a central scheduler.
    pub decentralized: bool,
    /// Executors keep parent outputs resident between tasks (locality);
    /// stateless engines round-trip everything through the KVS.
    pub stateful_executors: bool,
    /// Runs on ephemeral serverless executors (vs a serverful VM pool).
    pub serverless: bool,
    /// Intermediate objects flow through the metered KVS, so the report's
    /// `kvs` byte counters are meaningful and byte-exact.
    pub meters_kvs: bool,
    /// Consumes `Config::faults` (§3.6 retry contract): the fault axis of
    /// `wukong verify --faults` only sweeps engines that set this. All
    /// sim-path engines do; the wall-clock real engines do not.
    pub supports_faults: bool,
    /// Consumes `Config::spawn` (dynamic DAGs): the dynamic axis of
    /// `wukong verify --dynamic` only sweeps engines that set this. All
    /// sim-path engines do; the wall-clock real engines do not.
    pub supports_spawning: bool,
}

impl Default for EngineCaps {
    fn default() -> Self {
        EngineCaps {
            decentralized: false,
            stateful_executors: false,
            serverless: true,
            meters_kvs: true,
            supports_faults: true,
            supports_spawning: true,
        }
    }
}

/// Normalized result of one engine run: the shared [`RunMetrics`] plus
/// engine-specific extras that matter for conformance and `wukong bench`.
#[derive(Debug, Clone)]
pub struct EngineReport {
    /// Registry name of the engine that produced this report.
    pub engine: &'static str,
    /// Normalized meters (makespan, KVS bytes, per-task counts, ...).
    pub metrics: RunMetrics,
    /// DES events processed, when the engine is simulator-backed (used by
    /// the determinism check: same seed ⇒ same event count, and by
    /// `wukong bench`: events/sec).
    pub sim_events: Option<u64>,
    /// High-water mark of the pending-event calendar depth, when the
    /// engine is simulator-backed (`wukong bench` memory-pressure proxy).
    pub peak_pending: Option<usize>,
}

/// A DAG execution engine. `run` must be a deterministic function of
/// `(dag, cfg, seed)` for sim-path engines (the conformance harness
/// asserts it); real engines are wall-clock-timed and exempt from the
/// determinism invariant but not from exactly-once/completion.
pub trait Engine {
    /// Stable registry name (`wukong`, `numpywren`, `dask1000`, ...).
    fn name(&self) -> &'static str;

    /// Structural capabilities (drives which invariants are checked).
    fn caps(&self) -> EngineCaps;

    /// Execute `dag` under `cfg` with `seed` and report normalized meters.
    fn run(&self, dag: &Dag, cfg: &Config, seed: u64) -> EngineReport;
}

/// The decentralized Wukong engine on the discrete-event simulator.
/// Fault injection (§3.6) is carried by `Config::faults`, like every
/// other sim engine.
#[derive(Debug, Clone, Copy, Default)]
pub struct SimWukong;

impl Engine for SimWukong {
    fn name(&self) -> &'static str {
        "wukong"
    }

    fn caps(&self) -> EngineCaps {
        EngineCaps {
            decentralized: true,
            stateful_executors: true,
            serverless: true,
            meters_kvs: true,
            supports_faults: true,
            supports_spawning: true,
        }
    }

    fn run(&self, dag: &Dag, cfg: &Config, seed: u64) -> EngineReport {
        let r = run_wukong(dag, cfg, seed);
        EngineReport {
            engine: self.name(),
            metrics: r.metrics,
            sim_events: Some(r.sim_events),
            peak_pending: Some(r.peak_pending),
        }
    }
}

/// The centralized, stateless numpywren baseline model.
#[derive(Debug, Clone, Copy, Default)]
pub struct SimNumpywren;

impl Engine for SimNumpywren {
    fn name(&self) -> &'static str {
        "numpywren"
    }

    fn caps(&self) -> EngineCaps {
        EngineCaps::default()
    }

    fn run(&self, dag: &Dag, cfg: &Config, seed: u64) -> EngineReport {
        let r = run_numpywren_full(dag, cfg, seed);
        EngineReport {
            engine: self.name(),
            metrics: r.metrics,
            sim_events: Some(r.sim_events),
            peak_pending: Some(r.peak_pending),
        }
    }
}

/// PyWren scaling configuration: numpywren's substrate with one worker
/// per static schedule (leaf) unless pinned.
#[derive(Debug, Clone, Copy, Default)]
pub struct SimPywren {
    /// Worker count override; `None` = one per DAG leaf (the paper's
    /// serverless-scaling setup, Figs. 2/21).
    pub n_workers: Option<usize>,
}

impl Engine for SimPywren {
    fn name(&self) -> &'static str {
        "pywren"
    }

    fn caps(&self) -> EngineCaps {
        EngineCaps::default()
    }

    fn run(&self, dag: &Dag, cfg: &Config, seed: u64) -> EngineReport {
        let n = self.n_workers.unwrap_or_else(|| dag.leaves().len().max(1));
        let r = run_pywren_full(dag, cfg, n, seed);
        EngineReport {
            engine: self.name(),
            metrics: r.metrics,
            sim_events: Some(r.sim_events),
            peak_pending: Some(r.peak_pending),
        }
    }
}

/// Serverful Dask-distributed model (paper's Dask-125 / Dask-1000).
#[derive(Debug, Clone)]
pub struct SimDask {
    name: &'static str,
    dcfg: DaskConfig,
}

impl SimDask {
    /// 1000 × 2-core workers (the scheduler-bound worst case).
    pub fn workers_1000() -> SimDask {
        SimDask {
            name: "dask1000",
            dcfg: DaskConfig::workers_1000(),
        }
    }

    /// 125 × 16-core workers (the serverful best case).
    pub fn workers_125() -> SimDask {
        SimDask {
            name: "dask125",
            dcfg: DaskConfig::workers_125(),
        }
    }
}

impl Engine for SimDask {
    fn name(&self) -> &'static str {
        self.name
    }

    fn caps(&self) -> EngineCaps {
        EngineCaps {
            decentralized: false,
            stateful_executors: true,
            serverless: false,
            // Dask moves data peer-to-peer between workers, not through
            // the metered KVS; its kvs counters stay 0.
            meters_kvs: false,
            supports_faults: true,
            supports_spawning: true,
        }
    }

    fn run(&self, dag: &Dag, cfg: &Config, seed: u64) -> EngineReport {
        let r = run_dask_full(dag, cfg, &self.dcfg, seed);
        EngineReport {
            engine: self.name(),
            metrics: r.metrics,
            sim_events: Some(r.sim_events),
            peak_pending: Some(r.peak_pending),
        }
    }
}

/// Convert a wall-clock [`RealReport`] into normalized metrics. The real
/// engines run fault-free, so their attempt/outcome vectors mirror the
/// execution counts (every task one attempt, all completed).
fn real_metrics(rep: &RealReport) -> RunMetrics {
    RunMetrics {
        makespan_s: rep.makespan.as_secs_f64(),
        tasks_executed: rep.tasks_executed,
        executors_used: rep.executors_used,
        invocations: rep.executors_used,
        kvs: crate::storage::KvsMetrics {
            bytes_read: rep.kvs_bytes_read,
            bytes_written: rep.kvs_bytes_written,
            reads: rep.kvs_reads,
            writes: rep.kvs_writes,
        },
        per_task_attempts: rep.per_task_exec.clone(),
        per_task_outcome: vec![
            TaskOutcome::Completed;
            rep.per_task_exec.len()
        ],
        per_task_exec: rep.per_task_exec.clone(),
        ..RunMetrics::default()
    }
}

/// The real (thread-pool + PJRT) Wukong engine behind the shared trait.
/// Requires AOT artifacts; construct via [`RealWukongEngine::try_new`].
pub struct RealWukongEngine {
    rt: Arc<SharedRuntime>,
    rcfg: RealConfig,
}

impl RealWukongEngine {
    /// `None` when artifacts or the PJRT backend are unavailable.
    pub fn try_new() -> Option<RealWukongEngine> {
        Some(RealWukongEngine {
            rt: SharedRuntime::try_load_default()?,
            rcfg: RealConfig::default(),
        })
    }

    pub fn with(rt: Arc<SharedRuntime>, rcfg: RealConfig) -> RealWukongEngine {
        RealWukongEngine { rt, rcfg }
    }
}

impl Engine for RealWukongEngine {
    fn name(&self) -> &'static str {
        "real-wukong"
    }

    fn caps(&self) -> EngineCaps {
        EngineCaps {
            decentralized: true,
            stateful_executors: true,
            serverless: true,
            meters_kvs: true,
            supports_faults: false,
            supports_spawning: false,
        }
    }

    fn run(&self, dag: &Dag, cfg: &Config, seed: u64) -> EngineReport {
        let kvs = RealKvs::new(cfg.storage.n_shards.max(1), 0.0, 0.0);
        seed_inputs(dag, &kvs, seed);
        let rep = run_real_wukong(dag, Arc::clone(&self.rt), kvs, self.rcfg.clone())
            .unwrap_or_else(|e| panic!("real-wukong run failed: {e}"));
        EngineReport {
            engine: self.name(),
            metrics: real_metrics(&rep),
            sim_events: None,
            peak_pending: None,
        }
    }
}

/// The real stateless numpywren baseline behind the shared trait.
pub struct RealNumpywrenEngine {
    rt: Arc<SharedRuntime>,
    rcfg: RealConfig,
}

impl RealNumpywrenEngine {
    /// `None` when artifacts or the PJRT backend are unavailable.
    pub fn try_new() -> Option<RealNumpywrenEngine> {
        Some(RealNumpywrenEngine {
            rt: SharedRuntime::try_load_default()?,
            rcfg: RealConfig::default(),
        })
    }

    pub fn with(rt: Arc<SharedRuntime>, rcfg: RealConfig) -> RealNumpywrenEngine {
        RealNumpywrenEngine { rt, rcfg }
    }
}

impl Engine for RealNumpywrenEngine {
    fn name(&self) -> &'static str {
        "real-numpywren"
    }

    fn caps(&self) -> EngineCaps {
        EngineCaps {
            // Wall-clock engine: no fault injection, no runtime spawning.
            supports_faults: false,
            supports_spawning: false,
            ..EngineCaps::default()
        }
    }

    fn run(&self, dag: &Dag, cfg: &Config, seed: u64) -> EngineReport {
        let kvs = RealKvs::new(cfg.storage.n_shards.max(1), 0.0, 0.0);
        seed_inputs(dag, &kvs, seed);
        let rep = run_real_numpywren(dag, Arc::clone(&self.rt), kvs, self.rcfg.clone())
            .unwrap_or_else(|e| panic!("real-numpywren run failed: {e}"));
        EngineReport {
            engine: self.name(),
            metrics: real_metrics(&rep),
            sim_events: None,
            peak_pending: None,
        }
    }
}

/// Every sim-path engine, in paper-comparison order. These need no
/// artifacts and are the default `wukong verify` matrix.
pub fn sim_registry() -> Vec<Box<dyn Engine>> {
    vec![
        Box::new(SimWukong::default()),
        Box::new(SimNumpywren),
        Box::new(SimPywren::default()),
        Box::new(SimDask::workers_125()),
        Box::new(SimDask::workers_1000()),
    ]
}

/// Names of every sim-path engine (CLI help / error messages).
pub fn sim_engine_names() -> Vec<&'static str> {
    sim_registry().iter().map(|e| e.name()).collect()
}

/// Look up a sim-path engine by registry name.
pub fn engine_by_name(name: &str) -> Option<Box<dyn Engine>> {
    sim_registry().into_iter().find(|e| e.name() == name)
}

/// Resolve a CLI engine selection against the sim registry: empty =
/// every sim-path engine; an unknown name is an error listing the known
/// ones. Shared by `wukong verify` and `wukong bench`.
pub fn select_engines(names: &[String]) -> Result<Vec<Box<dyn Engine>>, String> {
    if names.is_empty() {
        return Ok(sim_registry());
    }
    names
        .iter()
        .map(|n| {
            engine_by_name(n).ok_or_else(|| {
                format!(
                    "unknown engine {n:?} (known: {})",
                    sim_engine_names().join(" ")
                )
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::{DagBuilder, OpKind};

    fn diamond() -> Dag {
        let mut b = DagBuilder::new("diamond");
        let a = b.task("a", OpKind::Generic, 1e6, 100);
        let x = b.task("x", OpKind::Generic, 1e6, 100);
        let y = b.task("y", OpKind::Generic, 1e6, 100);
        let d = b.task("d", OpKind::Generic, 1e6, 100);
        b.edge(a, x).edge(a, y).edge(x, d).edge(y, d);
        b.build().unwrap()
    }

    #[test]
    fn registry_names_are_unique() {
        let names = sim_engine_names();
        let mut dedup = names.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), names.len(), "{names:?}");
        assert!(names.len() >= 3);
    }

    #[test]
    fn lookup_by_name_round_trips() {
        for name in sim_engine_names() {
            let e = engine_by_name(name).unwrap_or_else(|| panic!("{name}"));
            assert_eq!(e.name(), name);
        }
        assert!(engine_by_name("nope").is_none());
    }

    #[test]
    fn every_sim_engine_reports_per_task_counts() {
        let dag = diamond();
        let cfg = Config::default();
        for e in sim_registry() {
            let r = e.run(&dag, &cfg, 7);
            assert_eq!(r.engine, e.name());
            assert_eq!(
                r.metrics.per_task_exec,
                vec![1; dag.len()],
                "{} per-task counts",
                e.name()
            );
            assert_eq!(r.metrics.tasks_executed as usize, dag.len(), "{}", e.name());
        }
    }

    #[test]
    fn every_sim_engine_reports_des_stats() {
        // All five sim engines are simulator-backed: `wukong bench` and
        // the determinism check rely on their event counters being
        // present.
        let dag = diamond();
        let cfg = Config::default();
        for e in sim_registry() {
            let r = e.run(&dag, &cfg, 3);
            assert!(r.sim_events.unwrap_or(0) > 0, "{}", e.name());
            assert!(r.peak_pending.unwrap_or(0) > 0, "{}", e.name());
        }
    }

    #[test]
    fn every_sim_engine_supports_faults() {
        for e in sim_registry() {
            assert!(e.caps().supports_faults, "{}", e.name());
        }
    }

    #[test]
    fn every_sim_engine_supports_spawning() {
        for e in sim_registry() {
            assert!(e.caps().supports_spawning, "{}", e.name());
        }
    }

    #[test]
    fn every_sim_engine_expands_spawn_plans_like_the_static_dag() {
        // The trait-level differential gate: a live plan run dynamically
        // must be byte-identical to the statically pre-expanded DAG run
        // plan-free — on every registered sim engine.
        use crate::dag::{pre_expand, SpawnPlan};
        let dag = diamond();
        let mut cfg = Config::default();
        cfg.spawn = SpawnPlan::recursive(1.0, 2, 2);
        let seed = 17;
        let expanded = pre_expand(&dag, cfg.spawn, seed);
        assert_eq!(expanded.len(), dag.len() + dag.len() * 6);
        let mut static_cfg = cfg.clone();
        static_cfg.spawn = SpawnPlan::default();
        for e in sim_registry() {
            let dy = e.run(&dag, &cfg, seed);
            let st = e.run(&expanded, &static_cfg, seed);
            assert_eq!(dy.metrics, st.metrics, "{}", e.name());
            assert_eq!(dy.sim_events, st.sim_events, "{}", e.name());
            assert_eq!(dy.peak_pending, st.peak_pending, "{}", e.name());
            assert_eq!(
                dy.metrics.tasks_executed,
                expanded.len() as u64,
                "{}",
                e.name()
            );
        }
    }

    #[test]
    fn every_sim_engine_honors_config_faults() {
        // p=1 with no retries: nothing executes and every task is
        // reported failed — through the shared trait, on each engine.
        use crate::platform::faults::FaultPlan;
        let dag = diamond();
        let mut cfg = Config::default();
        cfg.faults = FaultPlan::with_retries(1.0, 0);
        for e in sim_registry() {
            let r = e.run(&dag, &cfg, 11);
            assert_eq!(r.metrics.tasks_executed, 0, "{}", e.name());
            assert_eq!(
                r.metrics.failed_tasks,
                dag.len() as u64,
                "{}",
                e.name()
            );
            assert!(
                r.metrics
                    .per_task_outcome
                    .iter()
                    .all(|&o| o == TaskOutcome::Failed),
                "{}",
                e.name()
            );
        }
    }

    #[test]
    fn wukong_is_the_only_decentralized_sim_engine() {
        let decentralized: Vec<&str> = sim_registry()
            .iter()
            .filter(|e| e.caps().decentralized)
            .map(|e| e.name())
            .collect();
        assert_eq!(decentralized, vec!["wukong"]);
    }

    #[test]
    fn dask_does_not_meter_kvs() {
        let dag = diamond();
        let e = SimDask::workers_125();
        assert!(!e.caps().meters_kvs);
        let r = e.run(&dag, &Config::default(), 1);
        assert_eq!(r.metrics.kvs.bytes_written, 0);
    }
}
