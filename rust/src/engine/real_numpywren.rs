//! Real-engine numpywren baseline: a central ready queue and *stateless*
//! worker threads — every input read from the KVS, every output written
//! back. The end-to-end example compares this against real Wukong to
//! reproduce the paper's headline speedup/IO-reduction shape with real
//! numerics.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::dag::{Dag, TaskId};
use crate::runtime::SharedRuntime;
use crate::storage::real_kvs::RealKvs;

use super::compute::{
    input_key, obj_from_bytes, obj_key, obj_to_bytes, Obj, TaskComputer,
};
use super::real_wukong::{RealConfig, RealReport};

struct Shared {
    dag: Dag,
    kvs: RealKvs,
    computer: TaskComputer,
    queue: Mutex<VecDeque<TaskId>>,
    remaining: Vec<AtomicU32>,
    /// Per-task execution counters (fail-fast on 2; see RunMetrics).
    executed: Vec<AtomicU32>,
    done: AtomicU64,
    outputs: Mutex<HashMap<String, Obj>>,
    errors: Mutex<Vec<String>>,
}

fn worker(sh: &Arc<Shared>) {
    let n = sh.dag.len() as u64;
    loop {
        if sh.done.load(Ordering::SeqCst) >= n
            || !sh.errors.lock().unwrap().is_empty()
        {
            return;
        }
        let task = sh.queue.lock().unwrap().pop_front();
        let Some(t) = task else {
            std::thread::sleep(Duration::from_micros(200)); // poll interval
            continue;
        };
        // Stateless: read every input from the KVS.
        let mut parent_objs = Vec::with_capacity(sh.dag.indegree(t));
        let mut ok = true;
        for &p in sh.dag.parents(t) {
            match sh
                .kvs
                .get_blocking(&obj_key(p), Duration::from_secs(60))
                .ok_or_else(|| anyhow!("timeout on obj:{p}"))
                .and_then(|b| obj_from_bytes(&b))
            {
                Ok(o) => parent_objs.push(Arc::new(o)),
                Err(e) => {
                    sh.errors
                        .lock()
                        .unwrap()
                        .push(format!("{}: {e}", sh.dag.task_name(t)));
                    ok = false;
                    break;
                }
            }
        }
        if !ok {
            continue;
        }
        let ext = input_key(&sh.dag, t).and_then(|k| {
            sh.kvs
                .get(&k)
                .and_then(|b| obj_from_bytes(&b).ok().map(Arc::new))
        });
        match sh.computer.compute(&sh.dag, t, &parent_objs, ext) {
            Ok(out) => {
                assert!(
                    sh.executed[t as usize].fetch_add(1, Ordering::SeqCst) == 0,
                    "task {t} executed twice"
                );
                // Stateless: write the full output back.
                sh.kvs.put(&obj_key(t), obj_to_bytes(&out));
                if sh.dag.children(t).is_empty() {
                    sh.outputs
                        .lock()
                        .unwrap()
                        .insert(sh.dag.task_name(t).to_string(), out);
                }
                let mut q = sh.queue.lock().unwrap();
                for &c in sh.dag.children(t) {
                    if sh.remaining[c as usize].fetch_sub(1, Ordering::SeqCst)
                        == 1
                    {
                        q.push_back(c);
                    }
                }
                drop(q);
                sh.done.fetch_add(1, Ordering::SeqCst);
            }
            Err(e) => {
                sh.errors
                    .lock()
                    .unwrap()
                    .push(format!("{}: {e}", sh.dag.task_name(t)));
            }
        }
    }
}

/// Run the numpywren-style baseline with `cfg.n_threads` stateless
/// workers.
pub fn run_real_numpywren(
    dag: &Dag,
    rt: Arc<SharedRuntime>,
    kvs: RealKvs,
    cfg: RealConfig,
) -> Result<RealReport> {
    let n = dag.len();
    let sh = Arc::new(Shared {
        dag: dag.clone(),
        kvs,
        computer: TaskComputer { rt },
        queue: Mutex::new(dag.leaves().iter().copied().collect()),
        remaining: (0..n as u32)
            .map(|t| AtomicU32::new(dag.indegree(t) as u32))
            .collect(),
        executed: (0..n).map(|_| AtomicU32::new(0)).collect(),
        done: AtomicU64::new(0),
        outputs: Mutex::new(HashMap::new()),
        errors: Mutex::new(Vec::new()),
    });
    let start = Instant::now();
    let handles: Vec<_> = (0..cfg.n_threads)
        .map(|_| {
            std::thread::sleep(cfg.invoke_latency); // provisioner launch
            let sh2 = Arc::clone(&sh);
            std::thread::spawn(move || worker(&sh2))
        })
        .collect();
    for h in handles {
        h.join().map_err(|_| anyhow!("worker panicked"))?;
    }
    let makespan = start.elapsed();
    let errors = sh.errors.lock().unwrap();
    if !errors.is_empty() {
        return Err(anyhow!("run failed: {}", errors.join("; ")));
    }
    let done = sh.done.load(Ordering::SeqCst);
    if done != n as u64 {
        return Err(anyhow!("only {done}/{n} tasks executed"));
    }
    Ok(RealReport {
        makespan,
        tasks_executed: done,
        executors_used: cfg.n_threads as u64,
        kvs_bytes_read: sh.kvs.bytes_read.load(Ordering::Relaxed),
        kvs_bytes_written: sh.kvs.bytes_written.load(Ordering::Relaxed),
        kvs_reads: sh.kvs.reads.load(Ordering::Relaxed),
        kvs_writes: sh.kvs.writes.load(Ordering::Relaxed),
        per_task_exec: sh
            .executed
            .iter()
            .map(|e| e.load(Ordering::SeqCst))
            .collect(),
        outputs: {
            let mut guard = sh.outputs.lock().unwrap();
            std::mem::take(&mut *guard)
        },
    })
}
