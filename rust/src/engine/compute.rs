//! Task-body computation for the real engine: maps each DAG op kind to an
//! AOT artifact call (or a pure extraction) and assembles its inputs.
//!
//! Objects flowing between tasks are `Vec<Tensor>` bundles (a QR task's
//! object is `[Q, R]`). External input partitions are seeded into the
//! KVS under name-derived keys (`A:i:k`, `B:k:j`, `Ablk:i`, `in:<task>`),
//! mirroring how the paper's client uploads input partitions before the
//! job starts.

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::dag::{Dag, OpKind, TaskId};
use crate::runtime::{SharedRuntime, Tensor};
use crate::storage::real_kvs::RealKvs;
use crate::util::Rng;

/// An intermediate object: one or more tensors.
pub type Obj = Vec<Tensor>;

/// Serialize an object (tensor bundle) to bytes.
pub fn obj_to_bytes(obj: &Obj) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&(obj.len() as u32).to_le_bytes());
    for t in obj {
        let b = t.to_bytes();
        out.extend_from_slice(&(b.len() as u32).to_le_bytes());
        out.extend_from_slice(&b);
    }
    out
}

/// Deserialize an object.
pub fn obj_from_bytes(b: &[u8]) -> Result<Obj> {
    if b.len() < 4 {
        bail!("object blob too short");
    }
    let count = u32::from_le_bytes(b[0..4].try_into()?) as usize;
    let mut off = 4;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        if b.len() < off + 4 {
            bail!("object blob truncated");
        }
        let n = u32::from_le_bytes(b[off..off + 4].try_into()?) as usize;
        off += 4;
        out.push(Tensor::from_bytes(
            b.get(off..off + n).ok_or_else(|| anyhow!("short tensor"))?,
        )?);
        off += n;
    }
    Ok(out)
}

/// Executes task bodies against the PJRT runtime.
pub struct TaskComputer {
    pub rt: Arc<SharedRuntime>,
}

impl TaskComputer {
    /// Run task `t`; `parent_objs` are in DAG parent order; `ext` is the
    /// task's external input bundle (if any).
    pub fn compute(
        &self,
        dag: &Dag,
        t: TaskId,
        parent_objs: &[Arc<Obj>],
        ext: Option<Arc<Obj>>,
    ) -> Result<Obj> {
        let node = dag.task(t);
        let name = dag.task_name(t);
        let one = |i: usize| -> Result<&Tensor> {
            parent_objs
                .get(i)
                .and_then(|o| o.first())
                .ok_or_else(|| anyhow!("{name}: missing parent {i}"))
        };
        match node.op {
            OpKind::Noop | OpKind::Sleep => {
                if let Some(d) = node.dur_override {
                    std::thread::sleep(std::time::Duration::from_micros(d));
                }
                Ok(vec![Tensor::new(vec![1], vec![0.0])])
            }
            OpKind::TrAdd => {
                let (x, y) = if parent_objs.is_empty() {
                    let e = ext.ok_or_else(|| anyhow!("TR leaf without input"))?;
                    (e[0].clone(), e[1].clone())
                } else {
                    (one(0)?.clone(), one(1)?.clone())
                };
                Ok(self.rt.execute("tr_add_f32_8192", &[x, y])?)
            }
            OpKind::TrRoot => {
                Ok(self.rt.execute("tr_root_f32_8192", &[one(0)?.clone()])?)
            }
            OpKind::GemmBlock => {
                let e = ext.ok_or_else(|| anyhow!("GEMM leaf without input"))?;
                Ok(self
                    .rt
                    .execute("gemm_block_f32_256", &[e[0].clone(), e[1].clone()])?)
            }
            OpKind::BlockAdd => {
                let a = one(0)?.clone();
                let b = one(1)?.clone();
                if a.shape == vec![256, 256] {
                    Ok(self.rt.execute("block_add_f32_256", &[a, b])?)
                } else {
                    // SVD Gram sums etc. fall back to element-wise CPU add.
                    let data = a
                        .data
                        .iter()
                        .zip(&b.data)
                        .map(|(x, y)| x + y)
                        .collect();
                    Ok(vec![Tensor::new(a.shape.clone(), data)])
                }
            }
            OpKind::QrFactor => {
                let e = ext.ok_or_else(|| anyhow!("QR leaf without input"))?;
                Ok(self.rt.execute("qr_factor_f32_1024x128", &[e[0].clone()])?)
            }
            OpKind::RExtract => {
                // Peel R (the last tensor) off a [Q, R] bundle.
                Ok(vec![parent_objs[0]
                    .last()
                    .ok_or_else(|| anyhow!("empty bundle"))?
                    .clone()])
            }
            OpKind::QrMerge => {
                // Parents are [Q, R] bundles: merge their R factors.
                let r_top = parent_objs[0]
                    .last()
                    .ok_or_else(|| anyhow!("empty parent"))?
                    .clone();
                let r_bot = parent_objs[1]
                    .last()
                    .ok_or_else(|| anyhow!("empty parent"))?
                    .clone();
                Ok(self.rt.execute("qr_merge_f32_128", &[r_top, r_bot])?)
            }
            OpKind::QApplyLeaf => match parent_objs.len() {
                // Q extraction from a [Q, R] bundle (zero-flop task).
                1 => Ok(vec![parent_objs[0][0].clone()]),
                // Final panel: Q_leaf · path-product (parents: [q], [prod]).
                2 => {
                    let q = parent_objs[0][0].clone();
                    let p = parent_objs[1][0].clone();
                    Ok(self.rt.execute("q_apply_leaf_f32_1024x128", &[p, q])?)
                }
                n => bail!("QApplyLeaf with {n} parents"),
            },
            OpKind::QApplyHalf => match parent_objs.len() {
                // Half extraction from the merge's (2c × c) Q.
                1 => {
                    let qm = &parent_objs[0][0];
                    let (rows, cols) = (qm.shape[0], qm.shape[1]);
                    let half = rows / 2;
                    // which half: task names end in _0 (top) / _1 (bottom)
                    let bottom = name.ends_with("_1");
                    let start = if bottom { half * cols } else { 0 };
                    Ok(vec![Tensor::new(
                        vec![half, cols],
                        qm.data[start..start + half * cols].to_vec(),
                    )])
                }
                // Path product: parents [parent_prod, half] → half · prod.
                2 => {
                    let prod = parent_objs[0][0].clone();
                    let half = parent_objs[1][0].clone();
                    Ok(self.rt.execute("q_apply_half_f32_128", &[prod, half])?)
                }
                n => bail!("QApplyHalf with {n} parents"),
            },
            OpKind::Gram => {
                let e = ext.ok_or_else(|| anyhow!("Gram leaf without input"))?;
                Ok(self.rt.execute("gram_f32_1024x128", &[e[0].clone()])?)
            }
            OpKind::Svd1Finish => {
                Ok(self.rt.execute("svd1_finish_f32_128", &[one(0)?.clone()])?)
            }
            OpKind::GemmAcc => {
                // C += A·B chain step: parents [c], ext [a, b].
                let c = one(0)?.clone();
                let e = ext.ok_or_else(|| anyhow!("GemmAcc without input"))?;
                Ok(self.rt.execute(
                    "gemm_acc_f32_256",
                    &[c, e[0].clone(), e[1].clone()],
                )?)
            }
            OpKind::SvcGrad | OpKind::SvcUpdate | OpKind::Generic => {
                bail!("{:?} is sim-only (no real-engine mapping)", node.op)
            }
        }
    }
}

/// KVS key for a task's output object.
pub fn obj_key(t: TaskId) -> String {
    format!("obj:{t}")
}

/// KVS key for a task's external input bundle.
pub fn input_key(dag: &Dag, t: TaskId) -> Option<String> {
    let node = dag.task(t);
    if node.input_bytes == 0 {
        return None;
    }
    // GEMM partials share input blocks: mul_{i}_{j}_{k} reads A:i:k, B:k:j
    // (resolved in `seed_inputs` as a combined bundle per task).
    Some(format!("in:{}", dag.task_name(t)))
}

/// Seed external input partitions for a real run. Returns the RNG-backed
/// ground-truth blocks for client-side verification, keyed by KVS key.
pub fn seed_inputs(dag: &Dag, kvs: &RealKvs, seed: u64) -> Vec<(String, Obj)> {
    let mut rng = Rng::new(seed);
    let mut seeded = Vec::new();
    // GEMM needs *consistent* shared blocks: generate A/B block pools
    // keyed by indices parsed from task names.
    let mut gemm_pool: std::collections::HashMap<String, Tensor> =
        std::collections::HashMap::new();
    for (id, node) in dag.tasks().iter().enumerate() {
        if node.input_bytes == 0 {
            continue;
        }
        let t = id as TaskId;
        let key = input_key(dag, t).unwrap();
        let obj: Obj = match node.op {
            OpKind::TrAdd => vec![
                Tensor::new(vec![8192], rng.f32_vec(8192)),
                Tensor::new(vec![8192], rng.f32_vec(8192)),
            ],
            OpKind::GemmBlock => {
                // name: mul_{i}_{j}_{k} → A[i,k], B[k,j]
                let parts: Vec<&str> = dag.task_name(t).split('_').collect();
                let (i, j, k) = (parts[1], parts[2], parts[3]);
                let a = gemm_pool
                    .entry(format!("A:{i}:{k}"))
                    .or_insert_with(|| {
                        Tensor::new(vec![256, 256], rng.f32_vec(256 * 256))
                    })
                    .clone();
                let b = gemm_pool
                    .entry(format!("B:{k}:{j}"))
                    .or_insert_with(|| {
                        Tensor::new(vec![256, 256], rng.f32_vec(256 * 256))
                    })
                    .clone();
                vec![a, b]
            }
            OpKind::QrFactor | OpKind::Gram | OpKind::QApplyLeaf => {
                vec![Tensor::new(vec![1024, 128], rng.f32_vec(1024 * 128))]
            }
            _ => vec![Tensor::new(
                vec![(node.input_bytes / 4) as usize],
                rng.f32_vec((node.input_bytes / 4) as usize),
            )],
        };
        kvs.put(&key, obj_to_bytes(&obj));
        seeded.push((key, obj));
    }
    seeded
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn obj_serde_roundtrip() {
        let obj = vec![
            Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]),
            Tensor::new(vec![3], vec![5., 6., 7.]),
        ];
        let b = obj_to_bytes(&obj);
        assert_eq!(obj_from_bytes(&b).unwrap(), obj);
    }

    #[test]
    fn obj_rejects_truncation() {
        let obj = vec![Tensor::new(vec![4], vec![0.0; 4])];
        let mut b = obj_to_bytes(&obj);
        b.truncate(b.len() - 2);
        assert!(obj_from_bytes(&b).is_err());
    }

    #[test]
    fn gemm_seeding_shares_blocks() {
        use crate::workloads::gemm;
        let dag = gemm::dag(gemm::GemmParams { n: 512, block: 256 });
        let kvs = RealKvs::new(4, 0.0, 0.0);
        let seeded = seed_inputs(&dag, &kvs, 1);
        // mul_0_0_0 and mul_0_1_0 share A[0,0]
        let find = |name: &str| {
            seeded
                .iter()
                .find(|(k, _)| k == &format!("in:{name}"))
                .map(|(_, o)| o)
                .unwrap()
        };
        let a00 = &find("mul_0_0_0")[0];
        let a00_again = &find("mul_0_1_0")[0];
        assert_eq!(a00.data, a00_again.data);
        // but B blocks differ between those tasks
        assert_ne!(find("mul_0_0_0")[1].data, find("mul_0_1_0")[1].data);
    }
}
