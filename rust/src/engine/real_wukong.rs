//! Real-engine Wukong: decentralized executors as thread-pool jobs, real
//! PJRT compute, a real sharded KVS, atomic fan-in counters.
//!
//! This is the serve-path instantiation of §3.3: each executor walks its
//! static schedule locally ("becomes"), spawns pool jobs for fan-out
//! targets ("invokes", with the injected invocation latency), clusters
//! large-output targets locally, and delays I/O by re-checking fan-in
//! counters before storing. The CAS-claim + counter protocol guarantees
//! exactly-once execution under real concurrency (property-tested in
//! `rust/tests/`).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::dag::{Dag, TaskId};
use crate::runtime::SharedRuntime;
use crate::storage::real_kvs::RealKvs;
use crate::util::threadpool::ThreadPool;

use super::compute::{
    input_key, obj_from_bytes, obj_key, obj_to_bytes, Obj, TaskComputer,
};

/// Real-engine knobs (latencies injected; `latency_scale=0` disables).
#[derive(Debug, Clone)]
pub struct RealConfig {
    /// Worker threads = Lambda concurrency.
    pub n_threads: usize,
    /// Injected invocation latency (the paper's ~50 ms), already scaled.
    pub invoke_latency: Duration,
    /// KVS per-op latency (already scaled).
    pub kvs_latency: Duration,
    /// KVS wire bandwidth in bytes/s (0 = unmodeled).
    pub kvs_bw: f64,
    pub kvs_shards: usize,
    /// Inline-argument limit (256 KB on AWS).
    pub inline_max: u64,
    pub clustering_threshold: u64,
    pub use_clustering: bool,
    pub use_delayed_io: bool,
    pub delayed_io_wait: Duration,
    pub delayed_io_retries: u32,
}

impl Default for RealConfig {
    fn default() -> Self {
        RealConfig {
            n_threads: 8,
            invoke_latency: Duration::from_millis(5),
            kvs_latency: Duration::from_micros(100),
            kvs_bw: 0.0,
            kvs_shards: 16,
            inline_max: 256 * 1024,
            clustering_threshold: 1024 * 1024,
            use_clustering: true,
            use_delayed_io: true,
            delayed_io_wait: Duration::from_millis(2),
            delayed_io_retries: 20,
        }
    }
}

/// Outcome of a real run.
#[derive(Debug)]
pub struct RealReport {
    pub makespan: Duration,
    pub tasks_executed: u64,
    pub executors_used: u64,
    pub kvs_bytes_read: u64,
    pub kvs_bytes_written: u64,
    pub kvs_reads: u64,
    pub kvs_writes: u64,
    /// Per-task execution counts (conformance: each must be exactly 1).
    pub per_task_exec: Vec<u32>,
    /// Sink-task outputs by task name (for client-side verification).
    pub outputs: HashMap<String, Obj>,
}

struct Shared {
    dag: Dag,
    cfg: RealConfig,
    kvs: RealKvs,
    computer: TaskComputer,
    counters: Vec<AtomicU32>,
    claimed: Vec<AtomicBool>,
    /// Per-task execution counters (fail-fast on 2; see RunMetrics).
    executed: Vec<AtomicU32>,
    stored: Vec<AtomicBool>,
    executors: AtomicU64,
    tasks_done: AtomicU64,
    outputs: Mutex<HashMap<String, Obj>>,
    errors: Mutex<Vec<String>>,
}

impl Shared {
    fn claim(&self, t: TaskId) -> bool {
        !self.claimed[t as usize].swap(true, Ordering::SeqCst)
    }

    fn store_obj(&self, t: TaskId, obj: &Obj) {
        if !self.stored[t as usize].swap(true, Ordering::SeqCst) {
            self.kvs.put(&obj_key(t), obj_to_bytes(obj));
        }
    }

    fn fetch_obj(&self, t: TaskId) -> Result<Arc<Obj>> {
        let blob = self
            .kvs
            .get_blocking(&obj_key(t), Duration::from_secs(60))
            .ok_or_else(|| anyhow!("timeout waiting for obj:{t}"))?;
        Ok(Arc::new(obj_from_bytes(&blob)?))
    }
}

/// One executor: runs its schedule from `start`, with inline args.
fn executor_body(
    sh: &Arc<Shared>,
    pool: &Arc<ThreadPool>,
    start: TaskId,
    inline: HashMap<TaskId, Arc<Obj>>,
) {
    sh.executors.fetch_add(1, Ordering::Relaxed);
    let mut cache: HashMap<TaskId, Arc<Obj>> = inline;
    let mut queue: VecDeque<TaskId> = VecDeque::from([start]);
    // (finished task, unready fan-in child, retries left)
    let mut watches: Vec<(TaskId, TaskId, u32)> = Vec::new();

    loop {
        let Some(t) = queue.pop_front() else {
            // Delayed-I/O recheck loop once local work drains (§3.3).
            if watches.is_empty() {
                break;
            }
            std::thread::sleep(sh.cfg.delayed_io_wait);
            let mut still = Vec::new();
            for (src, c, retries) in watches.drain(..) {
                if sh.claimed[c as usize].load(Ordering::SeqCst) {
                    continue;
                }
                let indeg = sh.dag.indegree(c) as u32;
                let avail = sh.counters[c as usize].load(Ordering::SeqCst);
                if avail == indeg - 1 && sh.claim(c) {
                    queue.push_back(c); // became the fan-in's executor
                } else if retries > 0 {
                    still.push((src, c, retries - 1));
                } else {
                    // Give up: store our object, count it, maybe claim.
                    let obj = cache.get(&src).expect("holder has object");
                    sh.store_obj(src, obj);
                    let newv =
                        sh.counters[c as usize].fetch_add(1, Ordering::SeqCst) + 1;
                    if newv == indeg && sh.claim(c) {
                        queue.push_back(c);
                    }
                }
            }
            watches = still;
            continue;
        };

        // ---- fetch inputs ----
        let mut parent_objs = Vec::with_capacity(sh.dag.indegree(t));
        let mut failed = false;
        for &p in sh.dag.parents(t) {
            let obj = match cache.get(&p) {
                Some(o) => Arc::clone(o),
                None => match sh.fetch_obj(p) {
                    Ok(o) => {
                        cache.insert(p, Arc::clone(&o));
                        o
                    }
                    Err(e) => {
                        sh.errors
                            .lock()
                            .unwrap()
                            .push(format!("{}: {e}", sh.dag.task_name(t)));
                        failed = true;
                        break;
                    }
                },
            };
            parent_objs.push(obj);
        }
        if failed {
            continue;
        }
        let ext = input_key(&sh.dag, t).and_then(|k| {
            sh.kvs
                .get(&k)
                .and_then(|b| obj_from_bytes(&b).ok().map(Arc::new))
        });

        // ---- compute ----
        let out = match sh.computer.compute(&sh.dag, t, &parent_objs, ext) {
            Ok(o) => Arc::new(o),
            Err(e) => {
                sh.errors
                    .lock()
                    .unwrap()
                    .push(format!("{}: {e}", sh.dag.task_name(t)));
                continue;
            }
        };
        assert!(
            sh.executed[t as usize].fetch_add(1, Ordering::SeqCst) == 0,
            "task {t} executed twice"
        );
        sh.tasks_done.fetch_add(1, Ordering::SeqCst);
        cache.insert(t, Arc::clone(&out));

        // ---- dispatch (§3.3) ----
        if sh.dag.children(t).is_empty() {
            sh.store_obj(t, &out);
            sh.outputs
                .lock()
                .unwrap()
                .insert(sh.dag.task_name(t).to_string(), (*out).clone());
            continue;
        }
        let out_bytes: u64 = out.iter().map(|x| x.bytes()).sum();
        let big = sh.cfg.use_clustering && out_bytes > sh.cfg.clustering_threshold;
        let mut ready = Vec::new();

        if big {
            for &c in sh.dag.children(t) {
                if sh.claimed[c as usize].load(Ordering::SeqCst) {
                    continue;
                }
                let indeg = sh.dag.indegree(c) as u32;
                if indeg <= 1 {
                    if sh.claim(c) {
                        ready.push(c);
                    }
                } else {
                    let avail = sh.counters[c as usize].load(Ordering::SeqCst);
                    if avail == indeg - 1 && sh.claim(c) {
                        ready.push(c);
                    } else if sh.cfg.use_delayed_io
                        && crate::coordinator::policy::should_hold(&sh.dag, t, c)
                    {
                        watches.push((t, c, sh.cfg.delayed_io_retries));
                    } else {
                        sh.store_obj(t, &out);
                        let newv = sh.counters[c as usize]
                            .fetch_add(1, Ordering::SeqCst)
                            + 1;
                        if newv == indeg && sh.claim(c) {
                            ready.push(c);
                        }
                    }
                }
            }
            // Clustering: every ready target runs locally.
            for c in ready {
                queue.push_back(c);
            }
        } else {
            // Small output (§3.3 fan-in Cases 1–2): increment first; claim
            // completed fan-ins (run here, no store); store only when an
            // unready fan-in's eventual executor must read us from the KVS
            // (its blocking read tolerates the store landing after the
            // increment) or invoked executors can't take the object inline.
            let mut any_unready = false;
            for &c in sh.dag.children(t) {
                if sh.claimed[c as usize].load(Ordering::SeqCst) {
                    continue;
                }
                let indeg = sh.dag.indegree(c) as u32;
                if indeg <= 1 {
                    if sh.claim(c) {
                        ready.push(c);
                    }
                } else {
                    let newv =
                        sh.counters[c as usize].fetch_add(1, Ordering::SeqCst) + 1;
                    if newv == indeg && sh.claim(c) {
                        ready.push(c);
                    } else {
                        any_unready = true;
                    }
                }
            }
            let inline_ok = out_bytes <= sh.cfg.inline_max;
            if any_unready || (ready.len() > 1 && !inline_ok) {
                sh.store_obj(t, &out);
            }
            // Becomes the first ready target; invokes the rest.
            if let Some(&becomes) = ready.first() {
                queue.push_front(becomes);
            }
            for &c in ready.iter().skip(1) {
                let inline: HashMap<TaskId, Arc<Obj>> = if inline_ok {
                    HashMap::from([(t, Arc::clone(&out))])
                } else {
                    HashMap::new()
                };
                // Client-side invocation latency (the 50 ms the paper's
                // invoker pool amortizes).
                std::thread::sleep(sh.cfg.invoke_latency);
                let sh2 = Arc::clone(sh);
                let pool2 = Arc::clone(pool);
                pool.spawn(move || executor_body(&sh2, &pool2, c, inline));
            }
        }
    }
}

/// Run a Wukong job for real: seeds must already be in the KVS (see
/// [`super::compute::seed_inputs`]).
pub fn run_real_wukong(
    dag: &Dag,
    rt: Arc<SharedRuntime>,
    kvs: RealKvs,
    cfg: RealConfig,
) -> Result<RealReport> {
    let n = dag.len();
    let sh = Arc::new(Shared {
        dag: dag.clone(),
        kvs,
        computer: TaskComputer { rt },
        counters: (0..n).map(|_| AtomicU32::new(0)).collect(),
        claimed: (0..n).map(|_| AtomicBool::new(false)).collect(),
        executed: (0..n).map(|_| AtomicU32::new(0)).collect(),
        stored: (0..n).map(|_| AtomicBool::new(false)).collect(),
        executors: AtomicU64::new(0),
        tasks_done: AtomicU64::new(0),
        outputs: Mutex::new(HashMap::new()),
        errors: Mutex::new(Vec::new()),
        cfg,
    });
    let pool = Arc::new(ThreadPool::new(sh.cfg.n_threads));
    let start = Instant::now();
    for &leaf in dag.leaves() {
        sh.claimed[leaf as usize].store(true, Ordering::SeqCst);
        let sh2 = Arc::clone(&sh);
        let pool2 = Arc::clone(&pool);
        std::thread::sleep(sh.cfg.invoke_latency); // initial invoker
        pool.spawn(move || executor_body(&sh2, &pool2, leaf, HashMap::new()));
    }
    pool.join();
    let makespan = start.elapsed();

    let errors = sh.errors.lock().unwrap();
    if !errors.is_empty() {
        return Err(anyhow!("run failed: {}", errors.join("; ")));
    }
    let done = sh.tasks_done.load(Ordering::SeqCst);
    if done != n as u64 {
        return Err(anyhow!("only {done}/{n} tasks executed"));
    }
    Ok(RealReport {
        makespan,
        tasks_executed: done,
        executors_used: sh.executors.load(Ordering::Relaxed),
        kvs_bytes_read: sh.kvs.bytes_read.load(Ordering::Relaxed),
        kvs_bytes_written: sh.kvs.bytes_written.load(Ordering::Relaxed),
        kvs_reads: sh.kvs.reads.load(Ordering::Relaxed),
        kvs_writes: sh.kvs.writes.load(Ordering::Relaxed),
        per_task_exec: sh
            .executed
            .iter()
            .map(|e| e.load(Ordering::SeqCst))
            .collect(),
        outputs: {
            let mut guard = sh.outputs.lock().unwrap();
            std::mem::take(&mut *guard)
        },
    })
}
