//! Serverless-platform substrate: the AWS-Lambda invocation model,
//! tenant-side billing, and fault injection.
//!
//! The paper's evaluation ran on AWS; we do not have it, so this module
//! carries the platform behaviours the results depend on: invocation
//! latency (~50 ms warm), memory→CPU bundling, the 5 000-Lambda
//! concurrency limit, the runtime ceiling, per-GB-second billing, and the
//! retry-twice fault tolerance contract (§3.6). See DESIGN.md
//! "Substitutions".

pub mod billing;
pub mod faults;
pub mod lambda;

pub use billing::{Billing, Prices};
pub use lambda::LambdaService;
