//! Fault injection: executor crashes + the AWS retry-twice contract (§3.6).
//!
//! The paper relies on Lambda's automatic retry (up to two) for fault
//! tolerance. Every sim engine consumes a [`FaultPlan`] (from
//! `Config::faults` or an explicit argument): a configurable fraction of
//! execution attempts fail; a failed attempt is retried with the
//! platform's invocation latency up to `max_retries` times, and an
//! exhausted budget *reports* the task (and, by cascade, everything
//! downstream of it) as failed — never silently lost. The `wukong
//! verify --faults` matrix asserts this contract differentially across
//! all engines.
//!
//! Fault draws come from a [`FaultStream`] — a dedicated RNG stream
//! derived from a salted split of the run seed — so toggling `p_fail`
//! can never shift the main simulation RNG (invocation jitter etc.):
//! a `p_fail = 0` run is bit-identical to a run with no fault plan at
//! all, and enabling faults perturbs only the attempts it actually
//! kills.

use crate::dag::{Dag, TaskId};
use crate::metrics::TaskOutcome;
use crate::util::Rng;

/// Salt XORed into the run seed to derive the dedicated fault stream.
/// Any constant works; it only has to be fixed so runs replay, and
/// distinct from the plain run seed so the streams never alias.
const FAULT_STREAM_SALT: u64 = 0xFA17_57E4_A06B_1D2C;

/// Salt for the dedicated *shard-crash* stream ([`CrashStream`]).
/// Distinct from [`FAULT_STREAM_SALT`] so executor-fault draws and
/// KVS-crash draws never alias each other or the main run stream.
const CRASH_STREAM_SALT: u64 = 0xC4A5_4B1D_5EED_90F3;

/// Fault model: each execution attempt fails independently with
/// `p_fail`. `Copy`: two scalars — engines pass it by value instead of
/// cloning per executor start.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPlan {
    pub p_fail: f64,
    pub max_retries: u32,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            p_fail: 0.0,
            max_retries: 2,
        }
    }
}

impl FaultPlan {
    pub fn with_failure_rate(p_fail: f64) -> FaultPlan {
        FaultPlan {
            p_fail,
            max_retries: 2,
        }
    }

    pub fn with_retries(p_fail: f64, max_retries: u32) -> FaultPlan {
        FaultPlan {
            p_fail,
            max_retries,
        }
    }

    /// Whether another retry is allowed after the failed attempt with
    /// index `attempt` (0-based: the first try is attempt 0).
    pub fn can_retry(&self, attempt: u32) -> bool {
        attempt < self.max_retries
    }

    /// Upper bound on attempts per task: the first try + every retry.
    pub fn max_attempts(&self) -> u32 {
        1 + self.max_retries
    }
}

/// The dedicated fault RNG stream for one run: all failure draws come
/// from here and *only* from here, so the main simulation streams
/// (invocation jitter, corpus generation, ...) are identical whether
/// faults are enabled or not.
#[derive(Debug, Clone)]
pub struct FaultStream {
    plan: FaultPlan,
    rng: Rng,
}

impl FaultStream {
    /// Derive the fault stream for a run from its seed (salted split —
    /// independent of `Rng::new(seed)` and every fork engines take
    /// from it).
    pub fn for_run(plan: FaultPlan, seed: u64) -> FaultStream {
        FaultStream {
            plan,
            rng: Rng::new(seed ^ FAULT_STREAM_SALT),
        }
    }

    pub fn plan(&self) -> FaultPlan {
        self.plan
    }

    /// Decide whether the next execution attempt fails. Draws from the
    /// stream only when `p_fail > 0`, so a zero-rate plan consumes
    /// nothing (and `{p_fail: 0, max_retries: r}` is bit-identical for
    /// every `r`).
    pub fn attempt_fails(&mut self) -> bool {
        self.plan.p_fail > 0.0 && self.rng.f64() < self.plan.p_fail
    }
}

/// Crash model for the KVS tier: each storage op independently crashes
/// its shard with `p_crash`, up to `max_crashes` crashes per run. The
/// crashed shard recovers by replaying its snapshot + WAL suffix
/// (see `storage::durability`). `Copy`: two scalars, like [`FaultPlan`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShardCrashPlan {
    pub p_crash: f64,
    pub max_crashes: u32,
}

impl Default for ShardCrashPlan {
    fn default() -> Self {
        ShardCrashPlan {
            p_crash: 0.0,
            max_crashes: 4,
        }
    }
}

impl ShardCrashPlan {
    pub fn with_crash_rate(p_crash: f64) -> ShardCrashPlan {
        ShardCrashPlan {
            p_crash,
            max_crashes: 4,
        }
    }

    pub fn with_crashes(p_crash: f64, max_crashes: u32) -> ShardCrashPlan {
        ShardCrashPlan {
            p_crash,
            max_crashes,
        }
    }
}

/// The dedicated shard-crash RNG stream for one run: crash points are
/// drawn here and *only* here (salted split of the run seed, distinct
/// from [`FaultStream`]'s salt), so enabling shard crashes can never
/// shift executor-fault draws or the main simulation stream — a
/// `p_crash = 0` plan is bit-identical to no plan at all.
#[derive(Debug, Clone)]
pub struct CrashStream {
    plan: ShardCrashPlan,
    rng: Rng,
    fired: u32,
}

impl CrashStream {
    /// Derive the crash stream for a run from its seed (salted split —
    /// independent of `Rng::new(seed)`, the fault stream, and every
    /// fork engines take from either).
    pub fn for_run(plan: ShardCrashPlan, seed: u64) -> CrashStream {
        CrashStream {
            plan,
            rng: Rng::new(seed ^ CRASH_STREAM_SALT),
            fired: 0,
        }
    }

    pub fn plan(&self) -> ShardCrashPlan {
        self.plan
    }

    /// How many crashes this stream has fired so far.
    pub fn fired(&self) -> u32 {
        self.fired
    }

    /// Decide whether the storage op being served crashes its shard.
    /// Draws from the stream only while `p_crash > 0` and the
    /// `max_crashes` budget is unspent, so a zero-rate plan consumes
    /// nothing and an exhausted plan stops perturbing the stream.
    pub fn op_crashes(&mut self) -> bool {
        if self.plan.p_crash <= 0.0 || self.fired >= self.plan.max_crashes {
            return false;
        }
        let crash = self.rng.f64() < self.plan.p_crash;
        if crash {
            self.fired += 1;
        }
        crash
    }
}

/// Cascade a set of directly-failed tasks (retry budget exhausted) over
/// the DAG: every task reachable from a failed task can never become
/// ready (it is missing that ancestor's output), so it resolves to
/// [`TaskOutcome::Failed`] too. Marks `outcome` in place and returns
/// how many tasks *newly* resolved to failed (idempotent: already-
/// failed tasks are skipped, so engines can call this incrementally).
pub fn propagate_failures(
    dag: &Dag,
    direct: &[TaskId],
    outcome: &mut [TaskOutcome],
) -> u64 {
    let mut newly = 0u64;
    let mut stack: Vec<TaskId> = direct.to_vec();
    while let Some(t) = stack.pop() {
        if outcome[t as usize] == TaskOutcome::Failed {
            continue;
        }
        outcome[t as usize] = TaskOutcome::Failed;
        newly += 1;
        for &c in dag.children(t) {
            if outcome[c as usize] != TaskOutcome::Failed {
                stack.push(c);
            }
        }
    }
    newly
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::{DagBuilder, OpKind};

    fn stream(p: f64, seed: u64) -> FaultStream {
        FaultStream::for_run(FaultPlan::with_failure_rate(p), seed)
    }

    #[test]
    fn zero_rate_never_fails() {
        let mut s = stream(0.0, 1);
        assert!((0..1000).all(|_| !s.attempt_fails()));
    }

    #[test]
    fn full_rate_always_fails() {
        let mut s = stream(1.0, 2);
        assert!((0..100).all(|_| s.attempt_fails()));
    }

    #[test]
    fn retry_budget_is_two_by_default() {
        let plan = FaultPlan::default();
        assert!(plan.can_retry(0));
        assert!(plan.can_retry(1));
        assert!(!plan.can_retry(2));
        assert_eq!(plan.max_attempts(), 3);
        assert_eq!(FaultPlan::with_retries(0.5, 0).max_attempts(), 1);
    }

    #[test]
    fn rate_is_roughly_respected() {
        let mut s = stream(0.3, 3);
        let fails = (0..10_000).filter(|_| s.attempt_fails()).count();
        assert!((2_700..3_300).contains(&fails), "fails={fails}");
    }

    #[test]
    fn stream_is_deterministic_per_seed() {
        let mut a = stream(0.5, 7);
        let mut b = stream(0.5, 7);
        let xs: Vec<bool> = (0..100).map(|_| a.attempt_fails()).collect();
        let ys: Vec<bool> = (0..100).map(|_| b.attempt_fails()).collect();
        assert_eq!(xs, ys);
    }

    #[test]
    fn stream_differs_from_the_main_seed_stream() {
        // The salted derivation must not alias the plain run stream:
        // drawing failures must not replay the jitter stream.
        let mut main = Rng::new(7);
        let mut fault = FaultStream::for_run(FaultPlan::with_failure_rate(0.5), 7);
        let main_draws: Vec<u64> = (0..8).map(|_| main.next_u64()).collect();
        let fault_draws: Vec<u64> = (0..8).map(|_| fault.rng.next_u64()).collect();
        assert_ne!(main_draws, fault_draws);
    }

    fn diamond() -> crate::dag::Dag {
        let mut b = DagBuilder::new("diamond");
        let a = b.task("a", OpKind::Generic, 1.0, 8);
        let x = b.task("x", OpKind::Generic, 1.0, 8);
        let y = b.task("y", OpKind::Generic, 1.0, 8);
        let d = b.task("d", OpKind::Generic, 1.0, 8);
        b.edge(a, x).edge(a, y).edge(x, d).edge(y, d);
        b.build().unwrap()
    }

    #[test]
    fn propagation_covers_the_reachable_set() {
        let dag = diamond();
        let mut outcome = vec![TaskOutcome::Completed; 4];
        let newly = propagate_failures(&dag, &[0], &mut outcome);
        assert_eq!(newly, 4);
        assert!(outcome.iter().all(|&o| o == TaskOutcome::Failed));
    }

    #[test]
    fn propagation_is_partial_and_idempotent() {
        let dag = diamond();
        let mut outcome = vec![TaskOutcome::Completed; 4];
        // x failed: only x and the join d are lost; a and y are fine.
        let newly = propagate_failures(&dag, &[1], &mut outcome);
        assert_eq!(newly, 2);
        assert_eq!(outcome[0], TaskOutcome::Completed);
        assert_eq!(outcome[1], TaskOutcome::Failed);
        assert_eq!(outcome[2], TaskOutcome::Completed);
        assert_eq!(outcome[3], TaskOutcome::Failed);
        // Re-propagating the overlapping set marks only what is new.
        assert_eq!(propagate_failures(&dag, &[1, 2], &mut outcome), 1);
        assert_eq!(outcome[2], TaskOutcome::Failed);
    }

    #[test]
    fn zero_rate_crash_plan_never_draws() {
        let mut s = CrashStream::for_run(ShardCrashPlan::with_crash_rate(0.0), 1);
        assert!((0..1000).all(|_| !s.op_crashes()));
        assert_eq!(s.fired(), 0);
        // The stream was never consumed: it still equals a fresh one.
        let mut fresh = CrashStream::for_run(ShardCrashPlan::with_crash_rate(0.0), 1);
        assert_eq!(s.rng.next_u64(), fresh.rng.next_u64());
    }

    #[test]
    fn crash_budget_caps_fired_crashes() {
        let mut s = CrashStream::for_run(ShardCrashPlan::with_crashes(1.0, 3), 2);
        let crashes = (0..100).filter(|_| s.op_crashes()).count();
        assert_eq!(crashes, 3);
        assert_eq!(s.fired(), 3);
        // Exhausted budget: no further draws perturb the stream.
        let snapshot = s.rng.clone().next_u64();
        assert!(!s.op_crashes());
        assert_eq!(s.rng.next_u64(), snapshot);
    }

    #[test]
    fn crash_stream_is_deterministic_and_distinct_from_faults() {
        let plan = ShardCrashPlan::with_crashes(0.5, u32::MAX);
        let mut a = CrashStream::for_run(plan, 7);
        let mut b = CrashStream::for_run(plan, 7);
        let xs: Vec<bool> = (0..100).map(|_| a.op_crashes()).collect();
        let ys: Vec<bool> = (0..100).map(|_| b.op_crashes()).collect();
        assert_eq!(xs, ys);
        // Distinct salt: crash draws never alias fault draws for the
        // same run seed.
        let mut crash = CrashStream::for_run(plan, 7);
        let mut fault = FaultStream::for_run(FaultPlan::with_failure_rate(0.5), 7);
        let cs: Vec<u64> = (0..8).map(|_| crash.rng.next_u64()).collect();
        let fs: Vec<u64> = (0..8).map(|_| fault.rng.next_u64()).collect();
        assert_ne!(cs, fs);
    }

    #[test]
    fn crash_rate_is_roughly_respected() {
        let mut s = CrashStream::for_run(ShardCrashPlan::with_crashes(0.3, u32::MAX), 3);
        let crashes = (0..10_000).filter(|_| s.op_crashes()).count();
        assert!((2_700..3_300).contains(&crashes), "crashes={crashes}");
    }
}
