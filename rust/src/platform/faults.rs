//! Fault injection: executor crashes + the AWS retry-twice contract (§3.6).
//!
//! The paper relies on Lambda's automatic retry (up to two) for fault
//! tolerance. Every sim engine consumes a [`FaultPlan`] (from
//! `Config::faults` or an explicit argument): a configurable fraction of
//! execution attempts fail; a failed attempt is retried with the
//! platform's invocation latency up to `max_retries` times, and an
//! exhausted budget *reports* the task (and, by cascade, everything
//! downstream of it) as failed — never silently lost. The `wukong
//! verify --faults` matrix asserts this contract differentially across
//! all engines.
//!
//! Fault draws come from a [`FaultStream`] — a dedicated RNG stream
//! derived from a salted split of the run seed — so toggling `p_fail`
//! can never shift the main simulation RNG (invocation jitter etc.):
//! a `p_fail = 0` run is bit-identical to a run with no fault plan at
//! all, and enabling faults perturbs only the attempts it actually
//! kills.

use crate::dag::{Dag, TaskId};
use crate::metrics::TaskOutcome;
use crate::util::Rng;

/// Salt XORed into the run seed to derive the dedicated fault stream.
/// Any constant works; it only has to be fixed so runs replay, and
/// distinct from the plain run seed so the streams never alias.
const FAULT_STREAM_SALT: u64 = 0xFA17_57E4_A06B_1D2C;

/// Fault model: each execution attempt fails independently with
/// `p_fail`. `Copy`: two scalars — engines pass it by value instead of
/// cloning per executor start.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPlan {
    pub p_fail: f64,
    pub max_retries: u32,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            p_fail: 0.0,
            max_retries: 2,
        }
    }
}

impl FaultPlan {
    pub fn with_failure_rate(p_fail: f64) -> FaultPlan {
        FaultPlan {
            p_fail,
            max_retries: 2,
        }
    }

    pub fn with_retries(p_fail: f64, max_retries: u32) -> FaultPlan {
        FaultPlan {
            p_fail,
            max_retries,
        }
    }

    /// Whether another retry is allowed after the failed attempt with
    /// index `attempt` (0-based: the first try is attempt 0).
    pub fn can_retry(&self, attempt: u32) -> bool {
        attempt < self.max_retries
    }

    /// Upper bound on attempts per task: the first try + every retry.
    pub fn max_attempts(&self) -> u32 {
        1 + self.max_retries
    }
}

/// The dedicated fault RNG stream for one run: all failure draws come
/// from here and *only* from here, so the main simulation streams
/// (invocation jitter, corpus generation, ...) are identical whether
/// faults are enabled or not.
#[derive(Debug, Clone)]
pub struct FaultStream {
    plan: FaultPlan,
    rng: Rng,
}

impl FaultStream {
    /// Derive the fault stream for a run from its seed (salted split —
    /// independent of `Rng::new(seed)` and every fork engines take
    /// from it).
    pub fn for_run(plan: FaultPlan, seed: u64) -> FaultStream {
        FaultStream {
            plan,
            rng: Rng::new(seed ^ FAULT_STREAM_SALT),
        }
    }

    pub fn plan(&self) -> FaultPlan {
        self.plan
    }

    /// Decide whether the next execution attempt fails. Draws from the
    /// stream only when `p_fail > 0`, so a zero-rate plan consumes
    /// nothing (and `{p_fail: 0, max_retries: r}` is bit-identical for
    /// every `r`).
    pub fn attempt_fails(&mut self) -> bool {
        self.plan.p_fail > 0.0 && self.rng.f64() < self.plan.p_fail
    }
}

/// Cascade a set of directly-failed tasks (retry budget exhausted) over
/// the DAG: every task reachable from a failed task can never become
/// ready (it is missing that ancestor's output), so it resolves to
/// [`TaskOutcome::Failed`] too. Marks `outcome` in place and returns
/// how many tasks *newly* resolved to failed (idempotent: already-
/// failed tasks are skipped, so engines can call this incrementally).
pub fn propagate_failures(
    dag: &Dag,
    direct: &[TaskId],
    outcome: &mut [TaskOutcome],
) -> u64 {
    let mut newly = 0u64;
    let mut stack: Vec<TaskId> = direct.to_vec();
    while let Some(t) = stack.pop() {
        if outcome[t as usize] == TaskOutcome::Failed {
            continue;
        }
        outcome[t as usize] = TaskOutcome::Failed;
        newly += 1;
        for &c in dag.children(t) {
            if outcome[c as usize] != TaskOutcome::Failed {
                stack.push(c);
            }
        }
    }
    newly
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::{DagBuilder, OpKind};

    fn stream(p: f64, seed: u64) -> FaultStream {
        FaultStream::for_run(FaultPlan::with_failure_rate(p), seed)
    }

    #[test]
    fn zero_rate_never_fails() {
        let mut s = stream(0.0, 1);
        assert!((0..1000).all(|_| !s.attempt_fails()));
    }

    #[test]
    fn full_rate_always_fails() {
        let mut s = stream(1.0, 2);
        assert!((0..100).all(|_| s.attempt_fails()));
    }

    #[test]
    fn retry_budget_is_two_by_default() {
        let plan = FaultPlan::default();
        assert!(plan.can_retry(0));
        assert!(plan.can_retry(1));
        assert!(!plan.can_retry(2));
        assert_eq!(plan.max_attempts(), 3);
        assert_eq!(FaultPlan::with_retries(0.5, 0).max_attempts(), 1);
    }

    #[test]
    fn rate_is_roughly_respected() {
        let mut s = stream(0.3, 3);
        let fails = (0..10_000).filter(|_| s.attempt_fails()).count();
        assert!((2_700..3_300).contains(&fails), "fails={fails}");
    }

    #[test]
    fn stream_is_deterministic_per_seed() {
        let mut a = stream(0.5, 7);
        let mut b = stream(0.5, 7);
        let xs: Vec<bool> = (0..100).map(|_| a.attempt_fails()).collect();
        let ys: Vec<bool> = (0..100).map(|_| b.attempt_fails()).collect();
        assert_eq!(xs, ys);
    }

    #[test]
    fn stream_differs_from_the_main_seed_stream() {
        // The salted derivation must not alias the plain run stream:
        // drawing failures must not replay the jitter stream.
        let mut main = Rng::new(7);
        let mut fault = FaultStream::for_run(FaultPlan::with_failure_rate(0.5), 7);
        let main_draws: Vec<u64> = (0..8).map(|_| main.next_u64()).collect();
        let fault_draws: Vec<u64> = (0..8).map(|_| fault.rng.next_u64()).collect();
        assert_ne!(main_draws, fault_draws);
    }

    fn diamond() -> crate::dag::Dag {
        let mut b = DagBuilder::new("diamond");
        let a = b.task("a", OpKind::Generic, 1.0, 8);
        let x = b.task("x", OpKind::Generic, 1.0, 8);
        let y = b.task("y", OpKind::Generic, 1.0, 8);
        let d = b.task("d", OpKind::Generic, 1.0, 8);
        b.edge(a, x).edge(a, y).edge(x, d).edge(y, d);
        b.build().unwrap()
    }

    #[test]
    fn propagation_covers_the_reachable_set() {
        let dag = diamond();
        let mut outcome = vec![TaskOutcome::Completed; 4];
        let newly = propagate_failures(&dag, &[0], &mut outcome);
        assert_eq!(newly, 4);
        assert!(outcome.iter().all(|&o| o == TaskOutcome::Failed));
    }

    #[test]
    fn propagation_is_partial_and_idempotent() {
        let dag = diamond();
        let mut outcome = vec![TaskOutcome::Completed; 4];
        // x failed: only x and the join d are lost; a and y are fine.
        let newly = propagate_failures(&dag, &[1], &mut outcome);
        assert_eq!(newly, 2);
        assert_eq!(outcome[0], TaskOutcome::Completed);
        assert_eq!(outcome[1], TaskOutcome::Failed);
        assert_eq!(outcome[2], TaskOutcome::Completed);
        assert_eq!(outcome[3], TaskOutcome::Failed);
        // Re-propagating the overlapping set marks only what is new.
        assert_eq!(propagate_failures(&dag, &[1, 2], &mut outcome), 1);
        assert_eq!(outcome[2], TaskOutcome::Failed);
    }
}
