//! Fault injection: executor crashes + the AWS retry-twice contract (§3.6).
//!
//! The paper relies on Lambda's automatic retry (up to two) for fault
//! tolerance. The simulator can kill a configurable fraction of executor
//! runs; a killed run is retried from its static-schedule start with the
//! platform's invocation latency, up to `retries` times. Tests assert the
//! job still completes and every task still executes effectively-once
//! (results are idempotent because task outputs are keyed).

use crate::util::Rng;

/// Fault model: each executor run fails independently with `p_fail`.
/// `Copy`: two scalars — engines pass it by value instead of cloning per
/// executor start.
#[derive(Debug, Clone, Copy)]
pub struct FaultPlan {
    pub p_fail: f64,
    pub max_retries: u32,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            p_fail: 0.0,
            max_retries: 2,
        }
    }
}

impl FaultPlan {
    pub fn with_failure_rate(p_fail: f64) -> FaultPlan {
        FaultPlan {
            p_fail,
            max_retries: 2,
        }
    }

    /// Decide whether a given attempt fails.
    pub fn attempt_fails(&self, rng: &mut Rng) -> bool {
        self.p_fail > 0.0 && rng.f64() < self.p_fail
    }

    /// Whether another retry is allowed after `attempt` failures.
    pub fn can_retry(&self, attempt: u32) -> bool {
        attempt < self.max_retries
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_rate_never_fails() {
        let plan = FaultPlan::default();
        let mut rng = Rng::new(1);
        assert!((0..1000).all(|_| !plan.attempt_fails(&mut rng)));
    }

    #[test]
    fn full_rate_always_fails() {
        let plan = FaultPlan::with_failure_rate(1.0);
        let mut rng = Rng::new(2);
        assert!((0..100).all(|_| plan.attempt_fails(&mut rng)));
    }

    #[test]
    fn retry_budget_is_two() {
        let plan = FaultPlan::default();
        assert!(plan.can_retry(0));
        assert!(plan.can_retry(1));
        assert!(!plan.can_retry(2));
    }

    #[test]
    fn rate_is_roughly_respected() {
        let plan = FaultPlan::with_failure_rate(0.3);
        let mut rng = Rng::new(3);
        let fails = (0..10_000).filter(|_| plan.attempt_fails(&mut rng)).count();
        assert!((2_700..3_300).contains(&fails), "fails={fails}");
    }
}
