//! AWS-Lambda invocation/admission model.
//!
//! Captures the three platform effects the paper's figures hinge on:
//!
//! 1. **Invocation latency** — ~50 ms warm (lognormal-jittered), plus a
//!    cold-start penalty for a configurable cold fraction (the evaluation
//!    pre-warms, so the default cold fraction is 0).
//! 2. **Concurrency limit** — at most N executors run at once (paper: the
//!    account cap was 5 000); excess invocations queue for admission.
//! 3. **Runtime ceiling** — executors are forcibly stopped at
//!    `max_runtime_s` (420 s in the evaluation); the fault model retries.

use crate::config::LambdaConfig;
use crate::sim::{secs, Time};
use crate::util::Rng;

/// Admission + latency bookkeeping for a Lambda fleet.
#[derive(Debug)]
pub struct LambdaService {
    cfg: LambdaConfig,
    rng: Rng,
    active: usize,
    peak_active: usize,
    queued: Vec<Time>, // admission FIFO: requested-at times (metrics only)
    total_invocations: u64,
    throttled: u64,
    // Warm-pool accounting for the serving layer (cold-start
    // amortization across jobs). Single-DAG engine runs never touch
    // these paths, so their event streams are unchanged.
    warm_pool: usize,
    warm_hits: u64,
    cold_starts: u64,
}

/// Outcome of an invocation request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Invocation {
    /// When the executor actually starts running.
    pub start_at: Time,
    /// Whether the invocation hit a cold start.
    pub cold: bool,
}

impl LambdaService {
    pub fn new(cfg: LambdaConfig, rng: Rng) -> LambdaService {
        LambdaService {
            cfg,
            rng,
            active: 0,
            peak_active: 0,
            queued: Vec::new(),
            total_invocations: 0,
            throttled: 0,
            warm_pool: 0,
            warm_hits: 0,
            cold_starts: 0,
        }
    }

    /// Sampled invocation latency for a single request.
    pub fn sample_invoke_latency(&mut self) -> Time {
        let cold = self.rng.f64() < self.cfg.cold_fraction;
        let mut lat = if self.cfg.invoke_jitter_sigma > 0.0 {
            self.rng
                .lognormal(self.cfg.invoke_latency_s, self.cfg.invoke_jitter_sigma)
        } else {
            self.cfg.invoke_latency_s
        };
        if cold {
            lat += self.cfg.cold_start_s;
        }
        secs(lat)
    }

    /// Request an executor slot at time `now`, with the invocation call
    /// issued now (latency sampled). Returns when the executor will begin.
    ///
    /// If the fleet is at the concurrency limit the request is *throttled*:
    /// the caller must retry via [`LambdaService::release`]-driven wakeups;
    /// for simplicity we model throttling as an extra queued delay equal to
    /// the invocation latency (AWS surfaces it as a retryable error).
    pub fn invoke(&mut self, now: Time) -> Invocation {
        let lat = self.sample_invoke_latency();
        self.admit(now + lat)
    }

    /// Admission only: the invocation API latency has already been paid by
    /// the caller (invoker-pool service time / client-side blocking call);
    /// this accounts for the concurrency limit and slot bookkeeping.
    pub fn admit(&mut self, at: Time) -> Invocation {
        self.total_invocations += 1;
        let mut start_at = at;
        if self.active >= self.cfg.concurrency_limit {
            // Throttled: backoff-and-retry delay.
            self.throttled += 1;
            self.queued.push(at);
            start_at += secs(self.cfg.invoke_latency_s * 2.0);
        }
        self.active += 1;
        self.peak_active = self.peak_active.max(self.active);
        Invocation {
            start_at,
            cold: false,
        }
    }

    /// An executor finished and its slot is free again.
    pub fn release(&mut self) {
        debug_assert!(self.active > 0);
        self.active -= 1;
    }

    /// Serving-layer admission with warm-executor reuse: take a parked
    /// warm executor if one is available (a warm hit — no cold-start
    /// penalty), otherwise account a cold start and report `cold` so
    /// the caller can charge `cold_start_s`. Slot bookkeeping is the
    /// same as [`LambdaService::admit`].
    pub fn reuse(&mut self, at: Time) -> Invocation {
        if self.warm_pool > 0 {
            self.warm_pool -= 1;
            self.warm_hits += 1;
            self.admit(at)
        } else {
            self.cold_starts += 1;
            Invocation {
                cold: true,
                ..self.admit(at)
            }
        }
    }

    /// Park `n` finishing executors in the warm pool (their slots must
    /// be released separately via [`LambdaService::release`]); the next
    /// [`LambdaService::reuse`] calls take them without a cold start.
    pub fn park_warm(&mut self, n: usize) {
        self.warm_pool += n;
    }

    pub fn warm_pool(&self) -> usize {
        self.warm_pool
    }

    pub fn warm_hits(&self) -> u64 {
        self.warm_hits
    }

    pub fn cold_starts(&self) -> u64 {
        self.cold_starts
    }

    /// Runtime ceiling in virtual time.
    pub fn max_runtime(&self) -> Time {
        secs(self.cfg.max_runtime_s)
    }

    pub fn active(&self) -> usize {
        self.active
    }

    pub fn peak_active(&self) -> usize {
        self.peak_active
    }

    pub fn total_invocations(&self) -> u64 {
        self.total_invocations
    }

    pub fn throttled(&self) -> u64 {
        self.throttled
    }

    /// vCPUs allocated per function: AWS scales CPU with memory; 1 792 MB
    /// ≈ 1 vCPU, so a 3 GB function gets ~1.67 vCPUs (we round to 2 like
    /// the paper's vCPU plots).
    pub fn vcpus_per_fn(&self) -> f64 {
        (self.cfg.memory_gb * 1024.0 / 1792.0).ceil()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn svc(limit: usize) -> LambdaService {
        let cfg = LambdaConfig {
            concurrency_limit: limit,
            invoke_jitter_sigma: 0.0,
            ..LambdaConfig::default()
        };
        LambdaService::new(cfg, Rng::new(1))
    }

    #[test]
    fn warm_invoke_is_50ms() {
        let mut s = svc(10);
        let inv = s.invoke(0);
        assert_eq!(inv.start_at, secs(0.050));
        assert!(!inv.cold);
    }

    #[test]
    fn concurrency_limit_throttles() {
        let mut s = svc(2);
        s.invoke(0);
        s.invoke(0);
        let third = s.invoke(0);
        assert!(third.start_at > secs(0.050));
        assert_eq!(s.throttled(), 1);
    }

    #[test]
    fn release_frees_slots() {
        let mut s = svc(1);
        s.invoke(0);
        s.release();
        let inv = s.invoke(secs(1.0));
        assert_eq!(inv.start_at, secs(1.050));
        assert_eq!(s.throttled(), 0);
    }

    #[test]
    fn peak_active_tracks_high_water() {
        let mut s = svc(100);
        for _ in 0..7 {
            s.invoke(0);
        }
        for _ in 0..3 {
            s.release();
        }
        assert_eq!(s.active(), 4);
        assert_eq!(s.peak_active(), 7);
    }

    #[test]
    fn cold_start_adds_penalty() {
        let cfg = LambdaConfig {
            cold_fraction: 1.0,
            invoke_jitter_sigma: 0.0,
            ..LambdaConfig::default()
        };
        let mut s = LambdaService::new(cfg, Rng::new(2));
        let inv = s.invoke(0);
        assert!(inv.start_at >= secs(0.55));
    }

    #[test]
    fn vcpus_for_3gb_is_2() {
        let s = svc(1);
        assert_eq!(s.vcpus_per_fn(), 2.0);
    }

    #[test]
    fn scripted_reuse_sequence_pins_warm_and_cold_counters() {
        // admit 2 cold → park both → reuse 3: 2 warm hits + 1 cold.
        let mut s = svc(10);
        assert!(s.reuse(0).cold);
        assert!(s.reuse(0).cold);
        assert_eq!((s.warm_hits(), s.cold_starts()), (0, 2));
        assert_eq!(s.active(), 2);
        s.release();
        s.release();
        s.park_warm(2);
        assert_eq!(s.warm_pool(), 2);
        assert!(!s.reuse(0).cold);
        assert!(!s.reuse(0).cold);
        assert!(s.reuse(0).cold, "warm pool exhausted after two hits");
        assert_eq!((s.warm_hits(), s.cold_starts()), (2, 3));
        assert_eq!(s.warm_pool(), 0);
        assert_eq!(s.active(), 3);
        assert_eq!(s.total_invocations(), 5);
    }

    #[test]
    fn reuse_counts_slots_like_admit() {
        // Warm vs cold changes only the counters and the `cold` flag —
        // the slot/throttle bookkeeping stays identical to admit().
        let mut s = svc(2);
        s.park_warm(5);
        let a = s.reuse(0);
        let b = s.reuse(0);
        assert!(!a.cold && !b.cold);
        assert_eq!(a.start_at, 0);
        let third = s.reuse(0);
        assert!(third.start_at > 0, "third slot throttles past the limit");
        assert_eq!(s.throttled(), 1);
        assert_eq!(s.peak_active(), 3);
    }

    #[test]
    fn plain_admit_and_invoke_never_touch_warm_accounting() {
        // Single-DAG engines only ever call invoke/admit/release; the
        // warm meters must stay at zero so their runs are bit-identical
        // to the pre-warm-pool model.
        let mut s = svc(10);
        for _ in 0..5 {
            s.invoke(0);
        }
        s.admit(0);
        s.release();
        assert_eq!(s.warm_pool(), 0);
        assert_eq!(s.warm_hits(), 0);
        assert_eq!(s.cold_starts(), 0);
    }
}
