//! Tenant-side billing model (Fig. 18–20: cost comparisons).
//!
//! Prices are AWS us-east-1 as of the paper's timeframe (2020):
//! Lambda $0.0000166667/GB-s + $0.20/1M requests; c5.4xlarge $0.68/h;
//! r5n.16xlarge $4.768/h; Fargate $0.04048/vCPU-h + $0.004445/GB-h;
//! ElastiCache cache.r5.large $0.216/h.

/// Price book (override for sensitivity studies).
#[derive(Debug, Clone)]
pub struct Prices {
    pub lambda_gb_s: f64,
    pub lambda_per_invoke: f64,
    pub c5_4xlarge_h: f64,
    pub r5n_16xlarge_h: f64,
    pub fargate_vcpu_h: f64,
    pub fargate_gb_h: f64,
    pub elasticache_node_h: f64,
}

impl Default for Prices {
    fn default() -> Self {
        Prices {
            lambda_gb_s: 0.000_016_666_7,
            lambda_per_invoke: 0.20 / 1e6,
            c5_4xlarge_h: 0.68,
            r5n_16xlarge_h: 4.768,
            fargate_vcpu_h: 0.040_48,
            fargate_gb_h: 0.004_445,
            elasticache_node_h: 0.216,
        }
    }
}

/// Accumulating tenant-side cost meter.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Billing {
    /// Total Lambda GB-seconds consumed.
    pub lambda_gb_s: f64,
    /// Number of Lambda invocations.
    pub invocations: u64,
    /// Fargate (vCPU-hours, GB-hours) for the storage cluster.
    pub fargate_vcpu_h: f64,
    pub fargate_gb_h: f64,
    /// Scheduler VM hours (r5n.16xlarge).
    pub scheduler_vm_h: f64,
    /// Dask/EC2 cluster dollars (precomputed: $/h × h).
    pub ec2_dollars: f64,
    /// ElastiCache node hours.
    pub elasticache_node_h: f64,
}

impl Billing {
    /// Charge one executor's lifetime.
    pub fn charge_lambda(&mut self, memory_gb: f64, runtime_s: f64) {
        // AWS bills in 1 ms increments (100 ms pre-2020; we use 1 ms).
        let billed = (runtime_s * 1000.0).ceil() / 1000.0;
        self.lambda_gb_s += memory_gb * billed;
        self.invocations += 1;
    }

    /// Charge the Fargate storage cluster for the job's duration.
    pub fn charge_fargate(&mut self, nodes: usize, vcpus: f64, gb: f64, hours: f64) {
        self.fargate_vcpu_h += nodes as f64 * vcpus * hours;
        self.fargate_gb_h += nodes as f64 * gb * hours;
    }

    pub fn charge_scheduler_vm(&mut self, hours: f64) {
        self.scheduler_vm_h += hours;
    }

    pub fn charge_ec2(&mut self, dollars_per_hour: f64, hours: f64) {
        self.ec2_dollars += dollars_per_hour * hours;
    }

    pub fn charge_elasticache(&mut self, nodes: usize, hours: f64) {
        self.elasticache_node_h += nodes as f64 * hours;
    }

    /// Merge another meter into this one (serving-layer rollups: a
    /// tenant's bill is the absorbed sum of its jobs' meters).
    pub fn absorb(&mut self, other: &Billing) {
        self.lambda_gb_s += other.lambda_gb_s;
        self.invocations += other.invocations;
        self.fargate_vcpu_h += other.fargate_vcpu_h;
        self.fargate_gb_h += other.fargate_gb_h;
        self.scheduler_vm_h += other.scheduler_vm_h;
        self.ec2_dollars += other.ec2_dollars;
        self.elasticache_node_h += other.elasticache_node_h;
    }

    /// Total dollars under a price book.
    pub fn total(&self, p: &Prices) -> f64 {
        self.lambda_gb_s * p.lambda_gb_s
            + self.invocations as f64 * p.lambda_per_invoke
            + self.fargate_vcpu_h * p.fargate_vcpu_h
            + self.fargate_gb_h * p.fargate_gb_h
            + self.scheduler_vm_h * p.r5n_16xlarge_h
            + self.ec2_dollars
            + self.elasticache_node_h * p.elasticache_node_h
    }

    /// Lambda-only dollars (per-workload marginal cost).
    pub fn lambda_total(&self, p: &Prices) -> f64 {
        self.lambda_gb_s * p.lambda_gb_s
            + self.invocations as f64 * p.lambda_per_invoke
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lambda_billing_rounds_to_ms() {
        let mut b = Billing::default();
        b.charge_lambda(3.0, 0.0004);
        assert!((b.lambda_gb_s - 3.0 * 0.001).abs() < 1e-12);
        assert_eq!(b.invocations, 1);
    }

    #[test]
    fn totals_combine_all_sources() {
        let p = Prices::default();
        let mut b = Billing::default();
        b.charge_lambda(3.0, 10.0);
        b.charge_fargate(75, 4.0, 30.0, 0.5);
        b.charge_scheduler_vm(0.5);
        let t = b.total(&p);
        assert!(t > 0.0);
        assert!(b.lambda_total(&p) < t);
    }

    #[test]
    fn cost_monotone_in_usage() {
        let p = Prices::default();
        let mut a = Billing::default();
        let mut b = Billing::default();
        a.charge_lambda(3.0, 5.0);
        b.charge_lambda(3.0, 10.0);
        assert!(a.total(&p) < b.total(&p));
    }

    #[test]
    fn absorb_sums_every_meter() {
        let p = Prices::default();
        let mut a = Billing::default();
        a.charge_lambda(3.0, 5.0);
        a.charge_fargate(75, 4.0, 30.0, 0.25);
        a.charge_scheduler_vm(0.25);
        a.charge_ec2(85.0, 0.1);
        a.charge_elasticache(5, 0.1);
        let mut b = Billing::default();
        b.charge_lambda(3.0, 2.0);
        b.charge_fargate(75, 4.0, 30.0, 0.5);
        let mut rolled = Billing::default();
        rolled.absorb(&a);
        rolled.absorb(&b);
        assert_eq!(rolled.invocations, 2);
        assert!((rolled.total(&p) - (a.total(&p) + b.total(&p))).abs() < 1e-9);
        // Absorbing an empty meter is the identity.
        let before = rolled.clone();
        rolled.absorb(&Billing::default());
        assert_eq!(rolled, before);
    }

    #[test]
    fn ten_thousand_short_lambdas_cost_dollars_not_cents() {
        // sanity vs the paper's scale: 10k × 3 GB × 1 s ≈ $0.50 + $0.002
        let p = Prices::default();
        let mut b = Billing::default();
        for _ in 0..10_000 {
            b.charge_lambda(3.0, 1.0);
        }
        let t = b.total(&p);
        assert!(t > 0.4 && t < 0.7, "got {t}");
    }
}
