//! Configuration system: defaults matching the paper's §4.1 testbed, an
//! INI-style config-file loader, and `key.path=value` CLI overrides.
//!
//! All latency/bandwidth constants are the *inputs* to the simulator; the
//! defaults encode the paper's own numbers (50 ms Lambda invoke, 3 GB
//! functions, 75 Fargate shards, 64 invoker processes, 256 KB inline-arg
//! limit, 200 MB clustering threshold, 5 000-Lambda concurrency).

use std::collections::BTreeMap;
use std::path::Path;

use crate::dag::SpawnPlan;
use crate::platform::faults::{FaultPlan, ShardCrashPlan};
use crate::serving::{ArrivalMode, ArrivalPlan, FairnessPolicy, TenantPlan};
use crate::sim::{secs, CalendarKind, Sim, Time};

/// AWS-Lambda-like platform model parameters.
#[derive(Debug, Clone, Copy)]
pub struct LambdaConfig {
    /// Function memory (GB); AWS scales CPU linearly with memory.
    pub memory_gb: f64,
    /// Warm invocation latency (s) — the paper's ~50 ms Boto3 number.
    pub invoke_latency_s: f64,
    /// Cold-start penalty (s); evaluation warms the pool so default 0 use.
    pub cold_start_s: f64,
    /// Fraction of invocations that are cold (0 after warmup).
    pub cold_fraction: f64,
    /// Lognormal jitter sigma on invocation latency.
    pub invoke_jitter_sigma: f64,
    /// Max concurrent executors (paper's account limit: 5 000).
    pub concurrency_limit: usize,
    /// Max function runtime (s) — 420 s (7 min) in the evaluation.
    pub max_runtime_s: f64,
    /// Effective per-executor compute rate (GFLOP/s) for flops-modeled
    /// tasks. Calibrated against real PJRT runs (see EXPERIMENTS.md §Perf).
    pub gflops: f64,
    /// Per-executor network bandwidth (bytes/s) — Lambda ~600 Mbps.
    pub net_bw: f64,
    /// Automatic retries of failed executions (AWS allows 2).
    pub retries: u32,
}

impl Default for LambdaConfig {
    fn default() -> Self {
        LambdaConfig {
            memory_gb: 3.0,
            invoke_latency_s: 0.050,
            cold_start_s: 0.5,
            cold_fraction: 0.0,
            invoke_jitter_sigma: 0.15,
            concurrency_limit: 5_000,
            max_runtime_s: 420.0,
            gflops: 20.0,
            net_bw: 75e6,
            retries: 2,
        }
    }
}

/// Intermediate-storage backend flavor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvsMode {
    /// Fargate-hosted Redis shards: low latency, per-shard bandwidth.
    Redis,
    /// S3: higher latency, throttled IOPS, high aggregate bandwidth.
    S3,
    /// ElastiCache: Redis-like latency, fewer shards (cost-prohibitive to
    /// scale out — paper Fig. 23 baseline).
    ElastiCache,
}

/// Storage-cluster model parameters (KVS + MDS + proxy).
#[derive(Debug, Clone, Copy)]
pub struct StorageConfig {
    pub mode: KvsMode,
    /// Number of KVS shards (Fargate tasks). Paper uses 75.
    pub n_shards: usize,
    /// Per-shard sustained bandwidth (bytes/s). Fargate task ≈ 2.4 Gbps.
    pub shard_bw: f64,
    /// Per-op base latency (s): Redis ~1 ms, S3 ~15 ms.
    pub op_latency_s: f64,
    /// Per-shard IOPS cap (S3 throttling); 0 = uncapped.
    pub iops_limit: f64,
    /// MDS (dependency counters / schedules) op latency (s).
    pub mds_latency_s: f64,
    /// MDS throughput (ops/s) — a Redis instance on the scheduler VM.
    pub mds_ops_per_sec: f64,
    /// Max inline-argument payload on an invocation (bytes) — 256 KB.
    pub arg_inline_max: u64,
    /// Simulated WAL fsync time (s) added to every acknowledged write
    /// (synchronous logging). 0 = free logging (default), so the
    /// durability tier meters without perturbing any existing timing.
    pub wal_fsync_s: f64,
    /// Snapshot a shard (and truncate its WAL) every this many WAL
    /// records; 0 = never snapshot. Snapshots are taken in the
    /// background (no service-time cost) — only recovery pays for
    /// whatever snapshot + WAL suffix it must replay.
    pub snapshot_every_ops: u64,
    /// Recovery replay cost per record (s) — snapshot entries + WAL
    /// suffix, metered as `DurabilityMetrics::stall_s`.
    pub replay_op_s: f64,
    /// Fixed per-recovery restart cost (s) before replay begins.
    pub recovery_base_s: f64,
}

impl Default for StorageConfig {
    fn default() -> Self {
        StorageConfig {
            mode: KvsMode::Redis,
            n_shards: 75,
            shard_bw: 300e6,
            op_latency_s: 0.001,
            iops_limit: 0.0,
            mds_latency_s: 0.0008,
            mds_ops_per_sec: 150_000.0,
            arg_inline_max: 256 * 1024,
            wal_fsync_s: 0.0,
            snapshot_every_ops: 0,
            replay_op_s: 2e-5,
            recovery_base_s: 0.05,
        }
    }
}

impl StorageConfig {
    /// Paper's "single Redis shard" comparison configuration.
    pub fn single_redis(mut self) -> Self {
        self.mode = KvsMode::Redis;
        self.n_shards = 1;
        self
    }

    /// Paper's numpywren-on-S3 configuration.
    pub fn s3(mut self) -> Self {
        self.mode = KvsMode::S3;
        self.n_shards = 64; // S3 prefix parallelism stand-in
        self.op_latency_s = 0.015;
        self.iops_limit = 3_500.0;
        self.shard_bw = 120e6;
        self
    }

    /// Paper Fig. 23 ElastiCache baseline: few (costly) cache nodes.
    pub fn elasticache(mut self) -> Self {
        self.mode = KvsMode::ElastiCache;
        self.n_shards = 5;
        self.op_latency_s = 0.0008;
        self.shard_bw = 600e6;
        self
    }
}

/// Wukong scheduler/executor policy knobs (§3.3–§3.4).
#[derive(Debug, Clone, Copy)]
pub struct WukongConfig {
    /// Output-size threshold `t` above which fan-out targets are clustered.
    pub clustering_threshold: u64,
    /// Enable task clustering (Fig. 22/23 ablation flag).
    pub use_clustering: bool,
    /// Enable delayed I/O (Fig. 22/23 ablation flag).
    pub use_delayed_io: bool,
    /// Delayed-I/O recheck interval (s).
    pub delayed_io_wait_s: f64,
    /// Delayed-I/O recheck attempts before giving up and storing.
    pub delayed_io_retries: u32,
    /// Fan-outs wider than this are delegated to the invoker pool.
    pub fanout_delegation_threshold: usize,
    /// Dedicated invoker processes co-located with the static scheduler.
    pub n_invokers: usize,
}

impl Default for WukongConfig {
    fn default() -> Self {
        WukongConfig {
            clustering_threshold: 200 * 1024 * 1024,
            use_clustering: true,
            use_delayed_io: true,
            delayed_io_wait_s: 0.01,
            delayed_io_retries: 500,
            fanout_delegation_threshold: 8,
            n_invokers: 64,
        }
    }
}

/// Serverful Dask-distributed model parameters (§4.1 comparisons).
#[derive(Debug, Clone, Copy)]
pub struct DaskConfig {
    pub n_workers: usize,
    pub cores_per_worker: usize,
    pub mem_per_worker_gb: f64,
    /// Central-scheduler base service time per task message (s).
    pub sched_msg_s: f64,
    /// Additional scheduler service time per connected worker (s) — the
    /// Dask-1000 "scheduler struggles with a thousand connections"
    /// effect (§4.2, §6).
    pub sched_msg_per_worker_s: f64,
    /// Per-worker NIC bandwidth (bytes/s).
    pub worker_bw: f64,
    /// Per-core compute rate (GFLOP/s).
    pub gflops_per_core: f64,
    /// TCP dispatch latency scheduler->worker (s).
    pub dispatch_latency_s: f64,
    /// EC2 $/hour for the whole cluster (billing).
    pub cluster_dollars_per_hour: f64,
}

impl DaskConfig {
    /// Paper's 1 000-worker configuration: 1 000 × (2-core, 3 GB) workers
    /// on 125 c5.4xlarge VMs — the "serverless-like" worst case.
    pub fn workers_1000() -> DaskConfig {
        DaskConfig {
            n_workers: 1000,
            cores_per_worker: 2,
            mem_per_worker_gb: 3.0,
            sched_msg_s: 0.0002,
            sched_msg_per_worker_s: 1e-6,
            worker_bw: 1.25e9 / 8.0, // share of a 10 Gbps VM NIC
            gflops_per_core: 10.0,
            dispatch_latency_s: 0.0005,
            cluster_dollars_per_hour: 125.0 * 0.68,
        }
    }

    /// Effective per-message scheduler service time for this worker count.
    pub fn effective_msg_s(&self) -> f64 {
        self.sched_msg_s + self.n_workers as f64 * self.sched_msg_per_worker_s
    }

    /// Paper's 125-worker configuration: one 16-core 24 GB worker per
    /// c5.4xlarge VM — the serverful best case.
    pub fn workers_125() -> DaskConfig {
        DaskConfig {
            n_workers: 125,
            cores_per_worker: 16,
            mem_per_worker_gb: 24.0,
            sched_msg_s: 0.0002,
            sched_msg_per_worker_s: 1e-6,
            worker_bw: 1.25e9, // full 10 Gbps VM NIC
            gflops_per_core: 10.0,
            dispatch_latency_s: 0.0005,
            cluster_dollars_per_hour: 125.0 * 0.68,
        }
    }
}

/// numpywren/PyWren baseline model parameters.
#[derive(Debug, Clone, Copy)]
pub struct NumpywrenConfig {
    /// Initial executor (worker) count — a user-tuned knob in numpywren.
    pub n_workers: usize,
    /// SQS-like task-queue op latency (s).
    pub queue_op_s: f64,
    /// Queue service throughput (ops/s) — central contention point.
    pub queue_ops_per_sec: f64,
    /// Idle poll interval when the queue is empty (s).
    pub poll_interval_s: f64,
    /// PyWren scheduler invoker threads.
    pub n_invoker_threads: usize,
}

impl Default for NumpywrenConfig {
    fn default() -> Self {
        NumpywrenConfig {
            n_workers: 169,
            queue_op_s: 0.030,
            queue_ops_per_sec: 600.0,
            poll_interval_s: 0.100,
            n_invoker_threads: 64,
        }
    }
}

/// Task-compute cost model shared by all engines.
#[derive(Debug, Clone, Copy)]
pub struct ComputeConfig {
    /// Fixed per-task runtime overhead (s): deserialize + dispatch.
    pub task_overhead_s: f64,
    /// Serialization throughput (bytes/s) charged on reads/writes/args.
    pub serde_bw: f64,
}

impl Default for ComputeConfig {
    fn default() -> Self {
        ComputeConfig {
            task_overhead_s: 0.001,
            serde_bw: 1.2e9,
        }
    }
}

/// Event-calendar selection for every `Sim<E>` a run constructs.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SimConfig {
    /// Priority structure: bucketed calendar queue (default) or the
    /// PR-2 binary heap (the differential reference — both produce
    /// bit-identical traces, see `rust/tests/calendar.rs`).
    pub calendar: CalendarKind,
    /// Pinned bucket width in µs for the bucket calendar; 0 (default)
    /// auto-sizes the width from the observed event-time spread.
    pub bucket_width_us: u64,
}

impl SimConfig {
    /// Construct the event calendar this config selects — the per-run
    /// entry point every engine uses in place of `Sim::new()`.
    pub fn build<E>(&self) -> Sim<E> {
        Sim::with_calendar(self.calendar, self.bucket_width_us)
    }
}

/// Top-level configuration.
#[derive(Debug, Clone)]
pub struct Config {
    pub lambda: LambdaConfig,
    pub storage: StorageConfig,
    pub wukong: WukongConfig,
    pub numpywren: NumpywrenConfig,
    pub compute: ComputeConfig,
    /// Fault-injection plan (§3.6): every sim engine consumes it. The
    /// default injects nothing, and draws come from a dedicated RNG
    /// stream, so fault-free runs are unaffected by its presence.
    pub faults: FaultPlan,
    /// KVS shard-crash plan: storage ops crash their shard with
    /// `p_crash` (up to `max_crashes` per run); the shard recovers by
    /// snapshot + WAL replay. Like `faults`, draws come from a
    /// dedicated salted stream, so the zero-rate default is
    /// bit-identical to having no plan at all.
    pub crashes: ShardCrashPlan,
    /// Runtime task-spawning plan (dynamic DAGs): completing tasks may
    /// emit subtask trees, appended through the delta-graph layer.
    /// Draws come from a dedicated salted stream, so the zero-rate
    /// default is bit-identical to having no plan at all.
    pub spawn: SpawnPlan,
    /// Job-arrival plan for the multi-tenant serving layer (`wukong
    /// serve`). Single-DAG engine runs never consult it, and its draws
    /// come from a dedicated salted stream, so any value here leaves
    /// `wukong run`/`verify`/`bench` single-job output bit-identical.
    pub arrival: ArrivalPlan,
    /// Tenant population + fairness policy for the serving layer; like
    /// `arrival`, invisible outside `wukong serve`/`verify --serving`.
    pub tenants: TenantPlan,
    /// Event-calendar selection (priority structure + bucket width);
    /// purely structural — any setting yields bit-identical traces.
    pub sim: SimConfig,
    /// Watchdog ceiling on processed DES events per run; 0 = unlimited.
    /// An engine that exceeds it panics (caught by `wukong verify` as a
    /// violation) instead of livelocking CI.
    pub event_budget: u64,
    /// Simulation seed (same seed + config ⇒ identical trace).
    pub seed: u64,
    /// Repetitions per data point (paper averages ten runs).
    pub runs: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            lambda: LambdaConfig::default(),
            storage: StorageConfig::default(),
            wukong: WukongConfig::default(),
            numpywren: NumpywrenConfig::default(),
            compute: ComputeConfig::default(),
            faults: FaultPlan::default(),
            crashes: ShardCrashPlan::default(),
            spawn: SpawnPlan::default(),
            arrival: ArrivalPlan::default(),
            tenants: TenantPlan::default(),
            sim: SimConfig::default(),
            event_budget: 0,
            seed: 42,
            runs: 3,
        }
    }
}

impl Config {
    /// Warm invoke latency in virtual time.
    pub fn invoke_latency(&self) -> Time {
        secs(self.lambda.invoke_latency_s)
    }

    /// Load an INI-style file (`[section]` + `key = value`) over defaults.
    pub fn from_file(path: &Path) -> Result<Config, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        let mut cfg = Config::default();
        for (section, key, value) in parse_ini(&text)? {
            cfg.set(&format!("{section}.{key}"), &value)?;
        }
        Ok(cfg)
    }

    /// Apply a dotted-path override, e.g. `lambda.invoke_latency_s=0.05`.
    pub fn set(&mut self, path: &str, value: &str) -> Result<(), String> {
        let f = || -> Result<f64, String> {
            value
                .parse::<f64>()
                .map_err(|e| format!("{path}: bad number {value:?}: {e}"))
        };
        let b = || -> Result<bool, String> {
            value
                .parse::<bool>()
                .map_err(|e| format!("{path}: bad bool {value:?}: {e}"))
        };
        match path {
            "seed" => self.seed = f()? as u64,
            "runs" => self.runs = f()? as usize,
            "lambda.memory_gb" => self.lambda.memory_gb = f()?,
            "lambda.invoke_latency_s" => self.lambda.invoke_latency_s = f()?,
            "lambda.cold_start_s" => self.lambda.cold_start_s = f()?,
            "lambda.cold_fraction" => self.lambda.cold_fraction = f()?,
            "lambda.invoke_jitter_sigma" => {
                self.lambda.invoke_jitter_sigma = f()?
            }
            "lambda.concurrency_limit" => {
                self.lambda.concurrency_limit = f()? as usize
            }
            "lambda.max_runtime_s" => self.lambda.max_runtime_s = f()?,
            "lambda.gflops" => self.lambda.gflops = f()?,
            "lambda.net_bw" => self.lambda.net_bw = f()?,
            "lambda.retries" => self.lambda.retries = f()? as u32,
            "storage.mode" => {
                self.storage.mode = match value {
                    "redis" => KvsMode::Redis,
                    "s3" => KvsMode::S3,
                    "elasticache" => KvsMode::ElastiCache,
                    other => return Err(format!("unknown storage.mode {other}")),
                }
            }
            "storage.n_shards" => self.storage.n_shards = f()? as usize,
            "storage.shard_bw" => self.storage.shard_bw = f()?,
            "storage.op_latency_s" => self.storage.op_latency_s = f()?,
            "storage.iops_limit" => self.storage.iops_limit = f()?,
            "storage.mds_latency_s" => self.storage.mds_latency_s = f()?,
            "storage.mds_ops_per_sec" => self.storage.mds_ops_per_sec = f()?,
            "storage.arg_inline_max" => {
                self.storage.arg_inline_max = f()? as u64
            }
            "storage.wal_fsync_s" => self.storage.wal_fsync_s = f()?,
            "storage.snapshot_every_ops" => {
                self.storage.snapshot_every_ops = f()? as u64
            }
            "storage.replay_op_s" => self.storage.replay_op_s = f()?,
            "storage.recovery_base_s" => self.storage.recovery_base_s = f()?,
            "wukong.clustering_threshold" => {
                self.wukong.clustering_threshold = f()? as u64
            }
            "wukong.use_clustering" => self.wukong.use_clustering = b()?,
            "wukong.use_delayed_io" => self.wukong.use_delayed_io = b()?,
            "wukong.delayed_io_wait_s" => self.wukong.delayed_io_wait_s = f()?,
            "wukong.delayed_io_retries" => {
                self.wukong.delayed_io_retries = f()? as u32
            }
            "wukong.fanout_delegation_threshold" => {
                self.wukong.fanout_delegation_threshold = f()? as usize
            }
            "wukong.n_invokers" => self.wukong.n_invokers = f()? as usize,
            "numpywren.n_workers" => self.numpywren.n_workers = f()? as usize,
            "numpywren.queue_op_s" => self.numpywren.queue_op_s = f()?,
            "numpywren.queue_ops_per_sec" => {
                self.numpywren.queue_ops_per_sec = f()?
            }
            "numpywren.poll_interval_s" => self.numpywren.poll_interval_s = f()?,
            "numpywren.n_invoker_threads" => {
                self.numpywren.n_invoker_threads = f()? as usize
            }
            "compute.task_overhead_s" => self.compute.task_overhead_s = f()?,
            "compute.serde_bw" => self.compute.serde_bw = f()?,
            "faults.p_fail" => self.faults.p_fail = prob(path, f()?)?,
            "faults.max_retries" => self.faults.max_retries = f()? as u32,
            "crashes.p_crash" => self.crashes.p_crash = prob(path, f()?)?,
            "crashes.max_crashes" => {
                self.crashes.max_crashes = f()? as u32
            }
            "spawn.p_spawn" => self.spawn.p_spawn = prob(path, f()?)?,
            "spawn.fanout" => {
                let v = f()?;
                if !(1.0..=1024.0).contains(&v) {
                    return Err(format!(
                        "{path}: fanout must be in [1, 1024], got {v}"
                    ));
                }
                self.spawn.fanout = v as u32;
            }
            "spawn.depth" => {
                let v = f()?;
                if !(1.0..=8.0).contains(&v) {
                    return Err(format!(
                        "{path}: depth must be in [1, 8], got {v}"
                    ));
                }
                self.spawn.depth = v as u32;
            }
            "spawn.task_dur_s" => {
                self.spawn.task_dur_s = nonneg(path, f()?)?
            }
            "spawn.out_bytes" => self.spawn.out_bytes = f()? as u64,
            "arrival.mode" => {
                self.arrival.mode = match value {
                    "poisson" => ArrivalMode::Poisson,
                    "trace" => ArrivalMode::Trace,
                    other => {
                        return Err(format!("unknown arrival.mode {other}"))
                    }
                }
            }
            "arrival.rate" => self.arrival.rate_per_s = nonneg(path, f()?)?,
            "arrival.jobs" => self.arrival.jobs = f()? as u64,
            "arrival.trace_gap_s" => {
                self.arrival.trace_gap_s = nonneg(path, f()?)?
            }
            "tenants.count" => self.tenants.count = f()? as usize,
            "tenants.policy" => {
                self.tenants.policy = match value {
                    "fifo" => FairnessPolicy::Fifo,
                    "wfair" => FairnessPolicy::WeightedFair,
                    other => {
                        return Err(format!("unknown tenants.policy {other}"))
                    }
                }
            }
            "tenants.weight_skew" => {
                self.tenants.weight_skew = nonneg(path, f()?)?
            }
            "sim.calendar" => {
                self.sim.calendar = match value {
                    "bucket" => CalendarKind::Bucket,
                    "heap" => CalendarKind::Heap,
                    other => {
                        return Err(format!(
                            "unknown sim.calendar {other} (expected bucket | heap)"
                        ))
                    }
                }
            }
            "sim.bucket_width_us" => {
                self.sim.bucket_width_us = nonneg(path, f()?)? as u64
            }
            "event_budget" => self.event_budget = f()? as u64,
            other => return Err(format!("unknown config key {other:?}")),
        }
        Ok(())
    }
}

/// Validate a probability knob at parse time: rejects values outside
/// [0, 1] (and NaN) with the offending key in the message, so a typo'd
/// `--set faults.p_fail=1.5` fails loudly instead of skewing a sweep.
fn prob(path: &str, v: f64) -> Result<f64, String> {
    if (0.0..=1.0).contains(&v) {
        Ok(v)
    } else {
        Err(format!("{path}: probability must be in [0, 1], got {v}"))
    }
}

/// Validate a rate/gap/skew knob at parse time: rejects negatives and
/// NaN with the offending key in the message (same contract as [`prob`]).
fn nonneg(path: &str, v: f64) -> Result<f64, String> {
    if v >= 0.0 {
        Ok(v)
    } else {
        Err(format!("{path}: must be non-negative, got {v}"))
    }
}

/// Parse INI text into `(section, key, value)` triples.
fn parse_ini(text: &str) -> Result<Vec<(String, String, String)>, String> {
    let mut out = Vec::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split(['#', ';']).next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(stripped) = line.strip_prefix('[') {
            section = stripped
                .strip_suffix(']')
                .ok_or(format!("line {}: bad section header", lineno + 1))?
                .trim()
                .to_string();
        } else if let Some((k, v)) = line.split_once('=') {
            out.push((
                section.clone(),
                k.trim().to_string(),
                v.trim().to_string(),
            ));
        } else {
            return Err(format!("line {}: expected key = value", lineno + 1));
        }
    }
    Ok(out)
}

/// Parse a `--set a.b=c` style override list into an existing config.
pub fn apply_overrides(
    cfg: &mut Config,
    overrides: &BTreeMap<String, String>,
) -> Result<(), String> {
    for (k, v) in overrides {
        cfg.set(k, v)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_constants() {
        let c = Config::default();
        assert_eq!(c.lambda.memory_gb, 3.0);
        assert_eq!(c.lambda.invoke_latency_s, 0.050);
        assert_eq!(c.lambda.concurrency_limit, 5_000);
        assert_eq!(c.storage.n_shards, 75);
        assert_eq!(c.storage.arg_inline_max, 256 * 1024);
        assert_eq!(c.wukong.clustering_threshold, 200 * 1024 * 1024);
        assert_eq!(c.wukong.n_invokers, 64);
    }

    #[test]
    fn set_overrides_work() {
        let mut c = Config::default();
        c.set("lambda.invoke_latency_s", "0.1").unwrap();
        c.set("storage.mode", "s3").unwrap();
        c.set("wukong.use_clustering", "false").unwrap();
        c.set("faults.p_fail", "0.25").unwrap();
        c.set("faults.max_retries", "1").unwrap();
        assert_eq!(c.lambda.invoke_latency_s, 0.1);
        assert_eq!(c.storage.mode, KvsMode::S3);
        assert!(!c.wukong.use_clustering);
        assert_eq!(c.faults, FaultPlan::with_retries(0.25, 1));
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = Config::default();
        let err = c.set("nope.nope", "1").unwrap_err();
        assert!(err.contains("unknown config key"), "{err}");
        assert!(err.contains("nope.nope"), "{err}");
    }

    #[test]
    fn durability_and_crash_keys_work() {
        let mut c = Config::default();
        c.set("storage.wal_fsync_s", "0.0002").unwrap();
        c.set("storage.snapshot_every_ops", "32").unwrap();
        c.set("storage.replay_op_s", "0.0001").unwrap();
        c.set("storage.recovery_base_s", "0.2").unwrap();
        c.set("crashes.p_crash", "0.5").unwrap();
        c.set("crashes.max_crashes", "2").unwrap();
        c.set("event_budget", "1000000").unwrap();
        assert_eq!(c.storage.wal_fsync_s, 0.0002);
        assert_eq!(c.storage.snapshot_every_ops, 32);
        assert_eq!(c.storage.replay_op_s, 0.0001);
        assert_eq!(c.storage.recovery_base_s, 0.2);
        assert_eq!(c.crashes, ShardCrashPlan::with_crashes(0.5, 2));
        assert_eq!(c.event_budget, 1_000_000);
    }

    #[test]
    fn arrival_and_tenant_keys_work() {
        let mut c = Config::default();
        c.set("arrival.mode", "trace").unwrap();
        c.set("arrival.rate", "8.5").unwrap();
        c.set("arrival.jobs", "2500").unwrap();
        c.set("arrival.trace_gap_s", "0.125").unwrap();
        c.set("tenants.count", "7").unwrap();
        c.set("tenants.policy", "wfair").unwrap();
        c.set("tenants.weight_skew", "0.5").unwrap();
        assert_eq!(c.arrival.mode, ArrivalMode::Trace);
        assert_eq!(c.arrival.rate_per_s, 8.5);
        assert_eq!(c.arrival.jobs, 2500);
        assert_eq!(c.arrival.trace_gap_s, 0.125);
        assert_eq!(c.tenants.count, 7);
        assert_eq!(c.tenants.policy, FairnessPolicy::WeightedFair);
        assert_eq!(c.tenants.weight_skew, 0.5);
        c.set("arrival.mode", "poisson").unwrap();
        c.set("tenants.policy", "fifo").unwrap();
        assert_eq!(c.arrival.mode, ArrivalMode::Poisson);
        assert_eq!(c.tenants.policy, FairnessPolicy::Fifo);
    }

    #[test]
    fn bad_arrival_and_tenant_values_rejected_at_parse_time() {
        let mut c = Config::default();
        let err = c.set("arrival.mode", "burst").unwrap_err();
        assert!(err.contains("arrival.mode"), "{err}");
        let err = c.set("tenants.policy", "priority").unwrap_err();
        assert!(err.contains("tenants.policy"), "{err}");
        for (key, bad) in [
            ("arrival.rate", "-2"),
            ("arrival.rate", "nan"),
            ("arrival.trace_gap_s", "-0.5"),
            ("tenants.weight_skew", "-1"),
        ] {
            let err = c.set(key, bad).unwrap_err();
            assert!(
                err.contains(key) && err.contains("non-negative"),
                "{key}={bad}: {err}"
            );
        }
        // Rejected overrides leave the config untouched.
        assert_eq!(c.arrival, ArrivalPlan::default());
        assert_eq!(c.tenants, TenantPlan::default());
        // Zero boundaries are fine (the empty-stream plan).
        c.set("arrival.rate", "0").unwrap();
        c.set("tenants.weight_skew", "0").unwrap();
    }

    #[test]
    fn probabilities_outside_unit_interval_rejected() {
        let mut c = Config::default();
        for (key, bad) in [
            ("faults.p_fail", "1.5"),
            ("faults.p_fail", "-0.1"),
            ("faults.p_fail", "nan"),
            ("crashes.p_crash", "2"),
            ("crashes.p_crash", "-1"),
        ] {
            let err = c.set(key, bad).unwrap_err();
            assert!(
                err.contains(key) && err.contains("must be in [0, 1]"),
                "{key}={bad}: {err}"
            );
        }
        // The config is untouched by rejected overrides.
        assert_eq!(c.faults.p_fail, 0.0);
        assert_eq!(c.crashes.p_crash, 0.0);
        // Boundary values are fine.
        c.set("faults.p_fail", "1").unwrap();
        c.set("crashes.p_crash", "0").unwrap();
    }

    #[test]
    fn spawn_keys_work() {
        let mut c = Config::default();
        assert!(!c.spawn.is_live()); // dynamic expansion is opt-in
        c.set("spawn.p_spawn", "0.25").unwrap();
        c.set("spawn.fanout", "4").unwrap();
        c.set("spawn.depth", "3").unwrap();
        c.set("spawn.task_dur_s", "0.005").unwrap();
        c.set("spawn.out_bytes", "65536").unwrap();
        assert_eq!(c.spawn.p_spawn, 0.25);
        assert_eq!(c.spawn.fanout, 4);
        assert_eq!(c.spawn.depth, 3);
        assert_eq!(c.spawn.task_dur_s, 0.005);
        assert_eq!(c.spawn.out_bytes, 65_536);
        assert!(c.spawn.is_live());
    }

    #[test]
    fn bad_spawn_values_rejected_at_parse_time() {
        let mut c = Config::default();
        let err = c.set("spawn.p_spawn", "1.5").unwrap_err();
        assert!(
            err.contains("spawn.p_spawn") && err.contains("must be in [0, 1]"),
            "{err}"
        );
        let err = c.set("spawn.fanout", "0").unwrap_err();
        assert!(
            err.contains("spawn.fanout") && err.contains("[1, 1024]"),
            "{err}"
        );
        let err = c.set("spawn.fanout", "2000").unwrap_err();
        assert!(err.contains("spawn.fanout"), "{err}");
        let err = c.set("spawn.depth", "9").unwrap_err();
        assert!(
            err.contains("spawn.depth") && err.contains("[1, 8]"),
            "{err}"
        );
        let err = c.set("spawn.task_dur_s", "-1").unwrap_err();
        assert!(
            err.contains("spawn.task_dur_s") && err.contains("non-negative"),
            "{err}"
        );
        // Rejected overrides leave the config untouched.
        assert_eq!(c.spawn, SpawnPlan::default());
    }

    #[test]
    fn sim_calendar_keys_work() {
        let mut c = Config::default();
        assert_eq!(c.sim.calendar, CalendarKind::Bucket);
        assert_eq!(c.sim.bucket_width_us, 0);
        c.set("sim.calendar", "heap").unwrap();
        c.set("sim.bucket_width_us", "128").unwrap();
        assert_eq!(c.sim.calendar, CalendarKind::Heap);
        assert_eq!(c.sim.bucket_width_us, 128);
        c.set("sim.calendar", "bucket").unwrap();
        assert_eq!(c.sim.calendar, CalendarKind::Bucket);
    }

    #[test]
    fn bad_sim_calendar_values_rejected_at_parse_time() {
        let mut c = Config::default();
        let err = c.set("sim.calendar", "fibheap").unwrap_err();
        assert!(
            err.contains("sim.calendar") && err.contains("fibheap"),
            "{err}"
        );
        let err = c.set("sim.bucket_width_us", "-5").unwrap_err();
        assert!(
            err.contains("sim.bucket_width_us")
                && err.contains("non-negative"),
            "{err}"
        );
        // Rejected overrides leave the config untouched.
        assert_eq!(c.sim, SimConfig::default());
    }

    #[test]
    fn ini_parser_handles_sections_and_comments() {
        let triples = parse_ini(
            "# comment\n[lambda]\ninvoke_latency_s = 0.2 # inline\n\n[storage]\nn_shards=3\n",
        )
        .unwrap();
        assert_eq!(
            triples,
            vec![
                (
                    "lambda".into(),
                    "invoke_latency_s".into(),
                    "0.2".into()
                ),
                ("storage".into(), "n_shards".into(), "3".into()),
            ]
        );
    }

    #[test]
    fn storage_presets() {
        let s3 = StorageConfig::default().s3();
        assert_eq!(s3.mode, KvsMode::S3);
        assert!(s3.iops_limit > 0.0);
        let single = StorageConfig::default().single_redis();
        assert_eq!(single.n_shards, 1);
    }

    #[test]
    fn dask_presets_match_paper() {
        let d1000 = DaskConfig::workers_1000();
        let d125 = DaskConfig::workers_125();
        // both are 2,000 cores / ~3,000 GB total
        assert_eq!(d1000.n_workers * d1000.cores_per_worker, 2000);
        assert_eq!(d125.n_workers * d125.cores_per_worker, 2000);
        assert_eq!(d1000.n_workers as f64 * d1000.mem_per_worker_gb, 3000.0);
        assert_eq!(d125.n_workers as f64 * d125.mem_per_worker_gb, 3000.0);
    }
}
