//! `wukong` — the launcher: figures, workload runs, DAG inspection, and
//! the real-engine (PJRT) demo.

use std::path::Path;
use std::process::ExitCode;

use wukong::bench::{run_bench, to_json, BenchOptions};
use wukong::cli::{Args, USAGE};
use wukong::config::{apply_overrides, Config};
use wukong::dag::Dag;
use wukong::engine::{engine_by_name, sim_engine_names, Engine as _};
use wukong::serving::run_serving;
use wukong::verify::{run_verify, VerifyOptions};
use wukong::workloads::{gemm, svc, svd, tr, tsqr};
use wukong::{figures, util};

fn parse_threads(args: &Args) -> Result<usize, String> {
    match args.opt("threads") {
        Some(t) => t.parse().map_err(|e| format!("--threads: {e}")),
        None => Ok(0), // auto: one worker per available core
    }
}

fn parse_engine_list(args: &Args) -> Vec<String> {
    args.opt("engine")
        .map(|list| {
            list.split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect()
        })
        .unwrap_or_default()
}

fn build_workload(name: &str) -> Option<Dag> {
    Some(match name {
        "tr" => tr::dag(tr::TrParams::default()),
        "gemm" => gemm::dag(gemm::GemmParams::paper(25)),
        "tsqr" => tsqr::dag(tsqr::TsqrParams::paper(4.0)),
        "svd1" => svd::svd1(svd::Svd1Params::paper(1.0)),
        "svd2" => svd::svd2(svd::Svd2Params::paper(50)),
        "svc" => svc::dag(svc::SvcParams::paper(1.0)),
        _ => return None,
    })
}

fn main() -> ExitCode {
    match run(std::env::args().skip(1).collect()) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn load_config(args: &Args) -> Result<Config, String> {
    let mut cfg = match args.opt("config") {
        Some(path) => Config::from_file(Path::new(path))?,
        None => Config::default(),
    };
    apply_overrides(&mut cfg, &args.sets)?;
    if let Some(runs) = args.opt("runs") {
        cfg.runs = runs.parse().map_err(|e| format!("--runs: {e}"))?;
    }
    if let Some(seed) = args.opt("seed") {
        cfg.seed = seed.parse().map_err(|e| format!("--seed: {e}"))?;
    }
    Ok(cfg)
}

fn run(argv: Vec<String>) -> Result<(), String> {
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "" | "help" | "--help" => {
            println!("{USAGE}");
            Ok(())
        }
        "list" => {
            println!("figures:   {}", figures::all_ids().join(" "));
            println!("workloads: tr gemm tsqr svd1 svd2 svc");
            Ok(())
        }
        "figure" => {
            let cfg = load_config(&args)?;
            let quick = args.flag("quick");
            let id = args
                .positional
                .first()
                .map(String::as_str)
                .unwrap_or("all");
            let ids = if id == "all" {
                figures::all_ids()
            } else {
                vec![figures::all_ids()
                    .into_iter()
                    .find(|&x| x == id)
                    .ok_or_else(|| {
                        format!("unknown figure {id:?} (try `wukong list`)")
                    })?]
            };
            // Figure sweeps are pure per id: fan out across the pool and
            // print in id order (identical output to a sequential run).
            let threads = parse_threads(&args)?;
            for fig in figures::run_many(&ids, &cfg, quick, threads) {
                println!("== {} — {}", fig.id, fig.caption);
                println!("{}", fig.table.render());
            }
            Ok(())
        }
        "run" => {
            let cfg = load_config(&args)?;
            let name = args
                .positional
                .first()
                .ok_or("run: which workload? (try `wukong list`)")?;
            let dag = build_workload(name)
                .ok_or_else(|| format!("unknown workload {name:?}"))?;
            let engine = args.opt("engine").unwrap_or("wukong");
            println!(
                "workload {name}: {} tasks, {} edges, {} leaves",
                dag.len(),
                dag.n_edges(),
                dag.leaves().len()
            );
            // Every engine runs through the unified trait (same path the
            // `verify` conformance harness exercises).
            let eng = engine_by_name(engine).ok_or_else(|| {
                format!(
                    "unknown engine {engine:?} (known: {})",
                    sim_engine_names().join(" ")
                )
            })?;
            let m = eng.run(&dag, &cfg, cfg.seed).metrics;
            let mut t = util::table::Table::new(vec!["metric", "value"]);
            t.row(vec![
                "makespan".to_string(),
                util::stats::human_secs(m.makespan_s),
            ]);
            t.row(vec!["tasks executed".to_string(), m.tasks_executed.to_string()]);
            t.row(vec!["executors used".to_string(), m.executors_used.to_string()]);
            t.row(vec![
                "peak concurrency".to_string(),
                m.peak_concurrency.to_string(),
            ]);
            t.row(vec![
                "KVS read".to_string(),
                util::stats::human_bytes(m.kvs.bytes_read as f64),
            ]);
            t.row(vec![
                "KVS written".to_string(),
                util::stats::human_bytes(m.kvs.bytes_written as f64),
            ]);
            t.row(vec!["CPU core-s".to_string(), format!("{:.1}", m.cpu_seconds)]);
            t.row(vec!["cost".to_string(), format!("${:.4}", m.dollars())]);
            println!("{}", t.render());
            Ok(())
        }
        "dag" => {
            let name = args
                .positional
                .first()
                .ok_or("dag: which workload?")?;
            let dag = build_workload(name)
                .ok_or_else(|| format!("unknown workload {name:?}"))?;
            println!("{}", dag.to_dot());
            Ok(())
        }
        "verify" => {
            let mut opts = VerifyOptions::default();
            opts.engines = parse_engine_list(&args);
            if let Some(runs) = args.opt("runs") {
                opts.runs = runs.parse().map_err(|e| format!("--runs: {e}"))?;
            }
            if let Some(seed) = args.opt("seed") {
                opts.seed = seed.parse().map_err(|e| format!("--seed: {e}"))?;
            }
            opts.threads = parse_threads(&args)?;
            opts.large = args.flag("large");
            opts.verbose = args.flag("verbose");
            opts.faults = args.flag("faults");
            opts.crashes = args.flag("crashes");
            opts.serving = args.flag("serving");
            opts.dynamic = args.flag("dynamic");
            let summary = run_verify(&opts)?;
            let mut t = util::table::Table::new(vec!["metric", "value"]);
            t.row(vec!["engines".into(), summary.engines.join(" ")]);
            t.row(vec!["DAG cases".into(), summary.cases.to_string()]);
            t.row(vec!["total tasks".into(), summary.total_tasks.to_string()]);
            t.row(vec!["engine runs".into(), summary.engine_runs.to_string()]);
            t.row(vec![
                "violations".into(),
                summary.violations.len().to_string(),
            ]);
            println!("{}", t.render());
            if summary.ok() {
                println!(
                    "conformance OK: exactly-once, completion, determinism \
                     and locality ordering hold on every case{}{}{}{}",
                    if opts.faults {
                        ", incl. the §3.6 fault axis (retry bounds, \
                         completed-xor-failed totality, fault-free \
                         bit-identity)"
                    } else {
                        ""
                    },
                    if opts.crashes {
                        ", incl. the durable-KVS crash axis (recovered \
                         runs byte-identical to uninterrupted modulo \
                         recovery meters)"
                    } else {
                        ""
                    },
                    if opts.serving {
                        ", incl. the multi-tenant serving axis (job \
                         conservation, byte-identical replays, zero-rate \
                         streams are no-ops)"
                    } else {
                        ""
                    },
                    if opts.dynamic {
                        ", incl. the dynamic-DAG axis (runtime expansion \
                         byte-identical to the pre-expanded DAG, \
                         zero-rate plans bit-identical to plan-free)"
                    } else {
                        ""
                    }
                );
                Ok(())
            } else {
                for v in &summary.violations {
                    eprintln!("violation: {v}");
                }
                Err(format!(
                    "{} conformance violation(s)",
                    summary.violations.len()
                ))
            }
        }
        "bench" => {
            let mut opts = BenchOptions {
                quick: args.flag("quick"),
                engines: parse_engine_list(&args),
                ..BenchOptions::default()
            };
            if let Some(seed) = args.opt("seed") {
                opts.seed = seed.parse().map_err(|e| format!("--seed: {e}"))?;
            }
            let records = run_bench(&opts)?;
            let mut t = util::table::Table::new(vec![
                "engine",
                "workload",
                "tasks",
                "wall (ms)",
                "events",
                "events/sec",
                "peak pending",
                "makespan (s)",
            ]);
            for r in &records {
                t.row(vec![
                    r.engine.to_string(),
                    r.workload.to_string(),
                    r.tasks.to_string(),
                    format!("{:.1}", r.wall_ms),
                    r.sim_events.to_string(),
                    format!("{:.3}M", r.events_per_sec / 1e6),
                    r.peak_pending.to_string(),
                    format!("{:.2}", r.makespan_s),
                ]);
            }
            println!("{}", t.render());
            let json = to_json(&records, &opts);
            let path = args
                .opt("out")
                .map(String::from)
                .unwrap_or_else(wukong::bench::default_out_path);
            std::fs::write(&path, &json).map_err(|e| format!("{path}: {e}"))?;
            println!("wrote {path}");
            if let Some(baseline_path) = args.opt("diff") {
                let baseline = std::fs::read_to_string(baseline_path)
                    .map_err(|e| format!("{baseline_path}: {e}"))?;
                let diff = wukong::bench::diff::diff_benches(&baseline, &json)?;
                for line in &diff.lines {
                    println!("diff: {line}");
                }
                if !diff.passed() {
                    return Err(format!(
                        "bench regression gate: {} row(s) failed vs \
                         {baseline_path}",
                        diff.failures.len()
                    ));
                }
                println!("bench diff vs {baseline_path}: ok");
            }
            Ok(())
        }
        "serve" => {
            // Multi-tenant job-stream serving: a continuous stream of
            // DAG jobs multiplexed over one shared Lambda pool + KVS.
            let mut cfg = load_config(&args)?;
            let threads = parse_threads(&args)?;
            if args.flag("quick") {
                cfg.arrival.jobs = cfg.arrival.jobs.min(120);
            }
            let report = run_serving(&cfg, cfg.seed, threads);
            println!("{}", report.render());
            if let Some(path) = args.opt("out") {
                std::fs::write(path, format!("{}\n", report.to_json()))
                    .map_err(|e| format!("{path}: {e}"))?;
                println!("wrote {path}");
            }
            if report.conserves_jobs() {
                Ok(())
            } else {
                Err(format!(
                    "serving lost jobs: {} arrived, {} admitted, \
                     {} completed + {} failed",
                    report.arrived,
                    report.admitted,
                    report.completed,
                    report.failed
                ))
            }
        }
        "serve-real" => {
            let quick = args.flag("quick");
            serve_demo(quick).map_err(|e| e.to_string())
        }
        other => Err(format!("unknown command {other:?}\n\n{USAGE}")),
    }
}

/// Real-engine demo: run a small TSQR with real PJRT compute and verify
/// the factorization end to end.
fn serve_demo(quick: bool) -> anyhow::Result<()> {
    use wukong::engine::{run_real_wukong, seed_inputs, RealConfig};
    use wukong::runtime::{default_artifact_dir, SharedRuntime};
    use wukong::storage::real_kvs::RealKvs;

    let rt = SharedRuntime::load(&default_artifact_dir())?;
    rt.warmup()?;
    let nb = if quick { 2 } else { 8 };
    let dag = tsqr::dag(tsqr::TsqrParams {
        rows: 1024 * nb,
        cols: 128,
        block_rows: 1024,
        with_q: true,
    });
    let kvs = RealKvs::new(16, 0.0, 0.0);
    seed_inputs(&dag, &kvs, 7);
    let report = run_real_wukong(&dag, rt, kvs, RealConfig::default())?;
    println!(
        "real TSQR ({} tasks): {:?}, {} executors, KVS {} B written",
        report.tasks_executed,
        report.makespan,
        report.executors_used,
        report.kvs_bytes_written
    );
    Ok(())
}
