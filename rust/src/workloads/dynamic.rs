//! Irregular, recursion-shaped workload DAGs — the static analogs of the
//! runtime-spawning workloads dynamic DAG engines face (recursive
//! fork-join divide-and-conquer, branch-and-bound search with pruning).
//!
//! Like every other generator these are pure functions from parameters to
//! a [`Dag`]; `branch_and_bound` additionally takes a `seed` because its
//! pruning pattern is random *by definition* (the search tree's shape
//! depends on the instance), drawn from its own `Rng` so the same params
//! reproduce the same tree. The conformance corpus wraps both
//! (`verify::corpus`), and `tests/dynamic.rs` uses them as base graphs
//! under live spawn plans.

use crate::dag::{Dag, DagBuilder, OpKind, TaskId};
use crate::util::Rng;

/// Recursive fork-join parameters.
#[derive(Debug, Clone, Copy)]
pub struct ForkJoinParams {
    /// Children forked per internal node.
    pub fanout: usize,
    /// Recursion depth (`0` = a single leaf task).
    pub depth: usize,
    /// Work per task.
    pub flops: f64,
    /// Output size per task.
    pub out_bytes: u64,
}

/// Divide-and-conquer fork-join: each internal node forks `fanout`
/// subproblems and a mirrored join combines their results, recursively to
/// `depth` levels. Node count is `N(d) = 2 + fanout·N(d+1)`, `N(depth) = 1`
/// — e.g. fanout 2 × depth 2 → 10 tasks, fanout 3 × depth 3 → 53.
pub fn fork_join(p: ForkJoinParams) -> Dag {
    assert!(p.fanout >= 1);
    let mut b = DagBuilder::new(&format!("forkjoin_f{}d{}", p.fanout, p.depth));
    fn subtree(
        b: &mut DagBuilder,
        p: &ForkJoinParams,
        d: usize,
        path: &str,
    ) -> (TaskId, TaskId) {
        if d == p.depth {
            let leaf = b.task(
                format!("fj{path}_leaf"),
                OpKind::Generic,
                p.flops,
                p.out_bytes,
            );
            return (leaf, leaf);
        }
        let fork = b.task(
            format!("fj{path}_fork"),
            OpKind::Generic,
            p.flops,
            p.out_bytes,
        );
        let join = b.task(
            format!("fj{path}_join"),
            OpKind::Generic,
            p.flops,
            p.out_bytes,
        );
        for i in 0..p.fanout {
            let (top, bottom) = subtree(b, p, d + 1, &format!("{path}_{i}"));
            b.edge(fork, top);
            b.edge(bottom, join);
        }
        (fork, join)
    }
    subtree(&mut b, &p, 0, "");
    b.build().expect("fork-join DAG is acyclic by construction")
}

/// Branch-and-bound parameters.
#[derive(Debug, Clone, Copy)]
pub struct BranchBoundParams {
    /// Children expanded per surviving node.
    pub branches: usize,
    /// Maximum search depth.
    pub depth: usize,
    /// Levels expanded unconditionally before pruning starts (bounds the
    /// minimum tree size).
    pub keep_levels: usize,
    /// Probability a node past `keep_levels` is pruned (becomes a leaf).
    pub p_prune: f64,
    /// Work per node.
    pub flops: f64,
    /// Output size per node.
    pub out_bytes: u64,
    /// Seed for the pruning pattern (same params + seed ⇒ same tree).
    pub seed: u64,
}

/// Branch-and-bound search tree: a root expands `branches` children per
/// level; past `keep_levels`, each node is pruned with `p_prune` (the
/// bound cut). Every leaf — pruned or full-depth — feeds one final
/// "best" sink (the incumbent reduction), so the DAG has a single sink
/// and its completion requires the whole pruned frontier.
pub fn branch_and_bound(p: BranchBoundParams) -> Dag {
    assert!(p.branches >= 1 && p.depth >= 1);
    let mut rng = Rng::new(p.seed);
    let mut b = DagBuilder::new(&format!("bnb_b{}d{}", p.branches, p.depth));
    let root = b.task("bb_root", OpKind::Generic, p.flops, p.out_bytes);
    let mut frontier = vec![root];
    let mut tails: Vec<TaskId> = Vec::new();
    for level in 1..=p.depth {
        let mut next = Vec::with_capacity(frontier.len() * p.branches);
        for (i, &parent) in frontier.iter().enumerate() {
            for j in 0..p.branches {
                let t = b.task(
                    format!("bb_l{level}_{i}_{j}"),
                    OpKind::Generic,
                    p.flops,
                    p.out_bytes,
                );
                b.edge(parent, t);
                let pruned = level >= p.depth
                    || (level > p.keep_levels && rng.f64() < p.p_prune);
                if pruned {
                    tails.push(t);
                } else {
                    next.push(t);
                }
            }
        }
        frontier = next;
        if frontier.is_empty() {
            break;
        }
    }
    tails.extend(frontier);
    let best = b.task("bb_best", OpKind::Generic, p.flops, p.out_bytes);
    for &t in &tails {
        b.edge(t, best);
    }
    b.build().expect("branch-and-bound DAG is acyclic by construction")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fork_join_matches_the_closed_form() {
        // N(d) = 2 + F·N(d+1), N(depth) = 1
        for (fanout, depth, expect) in
            [(2, 2, 10), (3, 3, 53), (3, 4, 161), (4, 4, 426)]
        {
            let d = fork_join(ForkJoinParams {
                fanout,
                depth,
                flops: 1.0,
                out_bytes: 64,
            });
            assert_eq!(d.len(), expect, "F={fanout} D={depth}");
            assert_eq!(d.leaves().len(), 1, "one fork root");
            assert_eq!(d.sinks().len(), 1, "one join sink");
            assert_eq!(d.topo_order().len(), d.len());
        }
    }

    #[test]
    fn fork_join_depth_zero_is_one_task() {
        let d = fork_join(ForkJoinParams {
            fanout: 3,
            depth: 0,
            flops: 1.0,
            out_bytes: 8,
        });
        assert_eq!(d.len(), 1);
    }

    #[test]
    fn branch_and_bound_bounds_hold() {
        // keep_levels full expansion gives the floor; no-prune gives the
        // ceiling (full tree + sink).
        let p = BranchBoundParams {
            branches: 2,
            depth: 4,
            keep_levels: 2,
            p_prune: 0.35,
            flops: 1.0,
            out_bytes: 64,
            seed: 11,
        };
        let d = branch_and_bound(p);
        // floor: 1 + 2 + 4 (kept levels) + sink; ceiling: full binary
        // tree to depth 4 + sink.
        assert!(d.len() >= 8, "{}", d.len());
        assert!(d.len() <= 32, "{}", d.len());
        assert_eq!(d.sinks().len(), 1);
        assert_eq!(d.leaves().len(), 1);
        assert_eq!(d.topo_order().len(), d.len());
    }

    #[test]
    fn branch_and_bound_is_deterministic_per_seed() {
        let p = BranchBoundParams {
            branches: 3,
            depth: 5,
            keep_levels: 2,
            p_prune: 0.5,
            flops: 1.0,
            out_bytes: 64,
            seed: 7,
        };
        let a = branch_and_bound(p);
        let b = branch_and_bound(p);
        assert_eq!(a.len(), b.len());
        assert_eq!(a.n_edges(), b.n_edges());
        let c = branch_and_bound(BranchBoundParams { seed: 8, ..p });
        // a different seed prunes differently (overwhelmingly likely)
        assert!(a.len() != c.len() || a.n_edges() != c.n_edges());
    }

    #[test]
    fn pruning_probability_one_stops_at_keep_levels() {
        let d = branch_and_bound(BranchBoundParams {
            branches: 2,
            depth: 6,
            keep_levels: 2,
            p_prune: 1.0,
            flops: 1.0,
            out_bytes: 8,
            seed: 3,
        });
        // 1 + 2 + 4 kept, level 3 fully expanded then all pruned, + sink
        assert_eq!(d.len(), 1 + 2 + 4 + 8 + 1);
    }
}
