//! SVC — support-vector/logistic classification (§4.1, Fig. 12), shaped
//! like the Dask-ML benchmark the paper uses: per-partition gradients,
//! a tree-reduce, a weight update, and a broadcast into the next
//! iteration's gradient tasks.

use crate::dag::{Dag, DagBuilder, OpKind, TaskId};

use super::{reduction_tree, ELEM};

/// SVC parameters.
#[derive(Debug, Clone, Copy)]
pub struct SvcParams {
    /// Total training samples.
    pub samples: usize,
    /// Feature dimension.
    pub features: usize,
    /// Data partitions (one gradient task each per iteration).
    pub partitions: usize,
    /// Gradient-descent iterations in the graph.
    pub iters: usize,
}

impl SvcParams {
    /// Paper sizes: 0.5M–8M samples, 64 features, sample-proportional
    /// partitioning (~16k samples per partition), 3 unrolled iterations.
    pub fn paper(millions_of_samples: f64) -> SvcParams {
        let samples = (millions_of_samples * 1e6) as usize;
        SvcParams {
            samples,
            features: 64,
            partitions: (samples / 16_384).max(1),
            iters: 3,
        }
    }
}

/// Build the SVC DAG.
pub fn dag(p: SvcParams) -> Dag {
    assert!(p.partitions >= 1 && p.iters >= 1);
    let per_part = p.samples / p.partitions.max(1);
    let m = per_part as f64;
    let d = p.features as f64;
    let part_bytes = (per_part * (p.features + 1)) as u64 * ELEM; // X_i + y_i
    let grad_bytes = p.features as u64 * ELEM;
    let mut b = DagBuilder::new(&format!(
        "svc_{}m_{}p",
        p.samples / 1_000_000,
        p.partitions
    ));

    let mut prev_update: Option<TaskId> = None;
    for it in 0..p.iters {
        let grads: Vec<TaskId> = (0..p.partitions)
            .map(|i| {
                let t = b.task(
                    format!("grad_{it}_{i}"),
                    OpKind::SvcGrad,
                    4.0 * m * d,
                    grad_bytes,
                );
                b.with_input(t, part_bytes);
                if let Some(u) = prev_update {
                    b.edge(u, t); // broadcast of updated weights
                }
                t
            })
            .collect();
        let total = reduction_tree(
            &mut b,
            grads,
            OpKind::BlockAdd,
            d,
            grad_bytes,
            &format!("gsum_{it}"),
        );
        let update = b.task(
            format!("update_{it}"),
            OpKind::SvcUpdate,
            2.0 * d,
            grad_bytes,
        );
        b.edge(total, update);
        prev_update = Some(update);
    }
    b.build().expect("SVC DAG is well-formed")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iteration_structure() {
        let p = SvcParams {
            samples: 64_000,
            features: 64,
            partitions: 4,
            iters: 2,
        };
        let d = dag(p);
        // per iter: 4 grads + 3 sums + 1 update = 8; × 2 iters
        assert_eq!(d.len(), 16);
        assert_eq!(d.sinks().len(), 1); // last update
        assert_eq!(d.leaves().len(), 4); // first iteration's grads
    }

    #[test]
    fn update_broadcasts_to_next_iteration() {
        let p = SvcParams {
            samples: 64_000,
            features: 64,
            partitions: 4,
            iters: 2,
        };
        let d = dag(p);
        let u0 = (0..d.len() as u32)
            .find(|&t| d.task_name(t) == "update_0")
            .unwrap();
        assert_eq!(d.children(u0).len(), 4);
    }

    #[test]
    fn paper_partition_scaling() {
        let small = SvcParams::paper(0.5);
        let large = SvcParams::paper(8.0);
        assert!(large.partitions > small.partitions);
        assert_eq!(large.features, 64);
    }
}
