//! SVD workloads (§4.1, Figs. 10, 11, 17, 18, 22, 23).
//!
//! * **SVD1** — tall-skinny SVD via the Gram route: per-block AᵀA,
//!   tree-summed, eigensolved (Jacobi), then the left vectors U are
//!   reconstructed per block (U_i = A_i·V·S⁻¹) — the U panels are the
//!   large intermediates.
//! * **SVD2** — square-matrix approximate SVD (Halko-style randomized
//!   range finder, the paper's [40]): Y = A·Ω, TSQR(Y) → Q, B = QᵀA
//!   (tree-summed k×n partials — *large*), small SVD of B, then
//!   U = Q·Ũ. The large B partials and Q panels are what task clustering
//!   and delayed I/O eliminate (Figs. 22–23).

use crate::dag::{Dag, DagBuilder, OpKind, TaskId};

use super::{reduction_tree, ELEM};

/// SVD1 parameters (tall-skinny m×n, row-blocked).
#[derive(Debug, Clone, Copy)]
pub struct Svd1Params {
    pub rows: usize,
    pub cols: usize,
    pub block_rows: usize,
}

impl Svd1Params {
    pub fn nb(&self) -> usize {
        assert!(self.rows % self.block_rows == 0);
        self.rows / self.block_rows
    }

    /// Paper sizes: 0.25M–16M rows × 128 cols.
    pub fn paper(millions_of_rows: f64) -> Svd1Params {
        let rows = (millions_of_rows * 1024.0 * 1024.0) as usize;
        let mut block_rows = 16384;
        while rows % block_rows != 0 {
            block_rows /= 2;
        }
        Svd1Params {
            rows,
            cols: 128,
            block_rows,
        }
    }
}

/// Build the SVD1 DAG.
pub fn svd1(p: Svd1Params) -> Dag {
    let nb = p.nb();
    let m = p.block_rows as f64;
    let n = p.cols as f64;
    let block_bytes = (p.block_rows * p.cols) as u64 * ELEM;
    let gram_bytes = (p.cols * p.cols) as u64 * ELEM;
    let mut b = DagBuilder::new(&format!("svd1_{}x{}", p.rows, p.cols));

    // Materialize each A block once (Dask persists input partitions as
    // tasks); the block feeds both the Gram stage and the U stage — the
    // large fan-out that task clustering keeps local.
    let loads: Vec<TaskId> = (0..nb)
        .map(|i| {
            let t = b.task(
                format!("load_{i}"),
                OpKind::Generic,
                (p.block_rows * p.cols) as f64,
                block_bytes,
            );
            b.with_input(t, block_bytes);
            t
        })
        .collect();
    let grams: Vec<TaskId> = (0..nb)
        .map(|i| {
            let t = b.task(
                format!("gram_{i}"),
                OpKind::Gram,
                2.0 * m * n * n,
                gram_bytes,
            );
            b.edge(loads[i], t);
            t
        })
        .collect();
    let total = reduction_tree(
        &mut b,
        grams,
        OpKind::BlockAdd,
        n * n,
        gram_bytes,
        "gsum",
    );
    // Jacobi eigensolve of the n×n Gram matrix → (S, V).
    let finish = b.task(
        "svd1_finish",
        OpKind::Svd1Finish,
        12.0 * (n * (n - 1.0) / 2.0) * 12.0 * n,
        gram_bytes + p.cols as u64 * ELEM,
    );
    b.edge(total, finish);
    // U reconstruction: U_i = A_i · (V S⁻¹) — large panels.
    for i in 0..nb {
        let u = b.task(
            format!("u_{i}"),
            OpKind::QApplyLeaf,
            2.0 * m * n * n,
            block_bytes,
        );
        b.edge(loads[i], u).edge(finish, u);
    }
    b.build().expect("SVD1 DAG is well-formed")
}

/// SVD2 parameters (square n×n, rank-k randomized).
#[derive(Debug, Clone, Copy)]
pub struct Svd2Params {
    pub n: usize,
    /// Target rank + oversampling (paper uses small k ≪ n).
    pub k: usize,
    /// Row-panel count (power of two for the TSQR stage).
    pub nb: usize,
}

impl Svd2Params {
    /// Paper sizes: 10k–256k square, k=128. Panel count scales so one
    /// row panel fits a 3 GB Lambda (the paper repartitions likewise).
    pub fn paper(n_thousands: usize) -> Svd2Params {
        let n = n_thousands * 1000;
        let panel_limit = 1.5e9; // bytes per row panel
        let need = ((n as f64) * (n as f64) * 4.0 / panel_limit).ceil() as usize;
        Svd2Params {
            n,
            k: 128,
            nb: need.max(64).next_power_of_two(),
        }
    }
}

/// Build the SVD2 (randomized range-finder) DAG.
pub fn svd2(p: Svd2Params) -> Dag {
    assert!(p.nb.is_power_of_two(), "panel count must be a power of two");
    let rows_per = p.n / p.nb;
    let m = rows_per as f64;
    let n = p.n as f64;
    let k = p.k as f64;
    let panel_bytes = (rows_per * p.n) as u64 * ELEM; // A_i row panel
    let y_bytes = (rows_per * p.k) as u64 * ELEM;
    let kk_bytes = (p.k * p.k) as u64 * ELEM;
    let bpart_bytes = (p.k * p.n) as u64 * ELEM; // k×n partials — LARGE
    let mut b = DagBuilder::new(&format!("svd2_{}k", p.n / 1000));

    // Stage 0: materialize each A row panel once; it feeds both the
    // sketch (Y_i) and the projection (B_i) — the paper's canonical
    // large-object fan-out that clustering + delayed I/O keep resident.
    let loads: Vec<TaskId> = (0..p.nb)
        .map(|i| {
            let t = b.task(
                format!("load_{i}"),
                OpKind::Generic,
                (rows_per * p.n) as f64,
                panel_bytes,
            );
            b.with_input(t, panel_bytes);
            t
        })
        .collect();

    // Stage 1: range sketch Y_i = A_i · Ω, with Ω column-split in two
    // (Dask splits the random matrix across chunks): each panel fans out
    // to two immediately-ready sketch products — the multi-target
    // fan-out that task clustering (alone) executes locally instead of
    // invoking executors and shipping the panel through the KVS.
    let y: Vec<TaskId> = (0..p.nb)
        .map(|i| {
            let halves: Vec<TaskId> = (0..2)
                .map(|j| {
                    let t = b.task(
                        format!("y_{i}_{j}"),
                        OpKind::GemmBlock,
                        m * n * k, // half of 2·m·n·k
                        y_bytes / 2,
                    );
                    b.edge(loads[i], t);
                    t
                })
                .collect();
            let cat = b.task(format!("y_{i}"), OpKind::Generic, m * k, y_bytes);
            b.edge(halves[0], cat).edge(halves[1], cat);
            cat
        })
        .collect();

    // Stage 2: TSQR over Y panels → per-panel Q (via merge halves).
    let qr: Vec<TaskId> = y
        .iter()
        .enumerate()
        .map(|(i, &yi)| {
            let t = b.task(
                format!("yqr_{i}"),
                OpKind::QrFactor,
                4.0 * m * k * k,
                kk_bytes,
            );
            b.edge(yi, t);
            t
        })
        .collect();
    let _r_root = reduction_tree(
        &mut b,
        qr.clone(),
        OpKind::QrMerge,
        4.0 * (2.0 * k) * k * k,
        kk_bytes,
        "ymerge",
    );
    // Q panels (approximation: derived from Y + local R, large objects).
    let q: Vec<TaskId> = (0..p.nb)
        .map(|i| {
            let t = b.task(
                format!("q_{i}"),
                OpKind::QApplyLeaf,
                2.0 * m * k * k,
                y_bytes,
            );
            b.edge(y[i], t).edge(qr[i], t);
            t
        })
        .collect();

    // Stage 3: B partials = Q_iᵀ · A_i (k×n, large), tree-summed.
    let bparts: Vec<TaskId> = (0..p.nb)
        .map(|i| {
            let t = b.task(
                format!("b_{i}"),
                OpKind::GemmBlock,
                2.0 * m * k * n,
                bpart_bytes,
            );
            b.edge(loads[i], t).edge(q[i], t);
            t
        })
        .collect();
    let b_total = reduction_tree(
        &mut b,
        bparts,
        OpKind::BlockAdd,
        k * n,
        bpart_bytes,
        "bsum",
    );

    // Stage 4: small SVD of B (via k×k Gram + Jacobi).
    let small = b.task(
        "svd2_small",
        OpKind::Svd1Finish,
        2.0 * k * k * n + 12.0 * (k * (k - 1.0) / 2.0) * 12.0 * k,
        kk_bytes,
    );
    b.edge(b_total, small);

    // Stage 5: U_i = Q_i · Ũ.
    for i in 0..p.nb {
        let u = b.task(
            format!("u_{i}"),
            OpKind::QApplyLeaf,
            2.0 * m * k * k,
            y_bytes,
        );
        b.edge(q[i], u).edge(small, u);
    }
    b.build().expect("SVD2 DAG is well-formed")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn svd1_counts() {
        let p = Svd1Params {
            rows: 8192,
            cols: 128,
            block_rows: 1024,
        };
        let d = svd1(p);
        // 8 loads + 8 grams + 7 sums + 1 finish + 8 u = 32
        assert_eq!(d.len(), 32);
        assert_eq!(d.sinks().len(), 8);
        assert_eq!(d.leaves().len(), 8); // the loads
    }

    #[test]
    fn svd1_u_fanout_from_finish() {
        let p = Svd1Params {
            rows: 4096,
            cols: 128,
            block_rows: 1024,
        };
        let d = svd1(p);
        let finish = (0..d.len() as u32)
            .find(|&t| d.task_name(t) == "svd1_finish")
            .unwrap();
        assert_eq!(d.children(finish).len(), 4);
    }

    #[test]
    fn svd2_stage_structure() {
        let p = Svd2Params {
            n: 4096,
            k: 128,
            nb: 4,
        };
        let d = svd2(p);
        // 4 loads + 8 y-halves + 4 y-concats + 4 yqr + 3 merges + 4 q
        //  + 4 b + 3 bsum + 1 small + 4 u = 39
        assert_eq!(d.len(), 39);
        // sinks: the 4 U panels + the root R factor of the Y-TSQR
        assert_eq!(d.sinks().len(), 5);
    }

    #[test]
    fn svd2_b_partials_are_large() {
        let p = Svd2Params::paper(50);
        let d = svd2(p);
        let b0 = (0..d.len() as u32).find(|&t| d.task_name(t) == "b_0").unwrap();
        let bpart = d.task(b0);
        // 128 × 50 000 × 4 B ≈ 25.6 MB
        assert!(bpart.out_bytes > 20_000_000);
    }

    #[test]
    fn paper_svd2_is_64_panels() {
        let p = Svd2Params::paper(50);
        assert_eq!(p.nb, 64);
        assert_eq!(p.n, 50_000);
    }
}
