//! Scaling microbenchmarks (§4.4, Figs. 2 and 21): N chains of fixed-
//! duration tasks.
//!
//! * **Strong scaling** — 10 000 tasks over N executors: N chains of
//!   `10 000 / N` tasks.
//! * **Weak scaling** — 10 tasks per executor: N chains of 10.
//! * **Serverless scaling** — N tasks on N executors: N chains of 1.
//!
//! In Wukong each chain is one static schedule executed locally by one
//! Lambda; in (Num)PyWren every task is a queue round-trip.

use crate::dag::{Dag, DagBuilder, OpKind};
use crate::sim::Time;

/// Microbenchmark parameters.
#[derive(Debug, Clone, Copy)]
pub struct MicroParams {
    pub n_chains: usize,
    pub chain_len: usize,
    /// Per-task duration (0 = no-op).
    pub task_dur: Time,
}

/// Build `n_chains` independent chains of `chain_len` tasks.
pub fn chains(p: MicroParams) -> Dag {
    assert!(p.n_chains >= 1 && p.chain_len >= 1);
    let mut b = DagBuilder::new(&format!(
        "micro_{}x{}",
        p.n_chains, p.chain_len
    ));
    for c in 0..p.n_chains {
        let mut prev = None;
        for i in 0..p.chain_len {
            let op = if p.task_dur == 0 {
                OpKind::Noop
            } else {
                OpKind::Sleep
            };
            let t = b.task(format!("c{c}_t{i}"), op, 0.0, 8);
            b.with_duration(t, p.task_dur);
            if let Some(prev) = prev {
                b.edge(prev, t);
            }
            prev = Some(t);
        }
    }
    b.build().expect("microbenchmark DAG is well-formed")
}

/// Strong scaling: `total_tasks` spread over `n_exec` chains.
pub fn strong(total_tasks: usize, n_exec: usize, task_dur: Time) -> Dag {
    chains(MicroParams {
        n_chains: n_exec,
        chain_len: (total_tasks / n_exec).max(1),
        task_dur,
    })
}

/// Weak scaling: `per_exec` tasks on each of `n_exec` executors.
pub fn weak(n_exec: usize, per_exec: usize, task_dur: Time) -> Dag {
    chains(MicroParams {
        n_chains: n_exec,
        chain_len: per_exec,
        task_dur,
    })
}

/// Serverless scaling: N tasks on N executors.
pub fn serverless(n: usize, task_dur: Time) -> Dag {
    chains(MicroParams {
        n_chains: n,
        chain_len: 1,
        task_dur,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::secs;

    #[test]
    fn chain_structure() {
        let d = chains(MicroParams {
            n_chains: 4,
            chain_len: 3,
            task_dur: secs(0.1),
        });
        assert_eq!(d.len(), 12);
        assert_eq!(d.leaves().len(), 4);
        assert_eq!(d.sinks().len(), 4);
        assert_eq!(d.n_edges(), 8);
    }

    #[test]
    fn strong_divides_tasks() {
        let d = strong(10_000, 100, 0);
        assert_eq!(d.len(), 10_000);
        assert_eq!(d.leaves().len(), 100);
    }

    #[test]
    fn serverless_is_all_leaves() {
        let d = serverless(50, 0);
        assert_eq!(d.len(), 50);
        assert_eq!(d.leaves().len(), 50);
        assert_eq!(d.n_edges(), 0);
    }

    #[test]
    fn noop_tasks_have_zero_duration() {
        let d = serverless(3, 0);
        assert!(d.tasks().iter().all(|t| t.dur_override == Some(0)));
        assert!(d.tasks().iter().all(|t| t.op == OpKind::Noop));
    }
}
