//! Tree Reduction (TR) — the paper's task-granularity microbenchmark
//! (§4.1, Figs. 7–9).
//!
//! Sums N elements (or N chunks, for the real engine) pairwise over
//! log(N) passes. The paper's Fig. 9 variant injects a fixed per-task
//! delay (0–500 ms) to emulate heavier tasks.

use crate::dag::{Dag, DagBuilder, OpKind, TaskId};
use crate::sim::Time;

use super::{reduction_tree, ELEM};

/// TR parameters.
#[derive(Debug, Clone, Copy)]
pub struct TrParams {
    /// Array length; the first pass has `n/2` add tasks. Must be ≥ 2.
    pub n: usize,
    /// Elements per chunk (1 = paper's scalar TR; 8192 = real-engine TR).
    pub chunk: usize,
    /// Injected per-task delay (Fig. 9's 0–500 ms knob).
    pub delay: Option<Time>,
}

impl Default for TrParams {
    fn default() -> Self {
        TrParams {
            n: 1024,
            chunk: 1,
            delay: None,
        }
    }
}

/// Build the TR DAG: `n/2` leaf adds, pairwise-merged to a single root.
pub fn dag(p: TrParams) -> Dag {
    assert!(p.n >= 2, "TR needs at least 2 elements");
    let chunk_bytes = p.chunk as u64 * ELEM;
    let mut b = DagBuilder::new(&format!("tr_{}x{}", p.n, p.chunk));
    let n_leaves = p.n / 2;
    let leaves: Vec<TaskId> = (0..n_leaves)
        .map(|i| {
            let t = b.task(
                format!("add_l0_{i}"),
                OpKind::TrAdd,
                p.chunk as f64,
                chunk_bytes,
            );
            // Each leaf reads its two input chunks from storage.
            b.with_input(t, 2 * chunk_bytes);
            if let Some(d) = p.delay {
                b.with_duration(t, d);
            }
            t
        })
        .collect();
    let root = reduction_tree(
        &mut b,
        leaves,
        OpKind::TrAdd,
        p.chunk as f64,
        chunk_bytes,
        "add",
    );
    if let Some(d) = p.delay {
        // Internal nodes carry the injected delay too.
        let dag_len = root as usize + 1;
        for t in n_leaves..dag_len {
            b.with_duration(t as u32, d);
        }
    }
    // Final scalar collapse (real-engine TR ends with a (1,) sum).
    if p.chunk > 1 {
        let fin = b.task("tr_root", OpKind::TrRoot, p.chunk as f64, ELEM);
        b.edge(root, fin);
        if let Some(d) = p.delay {
            b.with_duration(fin, d);
        }
    }
    b.build().expect("TR DAG is well-formed")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::secs;

    #[test]
    fn paper_tr_1024_has_512_leaf_adds_and_1023_tasks() {
        let dag = dag(TrParams::default());
        assert_eq!(dag.leaves().len(), 512);
        assert_eq!(dag.len(), 1023); // N-1 operations
        assert_eq!(dag.sinks().len(), 1);
    }

    #[test]
    fn delay_is_applied_to_all_tasks() {
        let d = dag(TrParams {
            n: 16,
            chunk: 1,
            delay: Some(secs(0.25)),
        });
        assert!(d.tasks().iter().all(|t| t.dur_override == Some(secs(0.25))));
    }

    #[test]
    fn chunked_tr_appends_root_sum() {
        let d = dag(TrParams {
            n: 8,
            chunk: 8192,
            delay: None,
        });
        assert_eq!(d.sinks().len(), 1);
        let sink = d.task(d.sinks()[0]);
        assert_eq!(sink.op, OpKind::TrRoot);
        assert_eq!(sink.out_bytes, ELEM);
    }

    #[test]
    fn leaves_read_external_input() {
        let d = dag(TrParams::default());
        for &l in d.leaves() {
            assert_eq!(d.task(l).input_bytes, 2 * ELEM);
        }
    }
}
