//! TSQR — tall-skinny QR factorization (§4.1, Figs. 4, 14, 16, 20).
//!
//! Communication-avoiding QR: leaf blocks are QR-factored locally, the
//! small R factors merge pairwise up a binary tree. A leaf factorization
//! *materializes* its (large) implicit Q alongside R — numpywren's
//! stateless executors write the whole bundle to storage even though only
//! the 64 KB R travels up the tree, which is the source of the paper's
//! four-orders-of-magnitude write amplification. In Wukong the bundle
//! stays in the executor and only the extracted R moves.
//!
//! With `with_q = true` the DAG additionally reconstructs the explicit Q
//! factor (merge Q-halves propagated back down to the leaves) — the
//! variant the real engine verifies numerically (Q·R = A, QᵀQ = I).

use crate::dag::{Dag, DagBuilder, OpKind, TaskId};

use super::ELEM;

/// TSQR parameters.
#[derive(Debug, Clone, Copy)]
pub struct TsqrParams {
    /// Total rows (elements).
    pub rows: usize,
    /// Columns (the paper fixes 128).
    pub cols: usize,
    /// Rows per leaf block; `rows / block_rows` must be a power of two.
    pub block_rows: usize,
    /// Reconstruct the explicit Q factor (downward pass).
    pub with_q: bool,
}

impl TsqrParams {
    pub fn nb(&self) -> usize {
        assert!(self.rows % self.block_rows == 0);
        let nb = self.rows / self.block_rows;
        assert!(nb.is_power_of_two(), "leaf count must be a power of two");
        nb
    }

    /// Paper problem sizes: `millions_of_rows` M × 128, 4096-row blocks
    /// (row count rounded to the nearest power-of-two leaf count),
    /// R-factor output (numpywren's TSQR benchmark shape).
    pub fn paper(millions_of_rows: f64) -> TsqrParams {
        let want = millions_of_rows * 1024.0 * 1024.0 / 4096.0;
        // nearest power of two (next_power_of_two would round 16.7M rows
        // up to 33.5M)
        let nb = (1usize << (want.log2().round() as u32)).max(1);
        TsqrParams {
            rows: nb * 4096,
            cols: 128,
            block_rows: 4096,
            with_q: false,
        }
    }
}

/// Build the TSQR DAG.
pub fn dag(p: TsqrParams) -> Dag {
    let nb = p.nb();
    let c = p.cols as u64;
    let r_bytes = c * c * ELEM;
    let q_leaf_bytes = (p.block_rows as u64) * c * ELEM;
    let q_half_bytes = c * c * ELEM; // one half of the (2c × c) merge Q
    let qr_bundle_bytes = q_leaf_bytes + r_bytes; // [Q, R] of a leaf
    let merge_bundle_bytes = 2 * c * c * ELEM + r_bytes; // [Q (2c×c), R]
    let block_bytes = q_leaf_bytes; // input block same shape as Q
    let m = p.block_rows as f64;
    let n = p.cols as f64;
    let qr_flops = 4.0 * m * n * n;
    let merge_flops = 4.0 * (2.0 * n) * n * n;
    let apply_flops = 2.0 * m * n * n;
    let half_flops = 2.0 * n * n * n;

    let mut b = DagBuilder::new(&format!(
        "tsqr_{}x{}_b{}{}",
        p.rows,
        p.cols,
        p.block_rows,
        if p.with_q { "_q" } else { "" }
    ));

    // Leaf factorizations: the task's object is the full [Q, R] bundle;
    // a trivial extraction task peels off the small R for the merge tree.
    let qr: Vec<TaskId> = (0..nb)
        .map(|i| {
            let t = b.task(
                format!("qr_{i}"),
                OpKind::QrFactor,
                qr_flops,
                qr_bundle_bytes,
            );
            b.with_input(t, block_bytes);
            t
        })
        .collect();
    let r_of = |b: &mut DagBuilder, src: TaskId, name: String| {
        let t = b.task(name, OpKind::RExtract, 0.0, r_bytes);
        b.edge(src, t);
        t
    };
    let rs: Vec<TaskId> = qr
        .iter()
        .enumerate()
        .map(|(i, &q)| r_of(&mut b, q, format!("r_{i}")))
        .collect();

    // Q materialization per leaf (explicit-Q variant only).
    let q: Vec<TaskId> = if p.with_q {
        (0..nb)
            .map(|i| {
                let t = b.task(
                    format!("q_{i}"),
                    OpKind::QApplyLeaf,
                    0.0, // extraction: already computed by qr_i
                    q_leaf_bytes,
                );
                b.edge(qr[i], t);
                t
            })
            .collect()
    } else {
        Vec::new()
    };

    // Merge tree over extracted R factors, bottom-up; remember each
    // level's Q-half tasks for the downward reconstruction.
    let mut level_nodes = rs.clone();
    let mut halves_by_level: Vec<Vec<[TaskId; 2]>> = Vec::new();
    let mut level = 0;
    while level_nodes.len() > 1 {
        let mut next = Vec::new();
        let mut halves = Vec::new();
        for (pair_idx, pair) in level_nodes.chunks(2).enumerate() {
            let merge = b.task(
                format!("merge_l{level}_{pair_idx}"),
                OpKind::QrMerge,
                merge_flops,
                if p.with_q { merge_bundle_bytes } else { r_bytes },
            );
            b.edge(pair[0], merge).edge(pair[1], merge);
            if p.with_q {
                let hs = [0, 1].map(|half| {
                    let h = b.task(
                        format!("half_l{level}_{pair_idx}_{half}"),
                        OpKind::QApplyHalf,
                        0.0,
                        q_half_bytes,
                    );
                    b.edge(merge, h);
                    h
                });
                halves.push(hs);
            }
            // Next level consumes the extracted R, not the bundle.
            let r_next = if level_nodes.len() > 2 || p.with_q {
                r_of(&mut b, merge, format!("r_l{level}_{pair_idx}"))
            } else {
                merge // root merge of the R-only variant is the sink
            };
            next.push(r_next);
        }
        if p.with_q {
            halves_by_level.push(halves);
        }
        level_nodes = next;
        level += 1;
    }

    if p.with_q {
        // Downward pass: each tree node's path product = parent product ×
        // its merge half — one `prod` task per node (not per leaf).
        let n_levels = halves_by_level.len();
        let mut down: Vec<Option<TaskId>> = vec![None];
        for level in (0..n_levels).rev() {
            let halves = &halves_by_level[level];
            let mut next_down = vec![None; halves.len() * 2];
            for (pair_idx, hs) in halves.iter().enumerate() {
                for half in 0..2 {
                    let node = pair_idx * 2 + half;
                    next_down[node] = Some(match down[pair_idx] {
                        None => hs[half],
                        Some(parent_prod) => {
                            let prod = b.task(
                                format!("prod_l{level}_{node}"),
                                OpKind::QApplyHalf,
                                half_flops,
                                q_half_bytes,
                            );
                            b.edge(parent_prod, prod).edge(hs[half], prod);
                            prod
                        }
                    });
                }
            }
            down = next_down;
        }
        let path_prod: Vec<Option<TaskId>> =
            if n_levels == 0 { vec![None; nb] } else { down };

        // Final Q panels: Q_global_i = Q_i · (path product of halves).
        for i in 0..nb {
            let apply = b.task(
                format!("applyq_{i}"),
                OpKind::QApplyLeaf,
                apply_flops,
                q_leaf_bytes,
            );
            b.edge(q[i], apply);
            if let Some(pp) = path_prod[i] {
                b.edge(pp, apply);
            }
        }
    }

    b.build().expect("TSQR DAG is well-formed")
}

/// Logical input/output bytes: input matrix; output R (plus Q if
/// reconstructed).
pub fn io_bytes(p: TsqrParams) -> (u64, u64) {
    let a = (p.rows as u64) * (p.cols as u64) * ELEM;
    let r = (p.cols as u64) * (p.cols as u64) * ELEM;
    (a, if p.with_q { a + r } else { r })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(nb: usize, with_q: bool) -> TsqrParams {
        TsqrParams {
            rows: 1024 * nb,
            cols: 128,
            block_rows: 1024,
            with_q,
        }
    }

    #[test]
    fn r_only_two_leaf_tree() {
        let d = dag(params(2, false));
        // 2 qr + 2 r + 1 merge = 5; root merge is the sink
        assert_eq!(d.len(), 5);
        assert_eq!(d.leaves().len(), 2);
        assert_eq!(d.sinks().len(), 1);
        assert_eq!(d.task(d.sinks()[0]).op, OpKind::QrMerge);
    }

    #[test]
    fn with_q_two_leaf_tree() {
        let d = dag(params(2, true));
        // 2 qr + 2 r + 2 q + 1 merge + 1 r_l0 + 2 half + 2 applyq = 12
        assert_eq!(d.len(), 12);
        assert_eq!(d.sinks().len(), 3); // 2 Q panels + root R
    }

    #[test]
    fn with_q_four_leaf_counts() {
        let d = dag(params(4, true));
        // 4 qr + 4 r + 4 q + 3 merges + 3 r_lx + 6 halves + 4 prods
        //  + 4 applyq = 32
        assert_eq!(d.len(), 32);
        assert_eq!(d.sinks().len(), 5);
    }

    #[test]
    fn every_apply_depends_on_path_products() {
        let d = dag(params(8, true));
        for t in 0..d.len() as u32 {
            if d.task_name(t).starts_with("applyq_") {
                assert_eq!(d.parents(t).len(), 2, "{}", d.task_name(t));
            }
        }
    }

    #[test]
    fn qr_bundles_dominate_bytes_in_r_only_mode() {
        // The stateless-writes story: leaf [Q,R] bundles are ~97% of all
        // task output bytes, but only R objects are *needed* downstream.
        let d = dag(params(256, false));
        let bundle_bytes: u64 = d
            .tasks()
            .iter()
            .filter(|t| t.op == OpKind::QrFactor)
            .map(|t| t.out_bytes)
            .sum();
        assert!(bundle_bytes as f64 / d.total_output_bytes() as f64 > 0.7);
    }

    #[test]
    fn paper_params_are_power_of_two() {
        let p = TsqrParams::paper(4.0);
        assert!(p.nb().is_power_of_two());
        assert_eq!(p.cols, 128);
        assert_eq!(p.rows % p.block_rows, 0);
        assert!(!p.with_q);
        let p2 = TsqrParams::paper(16.7);
        assert!(p2.nb().is_power_of_two());
    }
}
