//! Workload DAG generators for the paper's five applications (§4.1) plus
//! the scaling microbenchmarks (§4.4).
//!
//! Every generator is a pure function from problem parameters to a
//! [`Dag`](crate::dag::Dag) with exact per-task byte sizes and flops, so
//! the same graph drives Wukong, numpywren and Dask engines (the paper's
//! "exact same input DAG" methodology).

pub mod dynamic;
pub mod gemm;
pub mod micro;
pub mod svc;
pub mod svd;
pub mod tr;
pub mod tsqr;

use crate::dag::{DagBuilder, OpKind, TaskId};

/// Bytes per matrix element (f32, matching the Pallas kernels).
pub const ELEM: u64 = 4;

/// Build a binary reduction tree over `items`, returning the root task.
/// Each internal node is `op` with `flops` work and `out_bytes` output.
pub(crate) fn reduction_tree(
    b: &mut DagBuilder,
    mut items: Vec<TaskId>,
    op: OpKind,
    flops: f64,
    out_bytes: u64,
    label: &str,
) -> TaskId {
    assert!(!items.is_empty());
    let mut level = 0;
    while items.len() > 1 {
        let mut next = Vec::with_capacity(items.len().div_ceil(2));
        for (i, pair) in items.chunks(2).enumerate() {
            if pair.len() == 1 {
                next.push(pair[0]); // odd one out rides up a level
                continue;
            }
            let t = b.task(format!("{label}_l{level}_{i}"), op, flops, out_bytes);
            b.edge(pair[0], t).edge(pair[1], t);
            next.push(t);
        }
        items = next;
        level += 1;
    }
    items[0]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::OpKind;

    #[test]
    fn reduction_tree_shape() {
        let mut b = DagBuilder::new("t");
        let leaves: Vec<_> = (0..8)
            .map(|i| b.task(format!("leaf{i}"), OpKind::Noop, 0.0, 8))
            .collect();
        let root = reduction_tree(&mut b, leaves, OpKind::BlockAdd, 1.0, 8, "r");
        let dag = b.build().unwrap();
        // 8 leaves + 7 internal nodes
        assert_eq!(dag.len(), 15);
        assert_eq!(dag.sinks().to_vec(), vec![root]);
        assert_eq!(dag.leaves().len(), 8);
    }

    #[test]
    fn reduction_tree_handles_odd_counts() {
        let mut b = DagBuilder::new("t");
        let leaves: Vec<_> = (0..5)
            .map(|i| b.task(format!("leaf{i}"), OpKind::Noop, 0.0, 8))
            .collect();
        let root = reduction_tree(&mut b, leaves, OpKind::BlockAdd, 1.0, 8, "r");
        let dag = b.build().unwrap();
        assert_eq!(dag.len(), 9); // 5 + 4 internal
        assert_eq!(dag.sinks().to_vec(), vec![root]);
    }
}
