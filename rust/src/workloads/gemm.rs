//! Blocked GEMM — C = A·B with square blocking (§4.1, Figs. 13, 15, 19).
//!
//! For an n×n problem with b×b blocks (nb = n/b per side): nb³ leaf
//! multiply tasks (each reading A_ik and B_kj partitions), then a binary
//! add-tree over the K partial products for every (i, j) output block.
//! GEMM is the paper's "hard for serverless" case: many large objects
//! move before compute can start.

use crate::dag::{Dag, DagBuilder, OpKind, TaskId};

use super::{reduction_tree, ELEM};

/// GEMM parameters.
#[derive(Debug, Clone, Copy)]
pub struct GemmParams {
    /// Matrix side (elements).
    pub n: usize,
    /// Block side (elements); must divide `n`.
    pub block: usize,
}

impl GemmParams {
    pub fn nb(&self) -> usize {
        assert!(
            self.block > 0 && self.n % self.block == 0,
            "block must divide n"
        );
        self.n / self.block
    }

    /// Paper problem sizes: 5k..25k with 5k blocks.
    pub fn paper(n_thousands: usize) -> GemmParams {
        GemmParams {
            n: n_thousands * 1000,
            block: 5000,
        }
    }
}

/// Build the blocked-GEMM DAG.
pub fn dag(p: GemmParams) -> Dag {
    let nb = p.nb();
    let bb = (p.block * p.block) as u64 * ELEM; // block bytes
    let mul_flops = 2.0 * (p.block as f64).powi(3);
    let add_flops = (p.block * p.block) as f64;
    let mut b = DagBuilder::new(&format!("gemm_{}x{}_b{}", p.n, p.n, p.block));
    for i in 0..nb {
        for j in 0..nb {
            let partials: Vec<TaskId> = (0..nb)
                .map(|k| {
                    let t = b.task(
                        format!("mul_{i}_{j}_{k}"),
                        OpKind::GemmBlock,
                        mul_flops,
                        bb,
                    );
                    // reads A[i,k] and B[k,j] input partitions
                    b.with_input(t, 2 * bb);
                    t
                })
                .collect();
            reduction_tree(
                &mut b,
                partials,
                OpKind::BlockAdd,
                add_flops,
                bb,
                &format!("acc_{i}_{j}"),
            );
        }
    }
    b.build().expect("GEMM DAG is well-formed")
}

/// Exact logical input/output sizes (for the amplification figures).
pub fn io_bytes(p: GemmParams) -> (u64, u64) {
    let n2 = (p.n as u64) * (p.n as u64) * ELEM;
    (2 * n2, n2) // read A + B; write C
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_counts() {
        let p = GemmParams { n: 4, block: 1 }; // nb = 4
        let d = dag(p);
        // 4*4 output blocks × (4 muls + 3 adds) = 112
        assert_eq!(d.len(), 16 * 7);
        assert_eq!(d.leaves().len(), 64);
        assert_eq!(d.sinks().len(), 16);
    }

    #[test]
    fn single_block_degenerates_to_one_task() {
        let d = dag(GemmParams { n: 8, block: 8 });
        assert_eq!(d.len(), 1);
    }

    #[test]
    fn paper_25k() {
        let p = GemmParams::paper(25);
        assert_eq!(p.nb(), 5);
        let d = dag(p);
        // 25 output blocks × (5 muls + 4 adds)
        assert_eq!(d.len(), 25 * 9);
    }

    #[test]
    fn io_accounts_both_inputs() {
        let (i, o) = io_bytes(GemmParams { n: 1000, block: 500 });
        assert_eq!(i, 2 * 1000 * 1000 * 4);
        assert_eq!(o, 1000 * 1000 * 4);
    }

    #[test]
    fn block_must_divide() {
        let p = GemmParams { n: 10, block: 3 };
        assert!(std::panic::catch_unwind(|| p.nb()).is_err());
    }
}
