//! Differential conformance checks: the cross-engine invariants every
//! [`crate::engine::Engine`] run must satisfy on every corpus DAG.
//!
//! * **completion** — every task ran; the job did not deadlock;
//! * **exactly-once** — per-task execution counts are all exactly 1
//!   (§3.3's fan-in ownership claim);
//! * **determinism** — the same `(dag, config, seed)` yields identical
//!   [`crate::metrics::RunMetrics`] (and DES event counts);
//! * **locality ordering** — Wukong's metered KVS traffic never exceeds
//!   the stateless bound (what a numpywren-style engine must move), the
//!   paper's Figs. 3–4 claim;
//! * **stateless model** — a stateless engine's measured bytes equal the
//!   closed form exactly (byte-exact metering, not modeling);
//! * **fault contract** (§3.6) — under any fault plan, every task is
//!   either completed or reported-failed (never silently lost), attempts
//!   never exceed `1 + max_retries`, completed tasks executed
//!   effectively-once, and `p_fail = 0` runs are bit-identical to the
//!   fault-free baseline;
//! * **dynamic equivalence** — a run expanding a spawn plan at runtime
//!   is byte-identical to the statically pre-expanded equivalent DAG run
//!   plan-free (metrics, event counts, calendar high-water mark);
//! * **crash recovery** — a run with mid-run shard crashes is
//!   byte-identical to the uninterrupted run in every data-plane metric
//!   (task outcomes, KVS/WAL byte meters, event counts, makespan); only
//!   the recovery meters (`recoveries`, `replayed_ops`, `stall_s`) may
//!   differ, and they must be internally consistent with the crash plan
//!   and the configured recovery costs.

use crate::config::StorageConfig;
use crate::dag::Dag;
use crate::engine::EngineReport;
use crate::metrics::TaskOutcome;
use crate::platform::faults::{FaultPlan, ShardCrashPlan};

/// The closed-form KVS traffic of a fully-stateless engine on `dag`:
/// every task writes its output once; every dependency edge reads the
/// parent's full output; every external input partition is read once.
/// Returns `(bytes_read, bytes_written)`.
pub fn stateless_bytes(dag: &Dag) -> (u64, u64) {
    let mut read = 0u64;
    let mut written = 0u64;
    for (i, t) in dag.tasks().iter().enumerate() {
        written += t.out_bytes;
        read += t.input_bytes;
        for &p in dag.parents(i as u32) {
            read += dag.task(p).out_bytes;
        }
    }
    (read, written)
}

/// Every task executed; count matches the DAG size.
pub fn check_completion(dag: &Dag, rep: &EngineReport) -> Result<(), String> {
    if rep.metrics.tasks_executed as usize != dag.len() {
        return Err(format!(
            "[{}] completion: {}/{} tasks executed",
            rep.engine,
            rep.metrics.tasks_executed,
            dag.len()
        ));
    }
    Ok(())
}

/// Per-task execution counts are present and all exactly 1.
pub fn check_exactly_once(dag: &Dag, rep: &EngineReport) -> Result<(), String> {
    let counts = &rep.metrics.per_task_exec;
    if counts.len() != dag.len() {
        return Err(format!(
            "[{}] exactly-once: engine reported {} per-task counts for a \
             {}-task DAG",
            rep.engine,
            counts.len(),
            dag.len()
        ));
    }
    for (t, &c) in counts.iter().enumerate() {
        if c != 1 {
            return Err(format!(
                "[{}] exactly-once: task {t} ({}) executed {c} times",
                rep.engine,
                dag.task_name(t as u32)
            ));
        }
    }
    Ok(())
}

/// Two runs with the same seed must be byte-identical.
pub fn check_determinism(a: &EngineReport, b: &EngineReport) -> Result<(), String> {
    if a.sim_events != b.sim_events {
        return Err(format!(
            "[{}] determinism: event counts differ ({:?} vs {:?})",
            a.engine, a.sim_events, b.sim_events
        ));
    }
    if a.metrics != b.metrics {
        let what = if a.metrics.makespan_s != b.metrics.makespan_s {
            format!(
                "makespan {} vs {}",
                a.metrics.makespan_s, b.metrics.makespan_s
            )
        } else if a.metrics.kvs != b.metrics.kvs {
            format!("kvs {:?} vs {:?}", a.metrics.kvs, b.metrics.kvs)
        } else {
            "metrics structs differ".to_string()
        };
        return Err(format!("[{}] determinism: {what}", a.engine));
    }
    Ok(())
}

/// Locality ordering: a locality-aware engine's metered KVS bytes never
/// exceed the stateless closed form on the same DAG.
pub fn check_locality(dag: &Dag, rep: &EngineReport) -> Result<(), String> {
    let (sl_read, sl_written) = stateless_bytes(dag);
    if rep.metrics.kvs.bytes_written > sl_written {
        return Err(format!(
            "[{}] locality: wrote {} B > stateless bound {} B",
            rep.engine, rep.metrics.kvs.bytes_written, sl_written
        ));
    }
    if rep.metrics.kvs.bytes_read > sl_read {
        return Err(format!(
            "[{}] locality: read {} B > stateless bound {} B",
            rep.engine, rep.metrics.kvs.bytes_read, sl_read
        ));
    }
    Ok(())
}

/// A stateless engine's measured traffic must equal the closed form
/// exactly (locks in byte-exact metering).
pub fn check_stateless_model(dag: &Dag, rep: &EngineReport) -> Result<(), String> {
    let (sl_read, sl_written) = stateless_bytes(dag);
    if rep.metrics.kvs.bytes_written != sl_written
        || rep.metrics.kvs.bytes_read != sl_read
    {
        return Err(format!(
            "[{}] stateless-model: measured read/write {}/{} B != closed \
             form {}/{} B",
            rep.engine,
            rep.metrics.kvs.bytes_read,
            rep.metrics.kvs.bytes_written,
            sl_read,
            sl_written
        ));
    }
    Ok(())
}

/// The §3.6 retry contract, checked structurally on one report:
///
/// * the per-task attempt/outcome/exec vectors cover the DAG;
/// * `attempts ≤ 1 + max_retries` for every task;
/// * completed ⊕ reported-failed partitions the DAG totally — a
///   completed task executed exactly once after ≥ 1 attempt, a failed
///   task never executed, and the aggregate counters agree with the
///   per-task vectors (no task silently lost);
/// * a failed job carries at least one §3.6 failure report
///   (`failed_executors > 0`).
pub fn check_fault_contract(
    dag: &Dag,
    rep: &EngineReport,
    plan: FaultPlan,
) -> Result<(), String> {
    let m = &rep.metrics;
    let n = dag.len();
    if m.per_task_outcome.len() != n
        || m.per_task_attempts.len() != n
        || m.per_task_exec.len() != n
    {
        return Err(format!(
            "[{}] fault-contract: per-task vectors {}/{}/{} for a {n}-task \
             DAG",
            rep.engine,
            m.per_task_exec.len(),
            m.per_task_attempts.len(),
            m.per_task_outcome.len()
        ));
    }
    let max_attempts = plan.max_attempts();
    let mut n_failed = 0u64;
    for t in 0..n {
        let attempts = m.per_task_attempts[t];
        let execs = m.per_task_exec[t];
        if attempts > max_attempts {
            return Err(format!(
                "[{}] fault-contract: task {t} attempted {attempts} times > \
                 1 + max_retries = {max_attempts}",
                rep.engine
            ));
        }
        match m.per_task_outcome[t] {
            TaskOutcome::Completed => {
                if execs != 1 {
                    return Err(format!(
                        "[{}] fault-contract: completed task {t} executed \
                         {execs} times (effectively-once violated)",
                        rep.engine
                    ));
                }
                if attempts == 0 {
                    return Err(format!(
                        "[{}] fault-contract: completed task {t} reports \
                         zero attempts",
                        rep.engine
                    ));
                }
            }
            TaskOutcome::Failed => {
                n_failed += 1;
                if execs != 0 {
                    return Err(format!(
                        "[{}] fault-contract: reported-failed task {t} \
                         executed {execs} times",
                        rep.engine
                    ));
                }
            }
        }
    }
    if m.failed_tasks != n_failed {
        return Err(format!(
            "[{}] fault-contract: failed_tasks={} but {} per-task outcomes \
             are Failed",
            rep.engine, m.failed_tasks, n_failed
        ));
    }
    if m.tasks_executed + m.failed_tasks != n as u64 {
        return Err(format!(
            "[{}] fault-contract: {} executed + {} failed != {n} tasks \
             (silent loss)",
            rep.engine, m.tasks_executed, m.failed_tasks
        ));
    }
    if m.failed_tasks > 0 && m.failed_executors == 0 {
        return Err(format!(
            "[{}] fault-contract: {} tasks failed without a §3.6 failure \
             report",
            rep.engine, m.failed_tasks
        ));
    }
    Ok(())
}

/// A `p_fail = 0` fault-plan run must be bit-identical to the plain
/// fault-free run — enabling the fault machinery without faults cannot
/// perturb the event stream (the dedicated-fault-RNG regression).
pub fn check_fault_free_baseline(
    reference: &EngineReport,
    rep: &EngineReport,
) -> Result<(), String> {
    if reference.sim_events != rep.sim_events {
        return Err(format!(
            "[{}] fault-free-baseline: p_fail=0 event count {:?} != \
             fault-free {:?}",
            rep.engine, rep.sim_events, reference.sim_events
        ));
    }
    if reference.metrics != rep.metrics {
        return Err(format!(
            "[{}] fault-free-baseline: p_fail=0 metrics differ from the \
             fault-free run",
            rep.engine
        ));
    }
    Ok(())
}

/// The dynamic-DAG differential gate: a run that expands a spawn plan
/// *at runtime* must be byte-identical — metrics, DES event counts,
/// calendar high-water mark — to running the statically pre-expanded
/// equivalent DAG ([`crate::dag::pre_expand`]) plan-free. Runtime
/// spawning is an implementation detail of *when* tasks enter the
/// graph, never of what the execution does.
pub fn check_dynamic_equivalence(
    dynamic: &EngineReport,
    static_rep: &EngineReport,
) -> Result<(), String> {
    if dynamic.sim_events != static_rep.sim_events {
        return Err(format!(
            "[{}] dynamic-equivalence: dynamic event count {:?} != \
             pre-expanded {:?}",
            dynamic.engine, dynamic.sim_events, static_rep.sim_events
        ));
    }
    if dynamic.peak_pending != static_rep.peak_pending {
        return Err(format!(
            "[{}] dynamic-equivalence: dynamic peak pending {:?} != \
             pre-expanded {:?}",
            dynamic.engine, dynamic.peak_pending, static_rep.peak_pending
        ));
    }
    if dynamic.metrics != static_rep.metrics {
        let a = &dynamic.metrics;
        let b = &static_rep.metrics;
        let what = if a.makespan_s != b.makespan_s {
            format!("makespan {} vs {}", a.makespan_s, b.makespan_s)
        } else if a.kvs != b.kvs {
            format!("kvs {:?} vs {:?}", a.kvs, b.kvs)
        } else if a.per_task_exec != b.per_task_exec {
            "per-task execution counts".to_string()
        } else if a.per_task_outcome != b.per_task_outcome {
            "per-task outcomes".to_string()
        } else {
            "metrics structs differ".to_string()
        };
        return Err(format!(
            "[{}] dynamic-equivalence: diverged from the pre-expanded \
             run: {what}",
            dynamic.engine
        ));
    }
    Ok(())
}

/// The durable-KVS recovery gate: a crashed-and-recovered run must be
/// byte-identical to the uninterrupted `reference` run, except for the
/// three recovery meters a crash is *allowed* to touch.
///
/// Checked in two halves:
///
/// 1. **Recovery-meter sanity** — `p_crash = 0` plans recover zero
///    times; `recoveries` never exceeds the plan's crash budget; the
///    metered stall covers at least `recoveries × recovery_base_s`
///    (replay time comes on top).
/// 2. **Data-plane bit-identity** — with `recoveries`, `replayed_ops`
///    and `stall_s` scrubbed from both sides, the full metrics structs
///    (and DES event counts) must compare equal. Recovery is
///    time-decoupled by design — the synchronous WAL means no
///    acknowledged op is lost, so outcomes, byte meters and event
///    streams cannot drift.
pub fn check_crash_recovery(
    reference: &EngineReport,
    rep: &EngineReport,
    plan: ShardCrashPlan,
    storage: &StorageConfig,
) -> Result<(), String> {
    let d = rep.metrics.durability;
    if plan.p_crash <= 0.0 && d.recoveries != 0 {
        return Err(format!(
            "[{}] crash-recovery: p_crash=0 plan recovered {} times",
            rep.engine, d.recoveries
        ));
    }
    if d.recoveries > plan.max_crashes as u64 {
        return Err(format!(
            "[{}] crash-recovery: {} recoveries exceed the plan's budget \
             of {}",
            rep.engine, d.recoveries, plan.max_crashes
        ));
    }
    let min_stall = d.recoveries as f64 * storage.recovery_base_s;
    if d.stall_s + 1e-12 < min_stall {
        return Err(format!(
            "[{}] crash-recovery: metered stall {}s < {} recoveries x \
             base {}s",
            rep.engine, d.stall_s, d.recoveries, storage.recovery_base_s
        ));
    }
    if reference.sim_events != rep.sim_events {
        return Err(format!(
            "[{}] crash-recovery: crashed-run event count {:?} != \
             uninterrupted {:?} (recovery leaked into the event stream)",
            rep.engine, rep.sim_events, reference.sim_events
        ));
    }
    if reference.peak_pending != rep.peak_pending {
        return Err(format!(
            "[{}] crash-recovery: peak pending {:?} != uninterrupted {:?}",
            rep.engine, rep.peak_pending, reference.peak_pending
        ));
    }
    let scrub = |m: &crate::metrics::RunMetrics| {
        let mut m = m.clone();
        m.durability.recoveries = 0;
        m.durability.replayed_ops = 0;
        m.durability.stall_s = 0.0;
        m
    };
    let a = scrub(&reference.metrics);
    let b = scrub(&rep.metrics);
    if a != b {
        let what = if a.makespan_s != b.makespan_s {
            format!("makespan {} vs {}", a.makespan_s, b.makespan_s)
        } else if a.kvs != b.kvs {
            format!("kvs {:?} vs {:?}", a.kvs, b.kvs)
        } else if a.durability != b.durability {
            format!(
                "wal/snapshot meters {:?} vs {:?}",
                a.durability, b.durability
            )
        } else if a.per_task_outcome != b.per_task_outcome {
            "per-task outcomes".to_string()
        } else {
            "metrics structs differ".to_string()
        };
        return Err(format!(
            "[{}] crash-recovery: data plane diverged from the \
             uninterrupted run: {what}",
            rep.engine
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::dag::{DagBuilder, OpKind};
    use crate::engine::{Engine, SimNumpywren, SimWukong};

    fn chain2() -> Dag {
        let mut b = DagBuilder::new("chain2");
        let a = b.task("a", OpKind::Generic, 1e6, 1000);
        let c = b.task("c", OpKind::Generic, 1e6, 1000);
        b.edge(a, c);
        b.build().unwrap()
    }

    #[test]
    fn stateless_closed_form_counts_edges_and_inputs() {
        let mut b = DagBuilder::new("f");
        let x = b.task("x", OpKind::Generic, 1.0, 100);
        let y = b.task("y", OpKind::Generic, 1.0, 50);
        let z = b.task("z", OpKind::Generic, 1.0, 10);
        b.edge(x, z).edge(y, z);
        b.with_input(x, 7);
        let dag = b.build().unwrap();
        let (read, written) = stateless_bytes(&dag);
        assert_eq!(written, 160);
        assert_eq!(read, 150 + 7);
    }

    #[test]
    fn numpywren_matches_the_stateless_closed_form() {
        let dag = chain2();
        let rep = SimNumpywren.run(&dag, &Config::default(), 1);
        check_stateless_model(&dag, &rep).unwrap();
        check_completion(&dag, &rep).unwrap();
        check_exactly_once(&dag, &rep).unwrap();
    }

    #[test]
    fn wukong_satisfies_the_locality_bound() {
        let dag = chain2();
        let rep = SimWukong::default().run(&dag, &Config::default(), 1);
        check_locality(&dag, &rep).unwrap();
    }

    #[test]
    fn violations_carry_engine_and_detail() {
        let dag = chain2();
        let mut rep = SimNumpywren.run(&dag, &Config::default(), 1);
        rep.metrics.per_task_exec[1] = 2;
        let err = check_exactly_once(&dag, &rep).unwrap_err();
        assert!(err.contains("numpywren") && err.contains("task 1"), "{err}");
    }

    #[test]
    fn fault_contract_accepts_clean_and_faulty_runs() {
        let dag = chain2();
        let cfg = Config::default();
        let rep = SimWukong.run(&dag, &cfg, 1);
        check_fault_contract(&dag, &rep, cfg.faults).unwrap();

        let mut faulty = Config::default();
        faulty.faults = FaultPlan::with_retries(1.0, 1);
        let rep = SimWukong.run(&dag, &faulty, 1);
        assert_eq!(rep.metrics.failed_tasks, 2);
        check_fault_contract(&dag, &rep, faulty.faults).unwrap();
    }

    #[test]
    fn fault_contract_rejects_silent_loss_and_overruns() {
        let dag = chain2();
        let cfg = Config::default();
        let clean = SimWukong.run(&dag, &cfg, 1);

        // A completed task that never executed = silent loss.
        let mut rep = clean.clone();
        rep.metrics.per_task_exec[1] = 0;
        rep.metrics.tasks_executed = 1;
        let err = check_fault_contract(&dag, &rep, cfg.faults).unwrap_err();
        assert!(err.contains("effectively-once"), "{err}");

        // Attempts beyond the retry budget.
        let mut rep = clean.clone();
        rep.metrics.per_task_attempts[0] = 9;
        let err = check_fault_contract(&dag, &rep, cfg.faults).unwrap_err();
        assert!(err.contains("max_retries"), "{err}");

        // Failed outcome without a failure report.
        let mut rep = clean.clone();
        rep.metrics.per_task_outcome[1] = TaskOutcome::Failed;
        rep.metrics.per_task_exec[1] = 0;
        rep.metrics.failed_tasks = 1;
        rep.metrics.tasks_executed = 1;
        let err = check_fault_contract(&dag, &rep, cfg.faults).unwrap_err();
        assert!(err.contains("failure"), "{err}");
    }

    #[test]
    fn crash_recovery_gate_accepts_a_recovered_run() {
        // numpywren is stateless: chain2 is 2 writes + 1 read, so a
        // p=1 plan with budget 2 recovers exactly twice.
        let dag = chain2();
        let cfg = Config::default();
        let reference = SimNumpywren.run(&dag, &cfg, 5);

        let mut crashed = cfg.clone();
        crashed.crashes = ShardCrashPlan::with_crashes(1.0, 2);
        let rep = SimNumpywren.run(&dag, &crashed, 5);
        assert_eq!(rep.metrics.durability.recoveries, 2);
        check_crash_recovery(&reference, &rep, crashed.crashes, &crashed.storage)
            .unwrap();
    }

    #[test]
    fn crash_recovery_gate_rejects_data_plane_drift_and_bad_meters() {
        let dag = chain2();
        let cfg = Config::default();
        let reference = SimNumpywren.run(&dag, &cfg, 5);
        let mut crashed = cfg.clone();
        crashed.crashes = ShardCrashPlan::with_crashes(1.0, 2);
        let clean = SimNumpywren.run(&dag, &crashed, 5);

        // Any data-plane divergence is a gate failure.
        let mut rep = clean.clone();
        rep.metrics.kvs.bytes_written += 1;
        let err = check_crash_recovery(
            &reference,
            &rep,
            crashed.crashes,
            &crashed.storage,
        )
        .unwrap_err();
        assert!(err.contains("data plane diverged"), "{err}");

        // Recoveries beyond the plan's crash budget.
        let mut rep = clean.clone();
        rep.metrics.durability.recoveries = 99;
        rep.metrics.durability.stall_s = 99.0 * crashed.storage.recovery_base_s;
        let err = check_crash_recovery(
            &reference,
            &rep,
            crashed.crashes,
            &crashed.storage,
        )
        .unwrap_err();
        assert!(err.contains("budget"), "{err}");

        // A recovery that was not billed its base cost.
        let mut rep = clean.clone();
        rep.metrics.durability.stall_s = 0.0;
        let err = check_crash_recovery(
            &reference,
            &rep,
            crashed.crashes,
            &crashed.storage,
        )
        .unwrap_err();
        assert!(err.contains("stall"), "{err}");

        // A zero-rate plan must not report recoveries at all.
        let zero = ShardCrashPlan::with_crashes(0.0, 4);
        let err =
            check_crash_recovery(&reference, &clean, zero, &crashed.storage)
                .unwrap_err();
        assert!(err.contains("p_crash=0"), "{err}");
    }

    #[test]
    fn dynamic_equivalence_gate_accepts_and_rejects() {
        use crate::dag::{pre_expand, SpawnPlan};
        let dag = chain2();
        let mut cfg = Config::default();
        cfg.spawn = SpawnPlan::recursive(1.0, 2, 2);
        let dy = SimWukong.run(&dag, &cfg, 3);
        let expanded = pre_expand(&dag, cfg.spawn, 3);
        let st = SimWukong.run(&expanded, &Config::default(), 3);
        check_dynamic_equivalence(&dy, &st).unwrap();
        check_completion(&expanded, &dy).unwrap();
        check_exactly_once(&expanded, &dy).unwrap();

        let mut drifted = st.clone();
        drifted.metrics.makespan_s += 1.0;
        let err = check_dynamic_equivalence(&dy, &drifted).unwrap_err();
        assert!(err.contains("dynamic-equivalence"), "{err}");
        assert!(err.contains("makespan"), "{err}");

        let mut fewer_events = st.clone();
        fewer_events.sim_events = fewer_events.sim_events.map(|e| e + 1);
        let err = check_dynamic_equivalence(&dy, &fewer_events).unwrap_err();
        assert!(err.contains("event count"), "{err}");
    }

    #[test]
    fn fault_free_baseline_flags_any_divergence() {
        let dag = chain2();
        let cfg = Config::default();
        let a = SimWukong.run(&dag, &cfg, 1);
        let b = SimWukong.run(&dag, &cfg, 1);
        check_fault_free_baseline(&a, &b).unwrap();
        let mut c = b.clone();
        c.metrics.makespan_s += 1.0;
        assert!(check_fault_free_baseline(&a, &c).is_err());
    }
}
