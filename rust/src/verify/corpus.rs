//! The conformance DAG corpus: seeded random generators covering the
//! regular *and* irregular shapes serverless DAG engines trip over
//! (cf. the irregular/elastic workloads of arXiv:2206.15321).
//!
//! Shapes:
//!  * layered      — random forward-edge layer graphs (the classic case);
//!  * skewed       — one wide fan-out root with chains of skewed depth
//!                   hanging off a subset of children, joined by a sink;
//!  * diamonds     — stacked fork/join diamonds of varying width;
//!  * chain        — a long dependency chain (single static schedule);
//!  * multi-sink   — several independent sinks (every sink must publish);
//!  * wide fan-in  — many parents into one child (MDS counter stress);
//!  * fork-join    — recursive divide-and-conquer trees (the static analog
//!                   of a runtime fork, `workloads::dynamic`);
//!  * branch-bound — pruned search trees joined by one incumbent sink.
//!
//! Output sizes deliberately straddle every policy threshold: zero-byte
//! edges, tiny objects, sizes just below/above the 256 KB inline-argument
//! limit, and objects above the 200 MB clustering threshold.
//!
//! Everything is a pure function of the [`Rng`] stream, so a case seed
//! reproduces its DAG exactly (the harness prints seeds on failure).

use crate::config::{Config, StorageConfig};
use crate::dag::{Dag, DagBuilder, OpKind, SpawnPlan, TaskId};
use crate::platform::faults::{FaultPlan, ShardCrashPlan};
use crate::serving::ArrivalPlan;
use crate::util::prop::gen;
use crate::util::Rng;
use crate::workloads::dynamic::{
    branch_and_bound, fork_join, BranchBoundParams, ForkJoinParams,
};

/// Corpus size tier. `Standard` draws the same DAGs (same RNG stream)
/// the harness always used; `Large` widens every shape's primary
/// dimensions by 1–2 orders of magnitude for scale smoke sweeps
/// (`wukong verify --large`). A case seed reproduces its DAG exactly
/// *within* a tier (generation is a pure function of seed + tier); the
/// two tiers' RNG streams diverge after the first sized draw, so seeds
/// are not comparable across tiers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CorpusSize {
    #[default]
    Standard,
    Large,
}

/// Output sizes straddling the inline (256 KB) and clustering (200 MB /
/// 1 MB knob values) thresholds, including zero-byte edges.
pub const SIZES: &[u64] = &[
    0,
    64,
    8 * 1024,
    200 * 1024,
    300 * 1024,
    2 << 20,
    300 << 20,
];

fn add_task(b: &mut DagBuilder, rng: &mut Rng, name: String) -> TaskId {
    let bytes = *gen::choose(rng, SIZES);
    b.task(name, OpKind::Generic, rng.below(1_000_000) as f64 + 1.0, bytes)
}

/// Attach an external input partition to ~half the leaves.
fn maybe_input(b: &mut DagBuilder, rng: &mut Rng, t: TaskId) {
    if rng.f64() < 0.5 {
        b.with_input(t, 1024);
    }
}

/// Random layered DAG: 1–5 ranks, forward-only random edges (the shape
/// the seed property tests used).
pub fn layered(rng: &mut Rng) -> Dag {
    layered_sized(rng, CorpusSize::Standard)
}

/// [`layered`] with a size tier.
pub fn layered_sized(rng: &mut Rng, size: CorpusSize) -> Dag {
    let (lmin, lmax, wmin, wmax) = match size {
        CorpusSize::Standard => (1, 5, 1, 6),
        CorpusSize::Large => (6, 10, 40, 200),
    };
    let layers = gen::usize_in(rng, lmin, lmax);
    let mut b = DagBuilder::new("layered");
    let mut prev: Vec<TaskId> = Vec::new();
    let mut all: Vec<TaskId> = Vec::new();
    let mut edges: std::collections::HashSet<(TaskId, TaskId)> =
        std::collections::HashSet::new();
    for layer in 0..layers {
        let width = gen::usize_in(rng, wmin, wmax);
        let mut cur = Vec::new();
        for i in 0..width {
            let t = add_task(&mut b, rng, format!("t{layer}_{i}"));
            if layer == 0 {
                maybe_input(&mut b, rng, t);
            }
            cur.push(t);
        }
        if layer > 0 {
            for &t in &cur {
                let p = *gen::choose(rng, &prev);
                edges.insert((p, t));
                b.edge(p, t);
                for _ in 0..gen::usize_in(rng, 0, 2) {
                    let extra = *gen::choose(rng, &all);
                    if edges.insert((extra, t)) {
                        b.edge(extra, t);
                    }
                }
            }
        }
        all.extend(&cur);
        prev = cur;
    }
    b.build().expect("layered corpus DAG is acyclic by construction")
}

/// Skewed fan-out: a root wide enough to cross the fan-out delegation
/// threshold, with chains of uneven depth under some children, all joined
/// by one sink (a wide, partially-deep fan-in).
pub fn skewed_fanout(rng: &mut Rng) -> Dag {
    skewed_fanout_sized(rng, CorpusSize::Standard)
}

/// [`skewed_fanout`] with a size tier.
pub fn skewed_fanout_sized(rng: &mut Rng, size: CorpusSize) -> Dag {
    let (wmin, wmax) = match size {
        CorpusSize::Standard => (8, 32),
        CorpusSize::Large => (512, 2048),
    };
    let width = gen::usize_in(rng, wmin, wmax);
    let mut b = DagBuilder::new("skewed");
    let root = add_task(&mut b, rng, "root".into());
    maybe_input(&mut b, rng, root);
    let mut tails = Vec::with_capacity(width);
    for i in 0..width {
        let mut cur = add_task(&mut b, rng, format!("k{i}"));
        b.edge(root, cur);
        // a skewed minority of branches grows a deeper chain
        if rng.f64() < 0.3 {
            for d in 0..gen::usize_in(rng, 1, 4) {
                let next = add_task(&mut b, rng, format!("k{i}_d{d}"));
                b.edge(cur, next);
                cur = next;
            }
        }
        tails.push(cur);
    }
    let sink = add_task(&mut b, rng, "sink".into());
    for (i, &t) in tails.iter().enumerate() {
        // every tail is a distinct task, so no duplicate edges; keep the
        // first one unconditionally so the sink has a parent
        if i == 0 || rng.f64() < 0.6 {
            b.edge(t, sink);
        }
    }
    b.build().expect("skewed corpus DAG is acyclic by construction")
}

/// Stacked fork/join diamonds: top → w mids → bottom, repeated 1–5 times
/// (fan-in ownership must hand over cleanly at every join).
pub fn diamond_stack(rng: &mut Rng) -> Dag {
    diamond_stack_sized(rng, CorpusSize::Standard)
}

/// [`diamond_stack`] with a size tier.
pub fn diamond_stack_sized(rng: &mut Rng, size: CorpusSize) -> Dag {
    let (dmin, dmax, wmin, wmax) = match size {
        CorpusSize::Standard => (1, 5, 2, 4),
        CorpusSize::Large => (4, 8, 32, 96),
    };
    let depth = gen::usize_in(rng, dmin, dmax);
    let mut b = DagBuilder::new("diamonds");
    let mut top = add_task(&mut b, rng, "d0_top".into());
    maybe_input(&mut b, rng, top);
    for d in 0..depth {
        let width = gen::usize_in(rng, wmin, wmax);
        let bottom = add_task(&mut b, rng, format!("d{d}_bot"));
        for i in 0..width {
            let mid = add_task(&mut b, rng, format!("d{d}_m{i}"));
            b.edge(top, mid);
            b.edge(mid, bottom);
        }
        top = bottom;
    }
    b.build().expect("diamond corpus DAG is acyclic by construction")
}

/// A long chain (16–80 tasks): one static schedule, zero fan-out — the
/// pure "becomes" path.
pub fn long_chain(rng: &mut Rng) -> Dag {
    long_chain_sized(rng, CorpusSize::Standard)
}

/// [`long_chain`] with a size tier.
pub fn long_chain_sized(rng: &mut Rng, size: CorpusSize) -> Dag {
    let (lmin, lmax) = match size {
        CorpusSize::Standard => (16, 80),
        CorpusSize::Large => (2_000, 6_000),
    };
    let len = gen::usize_in(rng, lmin, lmax);
    let mut b = DagBuilder::new("chain");
    let mut prev = add_task(&mut b, rng, "c0".into());
    maybe_input(&mut b, rng, prev);
    for i in 1..len {
        let t = add_task(&mut b, rng, format!("c{i}"));
        b.edge(prev, t);
        prev = t;
    }
    b.build().expect("chain corpus DAG is acyclic by construction")
}

/// Multiple independent sinks: the job only completes when *every* sink
/// publishes (the n_sinks bookkeeping the engines must get right).
pub fn multi_sink(rng: &mut Rng) -> Dag {
    multi_sink_sized(rng, CorpusSize::Standard)
}

/// [`multi_sink`] with a size tier.
pub fn multi_sink_sized(rng: &mut Rng, size: CorpusSize) -> Dag {
    let (rmin, rmax) = match size {
        CorpusSize::Standard => (2, 6),
        CorpusSize::Large => (48, 128),
    };
    let n_roots = gen::usize_in(rng, rmin, rmax);
    let mut b = DagBuilder::new("multisink");
    let mut roots = Vec::with_capacity(n_roots);
    for i in 0..n_roots {
        let r = add_task(&mut b, rng, format!("r{i}"));
        maybe_input(&mut b, rng, r);
        roots.push(r);
    }
    for (i, &r) in roots.iter().enumerate() {
        for j in 0..gen::usize_in(rng, 1, 3) {
            let s = add_task(&mut b, rng, format!("s{i}_{j}"));
            b.edge(r, s);
            // occasionally share a second parent from another root
            if n_roots > 1 && rng.f64() < 0.3 {
                let other = roots[(i + 1) % n_roots];
                b.edge(other, s);
            }
        }
    }
    b.build().expect("multi-sink corpus DAG is acyclic by construction")
}

/// Wide fan-in: 4–24 parents feeding one child (atomic-counter stress),
/// followed by a short tail chain.
pub fn wide_fanin(rng: &mut Rng) -> Dag {
    wide_fanin_sized(rng, CorpusSize::Standard)
}

/// [`wide_fanin`] with a size tier.
pub fn wide_fanin_sized(rng: &mut Rng, size: CorpusSize) -> Dag {
    let (wmin, wmax) = match size {
        CorpusSize::Standard => (4, 24),
        CorpusSize::Large => (1_024, 4_096),
    };
    let width = gen::usize_in(rng, wmin, wmax);
    let mut b = DagBuilder::new("fanin");
    let mut parents = Vec::with_capacity(width);
    for i in 0..width {
        let p = add_task(&mut b, rng, format!("p{i}"));
        maybe_input(&mut b, rng, p);
        parents.push(p);
    }
    let join = add_task(&mut b, rng, "join".into());
    for &p in &parents {
        b.edge(p, join);
    }
    let mut prev = join;
    for i in 0..gen::usize_in(rng, 0, 3) {
        let t = add_task(&mut b, rng, format!("tail{i}"));
        b.edge(prev, t);
        prev = t;
    }
    b.build().expect("fan-in corpus DAG is acyclic by construction")
}

/// Recursive fork-join (divide-and-conquer) tree — the irregular,
/// recursion-shaped graph runtime spawning produces, pre-expanded.
pub fn fork_join_tree(rng: &mut Rng) -> Dag {
    fork_join_tree_sized(rng, CorpusSize::Standard)
}

/// [`fork_join_tree`] with a size tier.
pub fn fork_join_tree_sized(rng: &mut Rng, size: CorpusSize) -> Dag {
    let (fanout, depth) = match size {
        // N(F,D) ∈ [10, 53] standard, [161, 426] large (closed form in
        // `workloads::dynamic`): large minimum > 2× standard maximum.
        CorpusSize::Standard => {
            (gen::usize_in(rng, 2, 3), gen::usize_in(rng, 2, 3))
        }
        CorpusSize::Large => (gen::usize_in(rng, 3, 4), 4),
    };
    fork_join(ForkJoinParams {
        fanout,
        depth,
        flops: rng.below(1_000_000) as f64 + 1.0,
        out_bytes: *gen::choose(rng, SIZES),
    })
}

/// Branch-and-bound search tree with random pruning, joined by one
/// incumbent sink (wide irregular fan-in over pruned leaves).
pub fn branch_bound_tree(rng: &mut Rng) -> Dag {
    branch_bound_tree_sized(rng, CorpusSize::Standard)
}

/// [`branch_bound_tree`] with a size tier.
pub fn branch_bound_tree_sized(rng: &mut Rng, size: CorpusSize) -> Dag {
    let (branches, depth, keep_levels, p_prune) = match size {
        // [16, 32] tasks standard; [122, 1366] large — the large floor
        // (1+3+9+27 kept + 81 all-pruned + sink) > 2× the standard cap.
        CorpusSize::Standard => (2, gen::usize_in(rng, 3, 4), 2, 0.35),
        CorpusSize::Large => (gen::usize_in(rng, 3, 4), 5, 3, 0.5),
    };
    branch_and_bound(BranchBoundParams {
        branches,
        depth,
        keep_levels,
        p_prune,
        flops: rng.below(1_000_000) as f64 + 1.0,
        out_bytes: *gen::choose(rng, SIZES),
        seed: rng.next_u64(),
    })
}

/// Draw one DAG from the whole corpus, shape chosen by the seed.
pub fn random_dag(rng: &mut Rng) -> Dag {
    random_dag_sized(rng, CorpusSize::Standard)
}

/// Draw one DAG from the whole corpus at the given size tier.
pub fn random_dag_sized(rng: &mut Rng, size: CorpusSize) -> Dag {
    match rng.below(8) {
        0 => layered_sized(rng, size),
        1 => skewed_fanout_sized(rng, size),
        2 => diamond_stack_sized(rng, size),
        3 => long_chain_sized(rng, size),
        4 => multi_sink_sized(rng, size),
        5 => wide_fanin_sized(rng, size),
        6 => fork_join_tree_sized(rng, size),
        _ => branch_bound_tree_sized(rng, size),
    }
}

/// Failure rates swept by `wukong verify --faults`: none, the rare-crash
/// regime, the Raptor-style stress regime, and an extreme rate where
/// retry budgets are routinely exhausted.
pub const FAULT_RATES: &[f64] = &[0.0, 0.01, 0.1, 0.5];

/// Retry budgets swept by the fault axis: none vs AWS's retry-twice.
pub const FAULT_RETRIES: &[u32] = &[0, 2];

/// The fault knob matrix (§3.6): every failure rate × retry budget.
/// `p_fail = 0` combos double as the bit-identity regression against the
/// fault-free baseline.
pub fn fault_matrix() -> Vec<FaultPlan> {
    let mut out = Vec::new();
    for &p_fail in FAULT_RATES {
        for &max_retries in FAULT_RETRIES {
            out.push(FaultPlan::with_retries(p_fail, max_retries));
        }
    }
    out
}

/// The shard-crash matrix swept by `wukong verify --crashes`: no
/// crashes (the bit-identity regression against the crash-free
/// reference), rare crashes, op-level crash stress, and a tight
/// one-crash budget (pins the `max_crashes` cap).
pub fn crash_matrix() -> Vec<ShardCrashPlan> {
    vec![
        ShardCrashPlan::with_crashes(0.0, 4),
        ShardCrashPlan::with_crashes(0.05, 4),
        ShardCrashPlan::with_crashes(0.5, 4),
        ShardCrashPlan::with_crashes(0.5, 1),
    ]
}

/// The spawn-plan matrix swept by `wukong verify --dynamic`: sparse
/// single-child spawns, recursive depth-3 expansion, wide one-level
/// bursts (straddling the 256 KB inline limit), guaranteed expansion at
/// every task including sinks (zero-cost subtasks — pure structure), and
/// the zero-rate regression plan (must be bit-identical to no plan at
/// all). Plans are fixed — not drawn from the case RNG — so the
/// harness's engine-run accounting is pinnable.
pub fn spawn_matrix() -> Vec<(&'static str, SpawnPlan)> {
    vec![
        (
            "single",
            SpawnPlan {
                p_spawn: 0.08,
                fanout: 1,
                depth: 1,
                task_dur_s: 0.005,
                out_bytes: 64 * 1024,
            },
        ),
        (
            "recursive",
            SpawnPlan {
                p_spawn: 0.3,
                fanout: 2,
                depth: 3,
                task_dur_s: 0.002,
                out_bytes: 8 * 1024,
            },
        ),
        (
            "burst",
            SpawnPlan {
                p_spawn: 0.15,
                fanout: 8,
                depth: 1,
                task_dur_s: 0.001,
                out_bytes: 300 * 1024,
            },
        ),
        (
            "at-sink",
            SpawnPlan {
                p_spawn: 1.0,
                fanout: 2,
                depth: 2,
                task_dur_s: 0.0,
                out_bytes: 0,
            },
        ),
        ("zero-rate", SpawnPlan::default()),
    ]
}

/// Jobs per serving plan swept by `wukong verify --serving`. Small on
/// purpose: every admitted job is a full engine run, and the axis runs
/// each plan twice (a determinism replay).
pub const SERVING_JOBS: u64 = 6;

/// The arrival-plan matrix swept by `wukong verify --serving`: a
/// zero-rate Poisson stream (the empty-stream/bit-identity regression —
/// it must admit nothing and draw nothing), a slow and a bursty Poisson
/// regime, and a deterministic trace. Plans are fixed (not drawn from
/// the case RNG) so the harness's engine-run accounting is pinnable.
pub fn arrival_matrix() -> Vec<ArrivalPlan> {
    vec![
        ArrivalPlan::poisson(0.0, SERVING_JOBS),
        ArrivalPlan::poisson(4.0, SERVING_JOBS),
        ArrivalPlan::poisson(50.0, SERVING_JOBS),
        ArrivalPlan::trace(0.25, SERVING_JOBS),
    ]
}

/// Durability cost profiles for the crash axis, derived from a case's
/// base config: the default free-WAL tier (fsync and snapshots cost
/// nothing, so crash-free runs are bit-identical to the base sweep's)
/// and a costed tier (nonzero fsync time + a snapshot cadence + replay
/// costs). Each profile gets its *own* crash-free reference inside the
/// axis, because a nonzero `wal_fsync_s` legitimately shifts timing.
pub fn crash_profiles(base: &Config) -> Vec<(&'static str, Config)> {
    let mut costed = base.clone();
    costed.storage.wal_fsync_s = 2e-4;
    costed.storage.snapshot_every_ops = 32;
    costed.storage.replay_op_s = 2e-5;
    costed.storage.recovery_base_s = 0.05;
    vec![("wal=free", base.clone()), ("wal=costed", costed)]
}

/// Random policy-knob + substrate configuration (the per-case baseline;
/// the harness additionally sweeps the exhaustive knob matrix on top).
pub fn random_config(rng: &mut Rng) -> Config {
    let mut cfg = Config::default();
    cfg.wukong.use_clustering = rng.f64() < 0.7;
    cfg.wukong.use_delayed_io = rng.f64() < 0.7;
    cfg.wukong.clustering_threshold =
        *gen::choose(rng, &[1u64 << 20, 200 << 20, 100]);
    cfg.wukong.fanout_delegation_threshold = gen::usize_in(rng, 1, 10);
    if rng.f64() < 0.25 {
        cfg.storage = StorageConfig::default().s3(); // IOPS-gated mode
    }
    cfg.storage.n_shards = gen::usize_in(rng, 1, 75);
    cfg.numpywren.n_workers = gen::usize_in(rng, 1, 32);
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn every_shape_builds_and_is_nonempty() {
        check(0xC0121, 60, |rng| {
            let shapes: [fn(&mut Rng) -> Dag; 8] = [
                layered,
                skewed_fanout,
                diamond_stack,
                long_chain,
                multi_sink,
                wide_fanin,
                fork_join_tree,
                branch_bound_tree,
            ];
            for f in shapes {
                let d = f(rng);
                assert!(!d.is_empty());
                assert!(!d.leaves().is_empty());
                assert!(!d.sinks().is_empty());
                // builder validated acyclicity; double-check via topo
                assert_eq!(d.topo_order().len(), d.len());
            }
        });
    }

    #[test]
    fn generation_is_deterministic_per_seed() {
        let mut a = Rng::new(99);
        let mut b = Rng::new(99);
        for _ in 0..20 {
            let da = random_dag(&mut a);
            let db = random_dag(&mut b);
            assert_eq!(da.len(), db.len());
            assert_eq!(da.n_edges(), db.n_edges());
            assert_eq!(
                da.tasks().iter().map(|t| t.out_bytes).sum::<u64>(),
                db.tasks().iter().map(|t| t.out_bytes).sum::<u64>()
            );
        }
    }

    #[test]
    fn corpus_covers_irregular_sizes() {
        // Across a modest sample the corpus must emit zero-byte edges,
        // inline-straddling sizes and clustering-sized objects.
        let mut rng = Rng::new(7);
        let (mut zero, mut straddle, mut huge) = (false, false, false);
        for _ in 0..40 {
            let d = random_dag(&mut rng);
            for t in d.tasks() {
                zero |= t.out_bytes == 0;
                straddle |= t.out_bytes == 300 * 1024;
                huge |= t.out_bytes == (300 << 20);
            }
        }
        assert!(zero && straddle && huge, "{zero} {straddle} {huge}");
    }

    #[test]
    fn large_tier_scales_every_shape_up() {
        let shapes: [fn(&mut Rng, CorpusSize) -> Dag; 8] = [
            layered_sized,
            skewed_fanout_sized,
            diamond_stack_sized,
            long_chain_sized,
            multi_sink_sized,
            wide_fanin_sized,
            fork_join_tree_sized,
            branch_bound_tree_sized,
        ];
        for (i, f) in shapes.iter().enumerate() {
            let small = f(&mut Rng::new(31 + i as u64), CorpusSize::Standard);
            let large = f(&mut Rng::new(31 + i as u64), CorpusSize::Large);
            // Guaranteed by the tier bounds: every large minimum exceeds
            // twice the corresponding standard maximum, and no large
            // shape is smaller than ~90 tasks.
            assert!(
                large.len() > 2 * small.len(),
                "shape {i}: large {} vs standard {}",
                large.len(),
                small.len()
            );
            assert!(large.len() >= 90, "shape {i}: large only {}", large.len());
            assert_eq!(large.topo_order().len(), large.len());
        }
    }

    #[test]
    fn standard_tier_is_the_default_corpus() {
        // `random_dag` and the Standard tier must stay the same stream:
        // a replay seed printed by a sweep reproduces its DAG exactly.
        let mut a = Rng::new(0x5EED);
        let mut b = Rng::new(0x5EED);
        for _ in 0..10 {
            let da = random_dag(&mut a);
            let db = random_dag_sized(&mut b, CorpusSize::Standard);
            assert_eq!(da.len(), db.len());
            assert_eq!(da.n_edges(), db.n_edges());
        }
    }

    #[test]
    fn fault_matrix_covers_rates_times_budgets() {
        let m = fault_matrix();
        assert_eq!(m.len(), FAULT_RATES.len() * FAULT_RETRIES.len());
        assert_eq!(m.iter().filter(|p| p.p_fail == 0.0).count(), 2);
        assert_eq!(m.iter().filter(|p| p.max_retries == 2).count(), 4);
    }

    #[test]
    fn crash_matrix_covers_zero_stress_and_budget_cap() {
        let m = crash_matrix();
        assert_eq!(m.len(), 4);
        assert_eq!(m.iter().filter(|p| p.p_crash == 0.0).count(), 1);
        assert!(m.iter().any(|p| p.max_crashes == 1));
        assert!(m.iter().all(|p| (0.0..=1.0).contains(&p.p_crash)));
    }

    #[test]
    fn arrival_matrix_pins_one_empty_and_three_live_plans() {
        let m = arrival_matrix();
        assert_eq!(m.len(), 4);
        assert_eq!(m.iter().filter(|p| p.is_empty()).count(), 1);
        assert!(m[0].is_empty(), "plan 0 is the zero-rate regression");
        assert!(m.iter().all(|p| p.jobs == SERVING_JOBS));
        assert!(m.iter().any(|p| p.mode == crate::serving::ArrivalMode::Trace));
    }

    #[test]
    fn crash_profiles_differ_only_in_durability_knobs() {
        let base = Config::default();
        let profiles = crash_profiles(&base);
        assert_eq!(profiles.len(), 2);
        let (name_free, free) = &profiles[0];
        let (name_costed, costed) = &profiles[1];
        assert_eq!(*name_free, "wal=free");
        assert_eq!(*name_costed, "wal=costed");
        // The free profile is the base config untouched.
        assert_eq!(free.storage.wal_fsync_s, base.storage.wal_fsync_s);
        assert_eq!(
            free.storage.snapshot_every_ops,
            base.storage.snapshot_every_ops
        );
        // The costed profile turns every durability knob on, and
        // leaves the data plane alone.
        assert!(costed.storage.wal_fsync_s > 0.0);
        assert!(costed.storage.snapshot_every_ops > 0);
        assert_eq!(costed.storage.n_shards, base.storage.n_shards);
        assert_eq!(costed.storage.shard_bw, base.storage.shard_bw);
        assert_eq!(costed.wukong.use_clustering, base.wukong.use_clustering);
    }

    #[test]
    fn spawn_matrix_pins_one_zero_rate_and_four_live_plans() {
        let m = spawn_matrix();
        assert_eq!(m.len(), 5);
        assert_eq!(m.iter().filter(|(_, p)| !p.is_live()).count(), 1);
        let (name, zero) = m.last().unwrap();
        assert_eq!(*name, "zero-rate");
        assert_eq!(*zero, SpawnPlan::default());
        // The live plans stay within the `--set` validation envelope.
        for (name, p) in &m {
            assert!((0.0..=1.0).contains(&p.p_spawn), "{name}");
            assert!((1..=1024).contains(&p.fanout), "{name}");
            assert!((1..=8).contains(&p.depth), "{name}");
            assert!(p.task_dur_s >= 0.0, "{name}");
        }
        // One plan expands everywhere (spawn-at-sink coverage), one
        // straddles the 256 KB inline-argument limit.
        assert!(m.iter().any(|(_, p)| p.p_spawn == 1.0));
        assert!(m.iter().any(|(_, p)| p.out_bytes == 300 * 1024));
    }

    #[test]
    fn chain_has_single_schedule() {
        let mut rng = Rng::new(3);
        let d = long_chain(&mut rng);
        assert_eq!(d.leaves().len(), 1);
        assert_eq!(d.sinks().len(), 1);
        assert_eq!(d.n_edges(), d.len() - 1);
    }
}
