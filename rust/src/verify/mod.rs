//! `wukong verify` — the cross-engine differential conformance harness.
//!
//! Sweeps a corpus of generated DAGs ([`corpus`]) through every
//! registered [`crate::engine::Engine`] under an exhaustive policy-knob
//! matrix and asserts the invariants in [`diff`]: exactly-once
//! execution, completion, per-seed determinism, and the paper's locality
//! ordering (Wukong KVS bytes ≤ stateless KVS bytes on every DAG).
//! Opt-in axes layer on top: `--faults` sweeps the §3.6 retry matrix,
//! `--crashes` sweeps durable-KVS shard-crash plans against the
//! byte-identical recovery gate ([`diff::check_crash_recovery`]), and
//! `--dynamic` sweeps runtime spawn plans against the dynamic-vs-
//! pre-expanded differential gate ([`diff::check_dynamic_equivalence`]).
//! Every
//! engine run is capped by a sim event budget (watchdog), so a
//! livelocked engine aborts and reports instead of hanging the sweep.
//!
//! This is the regression gate for every scaling/perf refactor: it runs
//! artifact-free under plain `cargo test -q` (`rust/tests/conformance.rs`)
//! and interactively via `wukong verify [--engine ...] [--runs N]
//! [--seed S] [--threads N] [--large]`. Engine panics (an engine's
//! internal exactly-once assert, an index bug mid-refactor) are caught
//! per run and reported as violations with the case seed, so one bad
//! case never hides the rest of the matrix.
//!
//! Cases are independent pure functions of their case seed, so the sweep
//! fans out across [`crate::util::threadpool::ordered_map`] workers and
//! aggregates in case-index order — the summary (cases, engine_runs,
//! violations, verbose lines) is byte-identical to a `--threads 1` run
//! (which additionally streams the verbose lines live).

pub mod corpus;
pub mod diff;

use std::panic::{catch_unwind, AssertUnwindSafe};

use crate::config::Config;
use crate::dag::Dag;
use crate::engine::{select_engines, Engine, EngineReport};
use crate::serving::{run_serving, FairnessPolicy};
use crate::util::threadpool::ordered_map;
use crate::util::Rng;

use self::corpus::CorpusSize;

/// Options for one verify sweep (CLI flags map 1:1).
#[derive(Debug, Clone)]
pub struct VerifyOptions {
    /// Engine names to exercise; empty = every sim-path engine.
    pub engines: Vec<String>,
    /// Number of generated DAG cases.
    pub runs: u64,
    /// Base seed; each case derives an independent seed from it.
    pub seed: u64,
    /// Print one line per case.
    pub verbose: bool,
    /// Worker threads for the case sweep; 0 = one per available core.
    pub threads: usize,
    /// Use the large corpus size tier (scale smoke sweeps).
    pub large: bool,
    /// Sweep the §3.6 fault axis (`corpus::fault_matrix`) on top of the
    /// base matrix. Opt-in so fault-free sweeps (and their pinned run
    /// counts) stay byte-identical to pre-fault-axis behavior.
    pub faults: bool,
    /// Sweep the durable-KVS crash axis (`corpus::crash_matrix` ×
    /// `corpus::crash_profiles`) on top of the base matrix: every
    /// crashed-and-recovered run must be byte-identical to its
    /// uninterrupted reference modulo the recovery meters. Opt-in, like
    /// `faults`.
    pub crashes: bool,
    /// Sweep the multi-tenant serving axis (`corpus::arrival_matrix`):
    /// each arrival plan is multiplexed over the shared pool twice and
    /// must conserve jobs (admitted = completed ⊕ failed) and replay
    /// byte-identically; the zero-rate plan must be a no-op. Opt-in,
    /// like `faults`.
    pub serving: bool,
    /// Sweep the dynamic-DAG axis (`corpus::spawn_matrix`): every live
    /// spawn plan runs dynamically, replays deterministically, and must
    /// be byte-identical to the statically pre-expanded equivalent DAG
    /// ([`diff::check_dynamic_equivalence`]); the zero-rate plan must be
    /// bit-identical to the plan-free reference. Opt-in, like `faults`.
    pub dynamic: bool,
}

impl Default for VerifyOptions {
    fn default() -> Self {
        VerifyOptions {
            engines: Vec::new(),
            runs: 25,
            seed: 7,
            verbose: false,
            threads: 0,
            large: false,
            faults: false,
            crashes: false,
            serving: false,
            dynamic: false,
        }
    }
}

/// Aggregate result of a verify sweep.
#[derive(Debug, Clone, PartialEq)]
pub struct VerifySummary {
    /// DAG cases generated and executed.
    pub cases: u64,
    /// Engines exercised (registry names).
    pub engines: Vec<String>,
    /// Total engine runs (incl. knob-matrix and determinism re-runs).
    pub engine_runs: u64,
    /// Total tasks across all generated DAGs.
    pub total_tasks: u64,
    /// Every invariant violation found, with its case seed for replay.
    pub violations: Vec<String>,
}

impl VerifySummary {
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }
}

/// One case's result, produced by a (possibly pooled) worker and merged
/// in case-index order.
struct CaseResult {
    case_seed: u64,
    total_tasks: u64,
    engine_runs: u64,
    violations: Vec<String>,
    verbose_line: String,
}

/// The exhaustive Wukong policy-knob matrix swept per case: clustering ×
/// delayed-I/O × clustering threshold (below/above most corpus sizes).
fn knob_matrix(base: &Config) -> Vec<(String, Config)> {
    let mut out = Vec::new();
    for &clustering in &[false, true] {
        for &delayed_io in &[false, true] {
            for &threshold in &[1u64 << 20, 200u64 << 20] {
                let mut cfg = base.clone();
                cfg.wukong.use_clustering = clustering;
                cfg.wukong.use_delayed_io = delayed_io;
                cfg.wukong.clustering_threshold = threshold;
                out.push((
                    format!(
                        "clustering={clustering} delayed_io={delayed_io} \
                         t={}MB",
                        threshold >> 20
                    ),
                    cfg,
                ));
            }
        }
    }
    out
}

/// Run one engine, converting a panic (engine-internal assertion) into a
/// reportable violation instead of aborting the sweep.
fn run_guarded(
    engine: &dyn Engine,
    dag: &Dag,
    cfg: &Config,
    seed: u64,
) -> Result<EngineReport, String> {
    catch_unwind(AssertUnwindSafe(|| engine.run(dag, cfg, seed))).map_err(|err| {
        format!(
            "[{}] engine panicked: {}",
            engine.name(),
            crate::util::prop::panic_message(err.as_ref())
        )
    })
}

/// Derive the replayable seed of case `case` (same derivation as
/// `util::prop::check`, so printed seeds replay).
fn case_seed_of(base: u64, case: u64) -> u64 {
    base.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(case)
}

/// Execute one case end to end: generate the DAG + config, sweep the
/// engine × knob matrix, collect violations. Pure function of
/// `(opts, case)` — the parallel sweep depends on it.
fn run_case(opts: &VerifyOptions, case: u64) -> CaseResult {
    let case_seed = case_seed_of(opts.seed, case);
    let mut rng = Rng::new(case_seed);
    let size = if opts.large {
        CorpusSize::Large
    } else {
        CorpusSize::Standard
    };
    let dag = corpus::random_dag_sized(&mut rng, size);
    let mut base = corpus::random_config(&mut rng);
    let run_seed = rng.next_u64();
    // Watchdog: cap every engine run at an event budget far above any
    // legitimate corpus case, so a livelocked engine (an event loop
    // re-scheduling itself forever mid-refactor) aborts with a panic —
    // caught by `run_guarded` and reported as a violation — instead of
    // hanging the whole sweep.
    if base.event_budget == 0 {
        base.event_budget = if opts.large {
            2_000_000_000
        } else {
            50_000_000
        };
    }
    // Engine names were validated before the sweep started.
    let engines = select_engines(&opts.engines).expect("engines pre-validated");

    let mut engine_runs = 0u64;
    let mut violations = Vec::new();
    for engine in &engines {
        // Wukong sweeps the full knob matrix; other engines ignore
        // the Wukong knobs, so one base config suffices.
        let configs = if engine.caps().decentralized {
            knob_matrix(&base)
        } else {
            vec![("base".to_string(), base.clone())]
        };
        for (label, cfg) in &configs {
            engine_runs += 1;
            let rep = match run_guarded(engine.as_ref(), &dag, cfg, run_seed) {
                Ok(r) => r,
                Err(v) => {
                    violations.push(format!("{v} ({label})"));
                    continue;
                }
            };
            engine_runs += 1; // determinism re-run
            let rerun = match run_guarded(engine.as_ref(), &dag, cfg, run_seed)
            {
                Ok(r) => r,
                Err(v) => {
                    violations.push(format!("{v} ({label}, rerun)"));
                    continue;
                }
            };

            for check in [
                diff::check_completion(&dag, &rep),
                diff::check_exactly_once(&dag, &rep),
                diff::check_determinism(&rep, &rerun),
                // Fault-free runs must still satisfy the §3.6 contract
                // shape: all-completed outcomes, one attempt per task.
                diff::check_fault_contract(&dag, &rep, cfg.faults),
            ] {
                if let Err(v) = check {
                    violations.push(format!("{v} ({label})"));
                }
            }
            if engine.caps().meters_kvs {
                // Locality ordering: metered engines never move more
                // bytes than the stateless closed form; stateful ones
                // (Wukong) are the paper's headline ≤ claim, and the
                // stateless baselines must *equal* the closed form.
                let check = if engine.caps().stateful_executors {
                    diff::check_locality(&dag, &rep)
                } else {
                    diff::check_stateless_model(&dag, &rep)
                };
                if let Err(v) = check {
                    violations.push(format!("{v} ({label})"));
                }
            }
        }

        // Opt-in §3.6 fault axis: p_fail × max_retries on top of the
        // base config. One fault-free reference run anchors the
        // bit-identity check for the p_fail=0 plans.
        if opts.faults && engine.caps().supports_faults {
            engine_runs += 1;
            let reference =
                match run_guarded(engine.as_ref(), &dag, &base, run_seed) {
                    Ok(r) => Some(r),
                    Err(v) => {
                        violations.push(format!("{v} (fault reference)"));
                        None
                    }
                };
            for plan in corpus::fault_matrix() {
                let label = format!(
                    "faults p={} r={}",
                    plan.p_fail, plan.max_retries
                );
                let mut cfg = base.clone();
                cfg.faults = plan;
                engine_runs += 1;
                let rep =
                    match run_guarded(engine.as_ref(), &dag, &cfg, run_seed) {
                        Ok(r) => r,
                        Err(v) => {
                            violations.push(format!("{v} ({label})"));
                            continue;
                        }
                    };
                engine_runs += 1; // determinism re-run
                let rerun =
                    match run_guarded(engine.as_ref(), &dag, &cfg, run_seed) {
                        Ok(r) => r,
                        Err(v) => {
                            violations
                                .push(format!("{v} ({label}, rerun)"));
                            continue;
                        }
                    };

                let mut checks = vec![
                    diff::check_fault_contract(&dag, &rep, plan),
                    diff::check_determinism(&rep, &rerun),
                ];
                if rep.metrics.failed_tasks == 0 {
                    // With no terminal failures the classic invariants
                    // must hold verbatim — retries are invisible to
                    // completion and effectively-once execution.
                    checks.push(diff::check_completion(&dag, &rep));
                    checks.push(diff::check_exactly_once(&dag, &rep));
                }
                if plan.p_fail == 0.0 {
                    // A zero-rate plan must be bit-identical to the
                    // fault-free run: enabling the knob draws nothing
                    // from the fault stream.
                    if let Some(reference) = &reference {
                        checks.push(diff::check_fault_free_baseline(
                            reference, &rep,
                        ));
                    }
                }
                for check in checks {
                    if let Err(v) = check {
                        violations.push(format!("{v} ({label})"));
                    }
                }
            }
        }

        // Opt-in durable-KVS crash axis: for each durability profile
        // (free vs costed WAL/snapshot knobs), one uninterrupted
        // reference run anchors the recovery gate; every crash plan must
        // match it byte-for-byte modulo the recovery meters. Profiles
        // get their *own* reference because a costed WAL fsync
        // legitimately shifts timing relative to the base config.
        if opts.crashes && engine.caps().supports_faults {
            for (profile, pbase) in corpus::crash_profiles(&base) {
                engine_runs += 1;
                let reference =
                    match run_guarded(engine.as_ref(), &dag, &pbase, run_seed)
                    {
                        Ok(r) => Some(r),
                        Err(v) => {
                            violations.push(format!(
                                "{v} (crash reference, {profile})"
                            ));
                            None
                        }
                    };
                for plan in corpus::crash_matrix() {
                    let label = format!(
                        "crashes p={} max={} ({profile})",
                        plan.p_crash, plan.max_crashes
                    );
                    let mut cfg = pbase.clone();
                    cfg.crashes = plan;
                    engine_runs += 1;
                    let rep = match run_guarded(
                        engine.as_ref(),
                        &dag,
                        &cfg,
                        run_seed,
                    ) {
                        Ok(r) => r,
                        Err(v) => {
                            violations.push(format!("{v} ({label})"));
                            continue;
                        }
                    };
                    engine_runs += 1; // determinism re-run
                    let rerun = match run_guarded(
                        engine.as_ref(),
                        &dag,
                        &cfg,
                        run_seed,
                    ) {
                        Ok(r) => r,
                        Err(v) => {
                            violations
                                .push(format!("{v} ({label}, rerun)"));
                            continue;
                        }
                    };

                    // Crashes never fail tasks (the synchronous WAL
                    // loses nothing), so the classic invariants hold
                    // verbatim on top of the recovery gate.
                    let mut checks = vec![
                        diff::check_determinism(&rep, &rerun),
                        diff::check_completion(&dag, &rep),
                        diff::check_exactly_once(&dag, &rep),
                        diff::check_fault_contract(&dag, &rep, cfg.faults),
                    ];
                    if let Some(reference) = &reference {
                        checks.push(diff::check_crash_recovery(
                            reference,
                            &rep,
                            plan,
                            &cfg.storage,
                        ));
                        if plan.p_crash == 0.0 {
                            // A zero-rate crash plan must be fully
                            // bit-identical — enabling the knob draws
                            // nothing from the crash stream.
                            checks.push(diff::check_fault_free_baseline(
                                reference, &rep,
                            ));
                        }
                    }
                    for check in checks {
                        if let Err(v) = check {
                            violations.push(format!("{v} ({label})"));
                        }
                    }
                }
            }
        }

        // Opt-in dynamic-DAG axis: one plan-free reference anchors the
        // zero-rate bit-identity check; every live spawn plan runs
        // dynamically (plus a determinism replay) and must be
        // byte-identical to the statically pre-expanded equivalent DAG
        // run plan-free — the whole tentpole contract in one gate. The
        // classic invariants (completion, exactly-once, fault contract)
        // are checked against the *expanded* task set.
        if opts.dynamic && engine.caps().supports_spawning {
            engine_runs += 1;
            let reference =
                match run_guarded(engine.as_ref(), &dag, &base, run_seed) {
                    Ok(r) => Some(r),
                    Err(v) => {
                        violations.push(format!("{v} (spawn reference)"));
                        None
                    }
                };
            for (name, plan) in corpus::spawn_matrix() {
                let label = format!(
                    "spawn {name} p={} f={} d={}",
                    plan.p_spawn, plan.fanout, plan.depth
                );
                let mut cfg = base.clone();
                cfg.spawn = plan;
                if !plan.is_live() {
                    // Zero-rate plan: one run, bit-identical to the
                    // plan-free reference (draws nothing from the spawn
                    // stream).
                    engine_runs += 1;
                    match run_guarded(engine.as_ref(), &dag, &cfg, run_seed)
                    {
                        Ok(rep) => {
                            if let Some(reference) = &reference {
                                if let Err(v) =
                                    diff::check_fault_free_baseline(
                                        reference, &rep,
                                    )
                                {
                                    violations
                                        .push(format!("{v} ({label})"));
                                }
                            }
                        }
                        Err(v) => {
                            violations.push(format!("{v} ({label})"))
                        }
                    }
                    continue;
                }
                engine_runs += 1;
                let rep =
                    match run_guarded(engine.as_ref(), &dag, &cfg, run_seed) {
                        Ok(r) => r,
                        Err(v) => {
                            violations.push(format!("{v} ({label})"));
                            continue;
                        }
                    };
                engine_runs += 1; // determinism re-run
                let rerun =
                    match run_guarded(engine.as_ref(), &dag, &cfg, run_seed) {
                        Ok(r) => r,
                        Err(v) => {
                            violations
                                .push(format!("{v} ({label}, rerun)"));
                            continue;
                        }
                    };
                // The statically pre-expanded equivalent: same seed, no
                // spawn plan (`base` carries the inert default).
                let expanded = crate::dag::pre_expand(&dag, plan, run_seed);
                engine_runs += 1;
                let static_rep = match run_guarded(
                    engine.as_ref(),
                    &expanded,
                    &base,
                    run_seed,
                ) {
                    Ok(r) => r,
                    Err(v) => {
                        violations
                            .push(format!("{v} ({label}, pre-expanded)"));
                        continue;
                    }
                };

                for check in [
                    diff::check_determinism(&rep, &rerun),
                    diff::check_dynamic_equivalence(&rep, &static_rep),
                    diff::check_completion(&expanded, &rep),
                    diff::check_exactly_once(&expanded, &rep),
                    diff::check_fault_contract(&expanded, &rep, base.faults),
                ] {
                    if let Err(v) = check {
                        violations.push(format!("{v} ({label})"));
                    }
                }
            }
        }
    }

    // Opt-in multi-tenant serving axis. Runs once per case — the
    // session drives the wukong sim engine internally for every
    // admitted job (each counted in `engine_runs`), independent of the
    // `--engine` filter. Every plan runs twice: the replay must be
    // byte-identical (`ServingReport` is `PartialEq` over virtual-time
    // metrics only), and every session must conserve jobs. The matrix's
    // zero-rate plan pins the empty-stream contract: nothing admitted,
    // no events, no KVS traffic.
    if opts.serving {
        for (i, plan) in corpus::arrival_matrix().into_iter().enumerate() {
            let label = format!(
                "serving {:?} rate={} gap={} jobs={}",
                plan.mode, plan.rate_per_s, plan.trace_gap_s, plan.jobs
            );
            let mut cfg = base.clone();
            cfg.arrival = plan;
            if i % 2 == 1 {
                // Alternate fairness policies across the matrix so both
                // schedulers stay under the conservation gate.
                cfg.tenants.policy = FairnessPolicy::WeightedFair;
                cfg.tenants.weight_skew = 0.5;
            }
            let (rep, rerun) = match catch_unwind(AssertUnwindSafe(|| {
                (
                    run_serving(&cfg, run_seed, 1),
                    run_serving(&cfg, run_seed, 1),
                )
            })) {
                Ok(pair) => pair,
                Err(err) => {
                    violations.push(format!(
                        "serving session panicked: {} ({label})",
                        crate::util::prop::panic_message(err.as_ref())
                    ));
                    continue;
                }
            };
            engine_runs += rep.admitted + rerun.admitted;
            if rep != rerun {
                violations.push(format!(
                    "serving replay diverged ({label})"
                ));
            }
            if !rep.conserves_jobs() {
                violations.push(format!(
                    "serving lost jobs: {} arrived, {} admitted, \
                     {} completed + {} failed ({label})",
                    rep.arrived, rep.admitted, rep.completed, rep.failed
                ));
            }
            if plan.is_empty() {
                if rep.admitted != 0
                    || rep.total_events != 0
                    || rep.kvs_bytes != 0
                    || rep.dollars != 0.0
                {
                    violations.push(format!(
                        "empty arrival plan was not a no-op ({label})"
                    ));
                }
            } else if rep.arrived != plan.jobs {
                violations.push(format!(
                    "serving stream emitted {} of {} jobs ({label})",
                    rep.arrived, plan.jobs
                ));
            }
        }
    }

    let verbose_line = format!(
        "case {case:>3}  seed {case_seed:#018x}  dag {:<10} {:>3} tasks \
         {:>3} edges  {}",
        dag.name,
        dag.len(),
        dag.n_edges(),
        if violations.is_empty() {
            "ok".to_string()
        } else {
            format!("{} VIOLATIONS", violations.len())
        }
    );
    CaseResult {
        case_seed,
        total_tasks: dag.len() as u64,
        engine_runs,
        violations,
        verbose_line,
    }
}

/// `run_case` with panics (outside the guarded engine runs — e.g. a
/// corpus-generator bug) converted into a reported violation, so a
/// pooled worker never dies holding the join counter.
fn run_case_guarded(opts: &VerifyOptions, case: u64) -> CaseResult {
    let case_seed = case_seed_of(opts.seed, case);
    catch_unwind(AssertUnwindSafe(|| run_case(opts, case))).unwrap_or_else(
        |err| CaseResult {
            case_seed,
            total_tasks: 0,
            engine_runs: 0,
            violations: vec![format!(
                "case worker panicked: {}",
                crate::util::prop::panic_message(err.as_ref())
            )],
            verbose_line: format!(
                "case {case:>3}  seed {case_seed:#018x}  PANICKED"
            ),
        },
    )
}

/// Execute the differential conformance sweep.
///
/// Errors only on invalid options (unknown engine name); invariant
/// violations are *returned in the summary*, not errors, so callers can
/// report all of them. Cases run across a thread pool (`opts.threads`,
/// 0 = auto); aggregation is case-index-ordered, so the summary is
/// byte-identical regardless of thread count. `--verbose` lines stream
/// live under `--threads 1` (inline execution) and print in case order
/// after the pooled sweep otherwise.
pub fn run_verify(opts: &VerifyOptions) -> Result<VerifySummary, String> {
    // Validate the selection up front (workers re-resolve by name).
    let engines = select_engines(&opts.engines)?;
    let engine_names: Vec<String> =
        engines.iter().map(|e| e.name().to_string()).collect();
    drop(engines);

    // `ordered_map` runs inline (streaming the per-case progress lines
    // as they happen) for threads <= 1, pooled otherwise.
    let streaming = opts.verbose && opts.threads == 1;
    let worker_opts = opts.clone();
    let results: Vec<CaseResult> =
        ordered_map(opts.runs as usize, opts.threads, move |case| {
            let r = run_case_guarded(&worker_opts, case as u64);
            if streaming {
                println!("{}", r.verbose_line);
            }
            r
        });

    // Deterministic, case-index-ordered aggregation.
    let mut summary = VerifySummary {
        cases: 0,
        engines: engine_names,
        engine_runs: 0,
        total_tasks: 0,
        violations: Vec::new(),
    };
    for (case, r) in results.into_iter().enumerate() {
        summary.cases += 1;
        summary.engine_runs += r.engine_runs;
        summary.total_tasks += r.total_tasks;
        if opts.verbose && !streaming {
            println!("{}", r.verbose_line);
        }
        for v in r.violations {
            summary.violations.push(format!(
                "case {case} (replay seed {:#x}): {v}",
                r.case_seed
            ));
        }
    }
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_sweep_is_clean() {
        let s = run_verify(&VerifyOptions {
            runs: 4,
            seed: 11,
            ..VerifyOptions::default()
        })
        .unwrap();
        assert_eq!(s.cases, 4);
        assert!(s.engines.len() >= 3);
        assert!(s.violations.is_empty(), "{:#?}", s.violations);
        // wukong knob matrix (8×2) + 4 baselines ×2, per case
        assert_eq!(s.engine_runs, 4 * (16 + 8));
    }

    #[test]
    fn faulty_sweep_is_clean_and_counts_the_fault_axis() {
        let s = run_verify(&VerifyOptions {
            runs: 3,
            seed: 17,
            faults: true,
            ..VerifyOptions::default()
        })
        .unwrap();
        assert_eq!(s.cases, 3);
        assert!(s.violations.is_empty(), "{:#?}", s.violations);
        // Base matrix (16 + 8) plus, per sim engine, one fault-free
        // reference and 8 fault plans × 2 (run + determinism re-run).
        assert_eq!(s.engine_runs, 3 * (16 + 8 + 5 * (1 + 8 * 2)));
    }

    #[test]
    fn crash_sweep_is_clean_and_counts_the_crash_axis() {
        let s = run_verify(&VerifyOptions {
            runs: 3,
            seed: 19,
            crashes: true,
            ..VerifyOptions::default()
        })
        .unwrap();
        assert_eq!(s.cases, 3);
        assert!(s.violations.is_empty(), "{:#?}", s.violations);
        // Base matrix (16 + 8) plus, per sim engine, 2 durability
        // profiles × (1 reference + 4 crash plans × 2 runs).
        assert_eq!(s.engine_runs, 3 * (16 + 8 + 5 * (2 * (1 + 4 * 2))));
    }

    #[test]
    fn serving_sweep_is_clean_and_counts_admitted_jobs() {
        let s = run_verify(&VerifyOptions {
            runs: 2,
            seed: 41,
            serving: true,
            ..VerifyOptions::default()
        })
        .unwrap();
        assert_eq!(s.cases, 2);
        assert!(s.violations.is_empty(), "{:#?}", s.violations);
        // Base matrix (16 + 8) plus the serving axis: 4 arrival plans
        // run twice, the zero-rate plan admits nothing and each live
        // plan admits all SERVING_JOBS jobs (one engine run per job).
        let per_session = 3 * corpus::SERVING_JOBS;
        assert_eq!(s.engine_runs, 2 * (16 + 8 + 2 * per_session));
    }

    #[test]
    fn parallel_and_sequential_sweeps_agree_under_serving() {
        let base = VerifyOptions {
            runs: 2,
            seed: 43,
            serving: true,
            ..VerifyOptions::default()
        };
        let seq = run_verify(&VerifyOptions {
            threads: 1,
            ..base.clone()
        })
        .unwrap();
        let par = run_verify(&VerifyOptions {
            threads: 4,
            ..base
        })
        .unwrap();
        assert_eq!(seq, par);
    }

    #[test]
    fn fault_and_crash_axes_compose() {
        let s = run_verify(&VerifyOptions {
            runs: 2,
            seed: 37,
            faults: true,
            crashes: true,
            ..VerifyOptions::default()
        })
        .unwrap();
        assert!(s.violations.is_empty(), "{:#?}", s.violations);
        assert_eq!(
            s.engine_runs,
            2 * (16 + 8 + 5 * (1 + 8 * 2) + 5 * (2 * (1 + 4 * 2)))
        );
    }

    #[test]
    fn dynamic_sweep_is_clean_and_counts_the_spawn_axis() {
        let s = run_verify(&VerifyOptions {
            runs: 2,
            seed: 13,
            dynamic: true,
            ..VerifyOptions::default()
        })
        .unwrap();
        assert_eq!(s.cases, 2);
        assert!(s.violations.is_empty(), "{:#?}", s.violations);
        // Base matrix (16 + 8) plus, per sim engine, 1 plan-free
        // reference + 4 live spawn plans × (dynamic + determinism
        // re-run + static pre-expanded) + 1 zero-rate run.
        assert_eq!(s.engine_runs, 2 * (16 + 8 + 5 * (1 + 4 * 3 + 1)));
    }

    #[test]
    fn parallel_and_sequential_sweeps_agree_under_dynamic() {
        let base = VerifyOptions {
            runs: 2,
            seed: 47,
            dynamic: true,
            ..VerifyOptions::default()
        };
        let seq = run_verify(&VerifyOptions {
            threads: 1,
            ..base.clone()
        })
        .unwrap();
        let par = run_verify(&VerifyOptions {
            threads: 4,
            ..base
        })
        .unwrap();
        assert_eq!(seq, par);
    }

    #[test]
    fn parallel_and_sequential_sweeps_agree_under_crashes() {
        let base = VerifyOptions {
            runs: 3,
            seed: 31,
            crashes: true,
            ..VerifyOptions::default()
        };
        let seq = run_verify(&VerifyOptions {
            threads: 1,
            ..base.clone()
        })
        .unwrap();
        let par = run_verify(&VerifyOptions {
            threads: 4,
            ..base
        })
        .unwrap();
        assert_eq!(seq, par);
    }

    #[test]
    fn parallel_and_sequential_sweeps_agree_under_faults() {
        let base = VerifyOptions {
            runs: 4,
            seed: 29,
            faults: true,
            ..VerifyOptions::default()
        };
        let seq = run_verify(&VerifyOptions {
            threads: 1,
            ..base.clone()
        })
        .unwrap();
        let par = run_verify(&VerifyOptions {
            threads: 4,
            ..base
        })
        .unwrap();
        assert_eq!(seq, par);
    }

    #[test]
    fn parallel_and_sequential_sweeps_agree_byte_for_byte() {
        let base = VerifyOptions {
            runs: 6,
            seed: 23,
            ..VerifyOptions::default()
        };
        let seq = run_verify(&VerifyOptions {
            threads: 1,
            ..base.clone()
        })
        .unwrap();
        let par = run_verify(&VerifyOptions {
            threads: 4,
            ..base
        })
        .unwrap();
        assert_eq!(seq, par);
    }

    #[test]
    fn unknown_engine_is_an_option_error() {
        let err = run_verify(&VerifyOptions {
            engines: vec!["warp-drive".into()],
            runs: 1,
            ..VerifyOptions::default()
        })
        .unwrap_err();
        assert!(err.contains("unknown engine"), "{err}");
        assert!(err.contains("wukong"), "{err}");
    }

    #[test]
    fn engine_filter_is_respected() {
        let s = run_verify(&VerifyOptions {
            engines: vec!["wukong".into(), "numpywren".into()],
            runs: 2,
            seed: 3,
            ..VerifyOptions::default()
        })
        .unwrap();
        assert_eq!(s.engines, vec!["wukong", "numpywren"]);
        assert!(s.violations.is_empty(), "{:#?}", s.violations);
    }

    #[test]
    fn knob_matrix_is_exhaustive() {
        let m = knob_matrix(&Config::default());
        assert_eq!(m.len(), 8);
        let on = m.iter().filter(|(_, c)| c.wukong.use_clustering).count();
        assert_eq!(on, 4);
    }
}
