//! Summary statistics over repeated runs (means, percentiles, min/max).
//!
//! The paper reports each data point as the average of ten runs with
//! min/max error bars; [`Summary`] carries exactly that.

/// Aggregate of a sample set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub std: f64,
}

impl Summary {
    /// Summarize a sample slice. Empty input yields NaNs with `n == 0`.
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary {
                n: 0,
                mean: f64::NAN,
                min: f64::NAN,
                max: f64::NAN,
                std: f64::NAN,
            };
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let (mut min, mut max) = (f64::INFINITY, f64::NEG_INFINITY);
        for &x in xs {
            min = min.min(x);
            max = max.max(x);
        }
        Summary {
            n,
            mean,
            min,
            max,
            std: var.sqrt(),
        }
    }
}

/// Percentile with linear interpolation (`p` in `[0, 100]`).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Human-readable bytes (paper figures use GB/TB scales).
pub fn human_bytes(b: f64) -> String {
    const UNITS: [&str; 6] = ["B", "KB", "MB", "GB", "TB", "PB"];
    let mut v = b;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{:.0} {}", v, UNITS[u])
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// Human-readable duration from seconds.
pub fn human_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.1} ms", s * 1e3)
    } else if s < 120.0 {
        format!("{:.2} s", s)
    } else {
        format!("{:.1} min", s / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn summary_empty_is_nan() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert!(s.mean.is_nan());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert!((percentile(&xs, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn human_bytes_scales() {
        assert_eq!(human_bytes(512.0), "512 B");
        assert_eq!(human_bytes(2048.0), "2.00 KB");
        assert!(human_bytes(3.5 * 1024.0 * 1024.0 * 1024.0).contains("GB"));
    }

    #[test]
    fn human_secs_scales() {
        assert!(human_secs(0.0000005).contains("µs"));
        assert!(human_secs(0.05).contains("ms"));
        assert!(human_secs(5.0).contains("s"));
        assert!(human_secs(300.0).contains("min"));
    }
}
