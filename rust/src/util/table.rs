//! Aligned plain-text table renderer for figure/table output.
//!
//! Every paper figure is regenerated as a table of rows/series; this
//! renders them the way the harness prints them into EXPERIMENTS.md.

/// A simple column-aligned table.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Create a table with the given column headers.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Table {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the header arity; extra/missing cells are
    /// padded to keep rendering robust).
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        self.rows.push(cells.into_iter().map(Into::into).collect());
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render as an aligned text block (also valid Markdown).
    pub fn render(&self) -> String {
        let ncols = self
            .rows
            .iter()
            .map(|r| r.len())
            .chain(std::iter::once(self.header.len()))
            .max()
            .unwrap_or(0);
        let mut widths = vec![0usize; ncols];
        let all = std::iter::once(&self.header).chain(self.rows.iter());
        for row in all {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let fmt_row = |row: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncols {
                let cell = row.get(i).map(String::as_str).unwrap_or("");
                s.push_str(&format!(" {:<w$} |", cell, w = widths[i]));
            }
            s
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["name", "value"]);
        t.row(vec!["x", "1"]);
        t.row(vec!["longer", "2.5"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        // all lines same width
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
        assert!(s.contains("| longer"));
    }

    #[test]
    fn pads_ragged_rows() {
        let mut t = Table::new(vec!["a", "b", "c"]);
        t.row(vec!["1"]);
        let s = t.render();
        assert!(s.lines().count() == 3);
    }

    #[test]
    fn markdown_separator_present() {
        let mut t = Table::new(vec!["h"]);
        t.row(vec!["v"]);
        assert!(t.render().lines().nth(1).unwrap().starts_with("|-"));
    }
}
