//! Tiny property-based testing loop (proptest is not in the offline crate
//! set).
//!
//! `check(seed, cases, f)` runs `f` against `cases` independently-seeded
//! [`Rng`]s; on failure it reports the case seed so the exact input can be
//! replayed with `replay(seed, f)`. Generators are plain functions of
//! `&mut Rng`, composed by hand — enough for the coordinator invariants in
//! `rust/tests/`.

use super::rng::Rng;

/// Best-effort panic payload → message (shared by [`check`] and the
/// verify harness's guarded engine runs).
pub fn panic_message(err: &(dyn std::any::Any + Send)) -> String {
    err.downcast_ref::<String>()
        .cloned()
        .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_else(|| "<non-string panic>".into())
}

/// Run `f` for `cases` random cases. Panics with the failing case seed.
pub fn check<F: FnMut(&mut Rng)>(seed: u64, cases: u32, mut f: F) {
    for case in 0..cases {
        let case_seed = seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case as u64);
        let mut rng = Rng::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || f(&mut rng),
        ));
        if let Err(err) = result {
            let msg = panic_message(err.as_ref());
            panic!(
                "property failed on case {case} (replay seed {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Re-run a single failing case by its reported seed.
pub fn replay<F: FnMut(&mut Rng)>(case_seed: u64, mut f: F) {
    let mut rng = Rng::new(case_seed);
    f(&mut rng);
}

/// Generator helpers.
pub mod gen {
    use super::Rng;

    /// Uniform usize in `[lo, hi]`.
    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.below((hi - lo + 1) as u64) as usize
    }

    /// A vector of length in `[lo, hi]` built from `f`.
    pub fn vec_of<T>(
        rng: &mut Rng,
        lo: usize,
        hi: usize,
        mut f: impl FnMut(&mut Rng) -> T,
    ) -> Vec<T> {
        let n = usize_in(rng, lo, hi);
        (0..n).map(|_| f(rng)).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(rng: &mut Rng, xs: &'a [T]) -> &'a T {
        &xs[rng.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check(1, 50, |rng| {
            let x = rng.below(100);
            assert!(x < 100);
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failing_case() {
        check(2, 50, |rng| {
            let x = rng.below(10);
            assert!(x != 3, "hit the bad value");
        });
    }

    #[test]
    fn gen_vec_respects_bounds() {
        check(3, 50, |rng| {
            let v = gen::vec_of(rng, 2, 8, |r| r.below(5));
            assert!((2..=8).contains(&v.len()));
        });
    }
}
