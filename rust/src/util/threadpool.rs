//! Fixed-size thread pool (tokio is not in the offline crate set).
//!
//! Used by the real engine: Lambda-executor bodies run as pool jobs, and
//! the pool size models the platform's concurrency limit. Plain
//! `std::sync::mpsc` + worker threads; jobs are `FnOnce() + Send`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size worker pool with a pending-job counter for `join`.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    inflight: Arc<(Mutex<usize>, Condvar)>,
    spawned: AtomicUsize,
}

impl ThreadPool {
    /// Create a pool with `n` worker threads (`n >= 1`).
    pub fn new(n: usize) -> ThreadPool {
        assert!(n >= 1, "pool needs at least one worker");
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let inflight = Arc::new((Mutex::new(0usize), Condvar::new()));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let inflight = Arc::clone(&inflight);
                thread::Builder::new()
                    .name(format!("wukong-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                let (lock, cv) = &*inflight;
                                let mut cnt = lock.lock().unwrap();
                                *cnt -= 1;
                                if *cnt == 0 {
                                    cv.notify_all();
                                }
                            }
                            Err(_) => break, // channel closed: shutdown
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool {
            tx: Some(tx),
            workers,
            inflight,
            spawned: AtomicUsize::new(0),
        }
    }

    /// Submit a job.
    pub fn spawn<F: FnOnce() + Send + 'static>(&self, f: F) {
        let (lock, _) = &*self.inflight;
        *lock.lock().unwrap() += 1;
        self.spawned.fetch_add(1, Ordering::Relaxed);
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("workers alive");
    }

    /// Block until every submitted job (including jobs submitted by jobs)
    /// has finished.
    pub fn join(&self) {
        let (lock, cv) = &*self.inflight;
        let mut cnt = lock.lock().unwrap();
        while *cnt > 0 {
            cnt = cv.wait(cnt).unwrap();
        }
    }

    /// Total jobs ever submitted (metrics).
    pub fn total_spawned(&self) -> usize {
        self.spawned.load(Ordering::Relaxed)
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run `f(0..n)` across a pool of `threads` workers (0 = one per
/// available core), collecting results in index order — the shared
/// scaffolding behind the parallel `wukong verify` case sweep and
/// `figures::run_many`. With one worker (or one item) the pool is
/// skipped entirely and `f` runs inline, in order. Worker-side panics
/// are caught per item (so a panicking job can never wedge `join`) and
/// re-raised on the calling thread after the pool drains; output is
/// identical to a sequential run regardless of thread count.
pub fn ordered_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send + 'static,
    F: Fn(usize) -> T + Send + Sync + 'static,
{
    let threads = if threads == 0 {
        std::thread::available_parallelism()
            .map(|x| x.get())
            .unwrap_or(1)
    } else {
        threads
    };
    let threads = threads.min(n).max(1);
    if threads <= 1 {
        return (0..n).map(f).collect();
    }

    type Slot<T> = Option<std::thread::Result<T>>;
    let slots: Arc<Mutex<Vec<Slot<T>>>> =
        Arc::new(Mutex::new((0..n).map(|_| None).collect()));
    let f = Arc::new(f);
    let pool = ThreadPool::new(threads);
    for i in 0..n {
        let slots = Arc::clone(&slots);
        let f = Arc::clone(&f);
        pool.spawn(move || {
            let r = std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(|| f(i)),
            );
            slots.lock().unwrap()[i] = Some(r);
        });
    }
    pool.join();
    drop(pool); // workers exit; every job's Arc clones are dropped
    Arc::try_unwrap(slots)
        .ok()
        .expect("pool joined; no worker holds the slots")
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|s| match s.expect("every item produced a result") {
            Ok(v) => v,
            Err(p) => std::panic::resume_unwind(p),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn jobs_can_spawn_jobs() {
        let pool = Arc::new(ThreadPool::new(4));
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            let p = Arc::clone(&pool);
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let c2 = Arc::clone(&c);
                p.spawn(move || {
                    c2.fetch_add(1, Ordering::SeqCst);
                });
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 20);
    }

    #[test]
    fn join_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.join();
    }

    #[test]
    fn single_worker_serializes() {
        let pool = ThreadPool::new(1);
        let log = Arc::new(Mutex::new(Vec::new()));
        for i in 0..20 {
            let log = Arc::clone(&log);
            pool.spawn(move || log.lock().unwrap().push(i));
        }
        pool.join();
        assert_eq!(*log.lock().unwrap(), (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn ordered_map_preserves_index_order() {
        for threads in [1, 4] {
            let out = ordered_map(50, threads, |i| i * 3);
            assert_eq!(out, (0..50).map(|i| i * 3).collect::<Vec<_>>());
        }
    }

    #[test]
    fn ordered_map_handles_empty_and_single() {
        assert_eq!(ordered_map(0, 4, |i| i), Vec::<usize>::new());
        assert_eq!(ordered_map(1, 4, |i| i + 7), vec![7]);
    }

    #[test]
    fn ordered_map_rethrows_worker_panics_without_wedging() {
        let r = std::panic::catch_unwind(|| {
            ordered_map(8, 4, |i| {
                if i == 5 {
                    panic!("boom at {i}");
                }
                i
            })
        });
        assert!(r.is_err(), "panic must propagate to the caller");
    }
}
