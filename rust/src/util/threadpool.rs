//! Fixed-size thread pool (tokio is not in the offline crate set).
//!
//! Used by the real engine: Lambda-executor bodies run as pool jobs, and
//! the pool size models the platform's concurrency limit. Plain
//! `std::sync::mpsc` + worker threads; jobs are `FnOnce() + Send`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size worker pool with a pending-job counter for `join`.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    inflight: Arc<(Mutex<usize>, Condvar)>,
    spawned: AtomicUsize,
}

impl ThreadPool {
    /// Create a pool with `n` worker threads (`n >= 1`).
    pub fn new(n: usize) -> ThreadPool {
        assert!(n >= 1, "pool needs at least one worker");
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let inflight = Arc::new((Mutex::new(0usize), Condvar::new()));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let inflight = Arc::clone(&inflight);
                thread::Builder::new()
                    .name(format!("wukong-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                let (lock, cv) = &*inflight;
                                let mut cnt = lock.lock().unwrap();
                                *cnt -= 1;
                                if *cnt == 0 {
                                    cv.notify_all();
                                }
                            }
                            Err(_) => break, // channel closed: shutdown
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool {
            tx: Some(tx),
            workers,
            inflight,
            spawned: AtomicUsize::new(0),
        }
    }

    /// Submit a job.
    pub fn spawn<F: FnOnce() + Send + 'static>(&self, f: F) {
        let (lock, _) = &*self.inflight;
        *lock.lock().unwrap() += 1;
        self.spawned.fetch_add(1, Ordering::Relaxed);
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("workers alive");
    }

    /// Block until every submitted job (including jobs submitted by jobs)
    /// has finished.
    pub fn join(&self) {
        let (lock, cv) = &*self.inflight;
        let mut cnt = lock.lock().unwrap();
        while *cnt > 0 {
            cnt = cv.wait(cnt).unwrap();
        }
    }

    /// Total jobs ever submitted (metrics).
    pub fn total_spawned(&self) -> usize {
        self.spawned.load(Ordering::Relaxed)
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn jobs_can_spawn_jobs() {
        let pool = Arc::new(ThreadPool::new(4));
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            let p = Arc::clone(&pool);
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let c2 = Arc::clone(&c);
                p.spawn(move || {
                    c2.fetch_add(1, Ordering::SeqCst);
                });
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 20);
    }

    #[test]
    fn join_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.join();
    }

    #[test]
    fn single_worker_serializes() {
        let pool = ThreadPool::new(1);
        let log = Arc::new(Mutex::new(Vec::new()));
        for i in 0..20 {
            let log = Arc::clone(&log);
            pool.spawn(move || log.lock().unwrap().push(i));
        }
        pool.join();
        assert_eq!(*log.lock().unwrap(), (0..20).collect::<Vec<_>>());
    }
}
