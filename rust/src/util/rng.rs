//! Deterministic pseudo-random number generator (splitmix64 + xoshiro256**).
//!
//! The simulator must be bit-reproducible across runs and platforms — a
//! fixed seed yields a fixed event trace — so we carry our own generator
//! instead of depending on `rand`.

/// Deterministic RNG; `xoshiro256**` seeded via `splitmix64`.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (for per-entity RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire-style rejection-free-enough reduction; bias is negligible
        // for simulator purposes but we reject to keep tests honest.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal sample with given median and sigma (latency jitter).
    pub fn lognormal(&mut self, median: f64, sigma: f64) -> f64 {
        median * (sigma * self.normal()).exp()
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Random f32 vector (for real-engine synthetic inputs).
    pub fn f32_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| (self.f64() * 2.0 - 1.0) as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(5);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let xs: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..10).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
