//! Small in-repo substrates that would normally be external crates.
//!
//! The offline crate set only contains `xla` + `anyhow`, so the RNG,
//! JSON parser, table renderer, stats helpers, property-test loop and
//! thread pool live here.

pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
pub mod threadpool;

pub use rng::Rng;
