//! Minimal JSON parser + writer (serde is not in the offline crate set).
//!
//! Parses the artifact `manifest.json` emitted by `python/compile/aot.py`
//! and serializes figure results. Supports the full JSON grammar except
//! `\u` surrogate pairs beyond the BMP (not needed for our data).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.b
                                    .get(self.i..self.i + 4)
                                    .ok_or("short \\u escape")?,
                            )
                            .map_err(|e| e.to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|e| e.to_string())?;
                            self.i += 4;
                            s.push(
                                char::from_u32(code).ok_or("bad \\u codepoint")?,
                            );
                        }
                        other => {
                            return Err(format!("bad escape \\{}", other as char))
                        }
                    }
                }
                Some(_) => {
                    // Copy a UTF-8 run verbatim.
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
            {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .map_err(|e| e.to_string())?
            .parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number at {start}: {e}"))
    }
}

/// Escape + quote a string for JSON output.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write!(f, "{}", escape(s)),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", escape(k), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse(r#""a\nb""#).unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"ops": {"x": {"shape": [1, 2], "ok": true}}}"#)
            .unwrap();
        let shape = j.get("ops").unwrap().get("x").unwrap().get("shape").unwrap();
        assert_eq!(shape.as_arr().unwrap().len(), 2);
        assert_eq!(shape.as_arr().unwrap()[1].as_u64(), Some(2));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""Aé""#).unwrap(),
            Json::Str("Aé".into())
        );
    }

    #[test]
    fn roundtrip_display() {
        let src = r#"{"a":[1,2.5,"x"],"b":{"c":null,"d":false}}"#;
        let j = Json::parse(src).unwrap();
        let again = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "format": "hlo-text",
          "return_tuple": true,
          "ops": {
            "tr_add_f32_8192": {
              "file": "tr_add_f32_8192.hlo.txt",
              "inputs": [{"shape": [8192], "dtype": "float32"}],
              "outputs": [{"shape": [8192], "dtype": "float32"}],
              "flops": 8192
            }
          }
        }"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("format").unwrap().as_str(), Some("hlo-text"));
        let op = j.get("ops").unwrap().get("tr_add_f32_8192").unwrap();
        assert_eq!(op.get("flops").unwrap().as_u64(), Some(8192));
    }
}
