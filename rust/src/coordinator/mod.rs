//! The paper's contribution: Wukong's decentralized, locality-aware
//! scheduling (§3).
//!
//! * [`static_schedule`] — §3.2: per-leaf DAG subgraphs computed by DFS.
//! * [`policy`] — §3.3: the pure becomes/invokes + clustering + delayed-I/O
//!   decision rules, shared verbatim by the simulator and the real engine.
//! * [`sim_engine`] — the discrete-event Wukong driver used for every
//!   paper figure.

pub mod policy;
pub mod sim_engine;
pub mod static_schedule;

pub use policy::{ChildClass, DispatchPlan};
pub use sim_engine::{run_wukong, WukongReport};
pub use static_schedule::{generate_schedules, StaticSchedule};
