//! Pure dynamic-scheduling decision rules (§3.3) — engine-agnostic.
//!
//! After an executor finishes task `T`, it must decide, for each out-edge,
//! whether to **become** the target's executor, **invoke** a new executor,
//! **delegate** a wide fan-out to the invoker pool, **cluster** targets
//! locally (large output), or **delay I/O** for unready fan-in targets.
//! These rules are pure functions over dependency-availability facts so
//! that both the simulator and the real engine execute byte-identical
//! policy, and so they can be unit/property-tested in isolation.

use crate::dag::{Dag, TaskId};

/// How one child of a finished task is classified.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChildClass {
    /// All dependencies satisfied by us — we may run or hand it off.
    Ready,
    /// Fan-in child whose other inputs are not all available yet.
    NotReady,
    /// Another executor already owns this child.
    Claimed,
}

/// Dispatch decision for a finished task's out-edges.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DispatchPlan {
    /// Child the executor *becomes* (runs next, locally, zero I/O).
    pub becomes: Option<TaskId>,
    /// Children to run locally after `becomes` (task clustering).
    pub cluster_local: Vec<TaskId>,
    /// Children to hand to freshly invoked executors.
    pub invoke: Vec<TaskId>,
    /// Whether `invoke` should go through the proxy's invoker pool.
    pub delegate: bool,
    /// Unready fan-in children to re-check under delayed I/O.
    pub delay_watch: Vec<TaskId>,
    /// Must the output object be written to the KVS now?
    pub must_store: bool,
}

/// Policy knobs (mirrors `config::WukongConfig` without the sim deps).
#[derive(Debug, Clone, Copy)]
pub struct PolicyKnobs {
    pub clustering_threshold: u64,
    pub use_clustering: bool,
    pub use_delayed_io: bool,
    pub fanout_delegation_threshold: usize,
    pub arg_inline_max: u64,
}

/// Build the dispatch plan for task `t`'s children.
///
/// `classify(c)` reports each child's availability as seen *after* this
/// executor's own contribution is (or would be) counted; the caller is
/// responsible for the atomic counter protocol — this function only turns
/// availability facts into scheduling actions.
pub fn plan_dispatch(
    dag: &Dag,
    t: TaskId,
    out_bytes: u64,
    knobs: &PolicyKnobs,
    classify: impl Fn(TaskId) -> ChildClass,
) -> DispatchPlan {
    let children = dag.children(t);
    let mut plan = DispatchPlan::default();
    if children.is_empty() {
        // Sink: final results are always stored + published.
        plan.must_store = true;
        return plan;
    }

    let mut ready = Vec::new();
    let mut not_ready = Vec::new();
    for &c in children {
        match classify(c) {
            ChildClass::Ready => ready.push(c),
            ChildClass::NotReady => not_ready.push(c),
            ChildClass::Claimed => {}
        }
    }

    let big = knobs.use_clustering && out_bytes > knobs.clustering_threshold;
    if big {
        // Task clustering (§3.3): execute every ready target locally to
        // avoid moving the large object; watch unready ones (delayed I/O).
        plan.becomes = ready.first().copied();
        plan.cluster_local = ready.iter().skip(1).copied().collect();
        if knobs.use_delayed_io {
            plan.delay_watch = not_ready.clone();
            // Store only if nothing can be delayed and remote consumers
            // exist anyway (handled by the engine when delay expires).
            plan.must_store = false;
        } else {
            // No delayed I/O: unready fan-ins force the store right away.
            plan.must_store = !not_ready.is_empty();
        }
        return plan;
    }

    // Normal (small-output) fan-out: become one ready target, invoke
    // executors for the rest (Case 1/2 of §3.3).
    plan.becomes = ready.first().copied();
    plan.invoke = ready.iter().skip(1).copied().collect();
    plan.delegate = plan.invoke.len() >= knobs.fanout_delegation_threshold.max(1);
    // The object must be stored if any unready fan-in child will be run by
    // another executor later, or if invoked executors cannot take the
    // object inline.
    let inline_ok = out_bytes <= knobs.arg_inline_max;
    plan.must_store = !not_ready.is_empty() || (!plan.invoke.is_empty() && !inline_ok);
    plan
}

/// Fan-in availability classification from a dependency counter: given a
/// child with `indegree` inputs of which `avail` are available *including
/// ours*, is the child ready?
pub fn fanin_ready(avail: u32, indegree: usize) -> bool {
    avail as usize == indegree
}

/// Delayed-I/O hold: we keep our (large) input unavailable; the child can
/// be claimed by us the moment all *other* inputs are available.
pub fn holdout_ready(avail_others: u32, indegree: usize) -> bool {
    avail_others as usize == indegree - 1
}

/// Holder election for delayed I/O: at most ONE parent of a fan-in may
/// hold its object back, or two large-output parents deadlock each other
/// until their retry budgets expire (both waiting to see `n-1`). The
/// holder is the parent producing the largest object (ties broken by
/// task id) — everyone else stores + increments immediately, so the
/// holder's recheck converges after a single store latency instead of a
/// full timeout. Deterministic and computable from the DAG alone, so the
/// simulator and the real engine elect identically without coordination.
pub fn should_hold(dag: &Dag, t: TaskId, child: TaskId) -> bool {
    let mine = (dag.task(t).out_bytes, t);
    dag.parents(child)
        .iter()
        .all(|&p| p == t || (dag.task(p).out_bytes, p) <= mine)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::{DagBuilder, OpKind};

    fn knobs() -> PolicyKnobs {
        PolicyKnobs {
            clustering_threshold: 1000,
            use_clustering: true,
            use_delayed_io: true,
            fanout_delegation_threshold: 4,
            arg_inline_max: 256,
        }
    }

    /// root -> {a, b, c}; d is a fan-in of a+b.
    fn fanout_dag() -> Dag {
        let mut b = DagBuilder::new("t");
        let root = b.task("root", OpKind::Generic, 1.0, 10);
        let a = b.task("a", OpKind::Generic, 1.0, 10);
        let x = b.task("b", OpKind::Generic, 1.0, 10);
        let c = b.task("c", OpKind::Generic, 1.0, 10);
        let d = b.task("d", OpKind::Generic, 1.0, 10);
        b.edge(root, a).edge(root, x).edge(root, c);
        b.edge(a, d).edge(x, d);
        b.build().unwrap()
    }

    #[test]
    fn small_fanout_becomes_first_invokes_rest() {
        let dag = fanout_dag();
        let plan = plan_dispatch(&dag, 0, 100, &knobs(), |_| ChildClass::Ready);
        assert_eq!(plan.becomes, Some(1));
        assert_eq!(plan.invoke, vec![2, 3]);
        assert!(!plan.delegate);
        assert!(plan.cluster_local.is_empty());
        // all children ready, object fits inline -> no store needed
        assert!(!plan.must_store);
    }

    #[test]
    fn large_output_clusters_locally() {
        let dag = fanout_dag();
        let plan =
            plan_dispatch(&dag, 0, 10_000, &knobs(), |_| ChildClass::Ready);
        assert_eq!(plan.becomes, Some(1));
        assert_eq!(plan.cluster_local, vec![2, 3]);
        assert!(plan.invoke.is_empty());
        assert!(!plan.must_store); // nothing leaves this executor
    }

    #[test]
    fn clustering_disabled_falls_back_to_invokes() {
        let dag = fanout_dag();
        let mut k = knobs();
        k.use_clustering = false;
        let plan = plan_dispatch(&dag, 0, 10_000, &k, |_| ChildClass::Ready);
        assert!(plan.cluster_local.is_empty());
        assert_eq!(plan.invoke.len(), 2);
        // 10_000 > arg_inline_max -> invoked executors need the KVS copy
        assert!(plan.must_store);
    }

    #[test]
    fn unready_fanin_forces_store_when_small() {
        let dag = fanout_dag();
        let plan = plan_dispatch(&dag, 1, 100, &knobs(), |_| {
            ChildClass::NotReady
        });
        assert_eq!(plan.becomes, None);
        assert!(plan.must_store);
    }

    #[test]
    fn unready_fanin_watched_when_large() {
        let dag = fanout_dag();
        let plan = plan_dispatch(&dag, 1, 10_000, &knobs(), |_| {
            ChildClass::NotReady
        });
        assert_eq!(plan.delay_watch, vec![4]);
        assert!(!plan.must_store); // delayed I/O: hold the object
    }

    #[test]
    fn delayed_io_disabled_stores_immediately() {
        let dag = fanout_dag();
        let mut k = knobs();
        k.use_delayed_io = false;
        let plan =
            plan_dispatch(&dag, 1, 10_000, &k, |_| ChildClass::NotReady);
        assert!(plan.delay_watch.is_empty());
        assert!(plan.must_store);
    }

    #[test]
    fn wide_fanout_delegates() {
        let mut b = DagBuilder::new("wide");
        let root = b.task("root", OpKind::Generic, 1.0, 10);
        let kids: Vec<_> = (0..10)
            .map(|i| b.task(format!("k{i}"), OpKind::Generic, 1.0, 10))
            .collect();
        for &k in &kids {
            b.edge(root, k);
        }
        let dag = b.build().unwrap();
        let plan = plan_dispatch(&dag, 0, 100, &knobs(), |_| ChildClass::Ready);
        assert_eq!(plan.invoke.len(), 9);
        assert!(plan.delegate);
    }

    #[test]
    fn claimed_children_are_skipped() {
        let dag = fanout_dag();
        let plan =
            plan_dispatch(&dag, 0, 100, &knobs(), |_| ChildClass::Claimed);
        assert_eq!(plan, DispatchPlan::default());
    }

    #[test]
    fn sink_always_stores() {
        let dag = fanout_dag();
        let plan = plan_dispatch(&dag, 4, 100, &knobs(), |_| unreachable!());
        assert!(plan.must_store);
    }

    #[test]
    fn fanin_counter_rules() {
        assert!(fanin_ready(3, 3));
        assert!(!fanin_ready(2, 3));
        assert!(holdout_ready(2, 3));
        assert!(!holdout_ready(1, 3));
    }

    #[test]
    fn exactly_one_holder_per_fanin() {
        // equal-size parents: the higher task id holds, the other stores
        let mut b = DagBuilder::new("hold");
        let p0 = b.task("p0", OpKind::Generic, 1.0, 5000);
        let p1 = b.task("p1", OpKind::Generic, 1.0, 5000);
        let c = b.task("c", OpKind::Generic, 1.0, 10);
        b.edge(p0, c).edge(p1, c);
        let dag = b.build().unwrap();
        assert!(!should_hold(&dag, p0, c));
        assert!(should_hold(&dag, p1, c));
    }

    #[test]
    fn largest_object_holds() {
        // a big Q panel beats a small path-product regardless of id order
        let mut b = DagBuilder::new("hold2");
        let q = b.task("q", OpKind::Generic, 1.0, 2_000_000);
        let prod = b.task("prod", OpKind::Generic, 1.0, 65_536);
        let c = b.task("apply", OpKind::Generic, 1.0, 10);
        b.edge(q, c).edge(prod, c);
        let dag = b.build().unwrap();
        assert!(should_hold(&dag, q, c));
        assert!(!should_hold(&dag, prod, c));
    }
}
