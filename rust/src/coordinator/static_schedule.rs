//! Static schedule generation (§3.2).
//!
//! For a DAG with `n` leaf nodes, `n` static schedules are generated; the
//! schedule for leaf `L` contains every task reachable from `L` (computed
//! by DFS) plus all edges into and out of those nodes. Schedules may
//! overlap — dynamic scheduling (fan-in counters) resolves ownership at
//! runtime. Task-to-processor mapping is *not* in the schedule; the
//! platform does that at invocation time.

use crate::dag::{Dag, TaskId};

/// One leaf's static schedule.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StaticSchedule {
    /// The leaf task this schedule starts from.
    pub leaf: TaskId,
    /// All tasks reachable from `leaf`, DFS preorder (leaf first).
    pub tasks: Vec<TaskId>,
}

impl StaticSchedule {
    pub fn contains(&self, t: TaskId) -> bool {
        self.tasks.contains(&t)
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }
}

/// Generate one static schedule per DAG leaf.
pub fn generate_schedules(dag: &Dag) -> Vec<StaticSchedule> {
    dag.leaves()
        .iter()
        .map(|&leaf| StaticSchedule {
            leaf,
            tasks: dag.reachable_from(leaf),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::{DagBuilder, OpKind};

    /// The paper's Fig. 6 DAG: two leaves (T1, T5), fan-out at T1/T3,
    /// fan-in at T4/T7.
    fn fig6() -> Dag {
        let mut b = DagBuilder::new("fig6");
        let t1 = b.task("T1", OpKind::Generic, 1.0, 8);
        let t2 = b.task("T2", OpKind::Generic, 1.0, 8);
        let t3 = b.task("T3", OpKind::Generic, 1.0, 8);
        let t4 = b.task("T4", OpKind::Generic, 1.0, 8);
        let t5 = b.task("T5", OpKind::Generic, 1.0, 8);
        let t6 = b.task("T6", OpKind::Generic, 1.0, 8);
        let t7 = b.task("T7", OpKind::Generic, 1.0, 8);
        b.edge(t1, t2)
            .edge(t2, t3)
            .edge(t3, t4)
            .edge(t3, t6)
            .edge(t5, t4)
            .edge(t4, t7)
            .edge(t6, t7);
        b.build().unwrap()
    }

    #[test]
    fn one_schedule_per_leaf() {
        let dag = fig6();
        let scheds = generate_schedules(&dag);
        assert_eq!(scheds.len(), 2);
        assert_eq!(scheds[0].leaf, 0); // T1
        assert_eq!(scheds[1].leaf, 4); // T5
    }

    #[test]
    fn schedule_is_reachable_closure() {
        let dag = fig6();
        let scheds = generate_schedules(&dag);
        // From T1: T1 T2 T3 T4 T6 T7 (not T5)
        assert_eq!(scheds[0].len(), 6);
        assert!(!scheds[0].contains(4));
        // From T5: T5 T4 T7
        assert_eq!(scheds[1].tasks, vec![4, 3, 6]);
    }

    #[test]
    fn schedules_may_overlap_at_fanins() {
        let dag = fig6();
        let scheds = generate_schedules(&dag);
        // T4 and T7 appear in both schedules.
        assert!(scheds[0].contains(3) && scheds[1].contains(3));
        assert!(scheds[0].contains(6) && scheds[1].contains(6));
    }

    #[test]
    fn union_of_schedules_covers_dag() {
        let dag = fig6();
        let scheds = generate_schedules(&dag);
        let mut covered = vec![false; dag.len()];
        for s in &scheds {
            for &t in &s.tasks {
                covered[t as usize] = true;
            }
        }
        assert!(covered.iter().all(|&c| c));
    }

    #[test]
    fn leaf_comes_first() {
        let dag = fig6();
        for s in generate_schedules(&dag) {
            assert_eq!(s.tasks[0], s.leaf);
        }
    }
}
