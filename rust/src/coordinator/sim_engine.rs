//! The discrete-event Wukong engine: decentralized executors over the
//! Lambda/KVS/MDS substrates (§3.3–§3.4).
//!
//! Every executor is an entity in the DES world; its life cycle is
//! `invoke → begin → (fetch → compute → dispatch)* → return`. Dispatch
//! runs the pure [`super::policy`] rules; fan-in ownership is decided by
//! atomic MDS counter increments (exact in the DES because events are
//! serialized); task clustering and delayed I/O keep large objects
//! resident in the producing executor.
//!
//! Data availability is tracked as *times*, not bytes: a consumer's read
//! of object `o` completes no earlier than the producer's write of `o`
//! (`avail_at`), which models the blocking-poll reads of the real system.
//!
//! Hot-path layout: the world *borrows* the DAG and config (no per-run
//! clone), adjacency is read straight from the DAG's CSR slices, and the
//! calendar carries the typed [`Ev`] enum — zero allocations per event —
//! so million-task DAGs run at millions of events/sec (`wukong bench`).

use std::collections::{HashSet, VecDeque};

use crate::config::Config;
use crate::dag::{Dag, SpawnState, TaskId, TaskNode};
use crate::metrics::{RunMetrics, TaskOutcome};
use crate::platform::faults::{FaultPlan, FaultStream};
use crate::platform::LambdaService;
use crate::sim::{
    secs, to_secs, FifoResource, Handler, Sim, TaskScratch, Time,
};
use crate::storage::{InvokerPool, KvsModel, MdsModel};
use crate::util::Rng;

use super::policy::{fanin_ready, holdout_ready, should_hold, PolicyKnobs};
use super::static_schedule::generate_schedules;

/// Result of one simulated Wukong run (the shared sim-report shape).
pub type WukongReport = crate::metrics::SimReport;

type ExecId = usize;

/// Typed calendar events — plain data, dispatched by the engine; no
/// per-event heap closure.
enum Ev {
    /// Executor `eid` starts running (fault check + first task).
    Begin(ExecId),
    /// Executor `eid` pulls the next task off its local queue.
    Process(ExecId),
    /// Executor `eid` finished computing `task`.
    Finish { eid: ExecId, task: TaskId },
    /// A sink's publish message reached the scheduler's subscriber.
    SinkPublished,
    /// Delayed-I/O recheck of fan-in `child` held by `eid` (producer of
    /// `task`).
    Recheck {
        eid: ExecId,
        task: TaskId,
        child: TaskId,
        retries_left: u32,
    },
    /// A delayed-I/O hold on `eid` resolved.
    ResolveHold(ExecId),
}

struct Exec {
    queue: VecDeque<TaskId>,
    /// Parent outputs resident in this executor (incl. inline args).
    /// A set, not a dense bitmap: executors touch O(schedule) tasks, and
    /// a per-executor Vec<bool> of DAG size costs O(execs × tasks) memory
    /// (100 MB churn on the 10k-Lambda sweeps — see EXPERIMENTS §Perf).
    cache: HashSet<TaskId>,
    nic: FifoResource,
    started: Time,
    pending_holds: usize,
    idle: bool,
    ended: bool,
    attempt: u32,
    first_task: TaskId,
}

struct World<'a> {
    cfg: &'a Config,
    knobs: PolicyKnobs,
    dag: &'a Dag,
    kvs: KvsModel,
    mds: MdsModel,
    lambda: LambdaService,
    pool: InvokerPool,
    execs: Vec<Exec>,
    /// Per-task scratch arena (claimed/stored flags, exec + attempt
    /// counters, output-availability clock) — one allocation instead of
    /// the five `Vec`s this engine carried before PR 9. The engine
    /// fail-fasts on a second execution of any task, and `wukong
    /// verify` independently asserts every `executed` entry is 1.
    scratch: TaskScratch,
    metrics: RunMetrics,
    sinks_done: usize,
    n_sinks: usize,
    finish: Option<Time>,
    /// Dedicated fault RNG stream: failure draws never touch the main
    /// run RNG, so `p_fail = 0` runs are bit-identical to fault-free.
    faults: FaultStream,
    /// Tasks whose own retry budget was exhausted (§3.6 failure report);
    /// everything downstream cascades to `Failed` at finalize.
    direct_failed: Vec<TaskId>,
    /// Runtime-spawning state (`cfg.spawn`): which tasks emit child
    /// subtasks on completion, with staged ids pre-laid-out so the run
    /// is byte-identical to the pre-expanded static DAG. Inert plans
    /// cost one branch per completion.
    spawn: SpawnState,
}

impl Handler for World<'_> {
    type Ev = Ev;

    fn handle(&mut self, sim: &mut Sim<Ev>, ev: Ev) {
        match ev {
            Ev::Begin(eid) => begin(self, sim, eid),
            Ev::Process(eid) => process(self, sim, eid),
            Ev::Finish { eid, task } => finish_task(self, sim, eid, task),
            Ev::SinkPublished => {
                self.sinks_done += 1;
                if self.sinks_done == self.n_sinks {
                    self.finish = Some(sim.now());
                }
            }
            Ev::Recheck {
                eid,
                task,
                child,
                retries_left,
            } => recheck(self, sim, eid, task, child, retries_left),
            Ev::ResolveHold(eid) => resolve_hold(self, sim, eid),
        }
    }
}

impl World<'_> {
    /// Task node, spawn-aware: staged (runtime-spawned) ids resolve
    /// through the spawn state; base ids through the DAG.
    fn node(&self, t: TaskId) -> TaskNode {
        if self.spawn.is_staged(t) {
            self.spawn.node(t)
        } else {
            *self.dag.task(t)
        }
    }

    fn compute_time(&self, t: TaskId) -> Time {
        let node = self.node(t);
        match node.dur_override {
            Some(d) => d + secs(self.cfg.compute.task_overhead_s),
            None => {
                secs(node.flops / (self.cfg.lambda.gflops * 1e9)
                    + self.cfg.compute.task_overhead_s)
            }
        }
    }

    fn serde_time(&self, bytes: u64) -> Time {
        secs(bytes as f64 / self.cfg.compute.serde_bw)
    }

    /// Sequential KVS read of `bytes` for object key `key`, not before
    /// `floor` (producer's write completion). Returns completion time.
    fn kvs_read(&mut self, eid: ExecId, at: Time, key: u64, bytes: u64, floor: Time) -> Time {
        let shard_end = self.kvs.read(at, key, bytes);
        let (_, nic_end) = self.execs[eid]
            .nic
            .acquire(at, secs(bytes as f64 / self.cfg.lambda.net_bw));
        let end = shard_end.max(nic_end).max(floor);
        self.metrics.breakdown.kvs_read_s += to_secs(end.saturating_sub(at));
        end
    }

    fn kvs_write(&mut self, eid: ExecId, at: Time, key: u64, bytes: u64) -> Time {
        let shard_end = self.kvs.write(at, key, bytes);
        let (_, nic_end) = self.execs[eid]
            .nic
            .acquire(at, secs(bytes as f64 / self.cfg.lambda.net_bw));
        let end = shard_end.max(nic_end);
        self.metrics.breakdown.kvs_write_s += to_secs(end.saturating_sub(at));
        end
    }
}

/// Spawn a new executor whose schedule starts at `task`; `inline` carries
/// parent outputs passed as invocation arguments (§3.3's 256 KB rule).
fn spawn(
    w: &mut World<'_>,
    sim: &mut Sim<Ev>,
    task: TaskId,
    inline: Vec<TaskId>,
    start_at: Time,
    attempt: u32,
) {
    let eid = w.execs.len();
    let cache: HashSet<TaskId> = inline.iter().copied().collect();
    w.execs.push(Exec {
        queue: VecDeque::from([task]),
        cache,
        nic: FifoResource::new(),
        started: start_at,
        pending_holds: 0,
        idle: false,
        ended: false,
        attempt,
        first_task: task,
    });
    w.metrics.executors_used += 1;
    sim.at(start_at, Ev::Begin(eid));
}

fn begin(w: &mut World<'_>, sim: &mut Sim<Ev>, eid: ExecId) {
    w.execs[eid].started = sim.now();
    w.metrics.timeline.add(sim.now(), 1);
    // Fault injection: a failing attempt dies immediately after start and
    // is retried by the platform (§3.6), up to the retry budget.
    if w.faults.attempt_fails() {
        let attempt = w.execs[eid].attempt;
        let task = w.execs[eid].first_task;
        w.scratch.slot_mut(task).attempts += 1;
        let inline: Vec<TaskId> = w.execs[eid].cache.iter().copied().collect();
        end_exec(w, sim, eid);
        if w.faults.plan().can_retry(attempt) {
            let inv = w.lambda.invoke(sim.now());
            spawn(w, sim, task, inline, inv.start_at, attempt + 1);
        } else {
            w.metrics.failed_executors += 1; // job is failed (§3.6)
            w.direct_failed.push(task);
        }
        return;
    }
    process(w, sim, eid);
}

/// Drive the executor's local queue.
fn process(w: &mut World<'_>, sim: &mut Sim<Ev>, eid: ExecId) {
    if w.execs[eid].ended {
        return;
    }
    let Some(t) = w.execs[eid].queue.pop_front() else {
        if w.execs[eid].pending_holds == 0 {
            end_exec(w, sim, eid);
        } else {
            w.execs[eid].idle = true; // waiting on delayed-I/O rechecks
        }
        return;
    };
    w.execs[eid].idle = false;
    w.scratch.slot_mut(t).attempts += 1;

    // Fetch phase: sequential reads of non-resident parent outputs.
    // (`dag` is an independent shared borrow: the CSR parent slice is
    // iterated directly while the world mutates — no clone.) Staged
    // tasks have exactly one parent — their spawner — read through a
    // stack-local slice so the loop body is shared.
    let dag = w.dag;
    let mut cursor = sim.now();
    let pbuf;
    let parents: &[TaskId] = if w.spawn.is_staged(t) {
        pbuf = [w.spawn.parent_of(t)];
        &pbuf
    } else {
        dag.parents(t)
    };
    for &p in parents {
        if w.execs[eid].cache.contains(&p) {
            continue;
        }
        let bytes = w.node(p).out_bytes;
        let floor = w.scratch.slot(p).avail_at;
        cursor = w.kvs_read(eid, cursor, TaskNode::obj_key(p), bytes, floor);
        let sd = w.serde_time(bytes);
        w.metrics.breakdown.serde_s += to_secs(sd);
        cursor += sd;
        w.execs[eid].cache.insert(p);
    }
    // External input partition (leaf tasks; staged tasks carry none).
    let ext = w.node(t).input_bytes;
    if ext > 0 {
        cursor = w.kvs_read(eid, cursor, TaskNode::input_key(t), ext, 0);
        let sd = w.serde_time(ext);
        w.metrics.breakdown.serde_s += to_secs(sd);
        cursor += sd;
    }

    // Compute phase.
    let d = w.compute_time(t);
    w.metrics.breakdown.execute_s += to_secs(d);
    cursor += d;
    sim.at(cursor, Ev::Finish { eid, task: t });
}

fn finish_task(w: &mut World<'_>, sim: &mut Sim<Ev>, eid: ExecId, t: TaskId) {
    w.scratch.slot_mut(t).executed += 1;
    assert!(w.scratch.slot(t).executed == 1, "task {t} executed twice");
    w.metrics.tasks_executed += 1;
    w.execs[eid].cache.insert(t);

    // Runtime spawning: a completing task may emit child subtasks, which
    // enter dispatch exactly as if declared up front (sealed-DAG child
    // order is base children first, then staged — dispatch preserves it).
    let spawned = w.spawn.spawned_children(t);
    let childless = spawned.is_empty()
        && (w.spawn.is_staged(t) || w.dag.children(t).is_empty());
    if childless {
        publish_final(w, sim, eid, t);
    } else {
        dispatch(w, sim, eid, t, &spawned);
    }
}

/// Final results are stored and relayed to the scheduler's subscriber.
fn publish_final(w: &mut World<'_>, sim: &mut Sim<Ev>, eid: ExecId, t: TaskId) {
    let bytes = w.node(t).out_bytes;
    let end = w.kvs_write(eid, sim.now(), TaskNode::obj_key(t), bytes);
    let slot = w.scratch.slot_mut(t);
    slot.avail_at = end;
    slot.set_stored();
    let (_, msg_end) = w.mds.incr(end, 0xF1AA_0000_0000_0000 | t as u64);
    w.metrics.breakdown.publish_s += to_secs(msg_end.saturating_sub(end));
    sim.at(msg_end, Ev::SinkPublished);
    sim.at(end, Ev::Process(eid));
}

/// Dynamic scheduling after task `t` (§3.3): becomes / invokes /
/// clustering / delayed I/O, with fan-in ownership via MDS counters.
/// `spawned` carries `t`'s runtime-spawned children; they flow through
/// every branch after the base children, matching the sealed DAG's child
/// order. Spawned children have in-degree 1 (their spawner), so they
/// always take the fast claim path and never touch the MDS counters —
/// in the dynamic run and in the pre-expanded one alike.
fn dispatch(
    w: &mut World<'_>,
    sim: &mut Sim<Ev>,
    eid: ExecId,
    t: TaskId,
    spawned: &[TaskId],
) {
    let dag = w.dag;
    let children: &[TaskId] = if w.spawn.is_staged(t) {
        &[] // staged tasks have no base children
    } else {
        dag.children(t)
    };
    let out_bytes = w.node(t).out_bytes;
    let big = w.knobs.use_clustering && out_bytes > w.knobs.clustering_threshold;
    let mut cursor = sim.now();

    let mut ready: Vec<TaskId> = Vec::new();
    let mut watch: Vec<TaskId> = Vec::new();
    let mut store_targets: Vec<TaskId> = Vec::new();

    if big {
        // Clustering path: hold the large object; run every ready target
        // here; for unready fan-ins, the elected holder watches (delayed
        // I/O) while every other parent stores + increments immediately.
        for &c in children.iter().chain(spawned) {
            if w.scratch.slot(c).claimed() {
                continue;
            }
            let indeg =
                if w.spawn.is_staged(c) { 1 } else { dag.indegree(c) };
            if indeg <= 1 {
                w.scratch.slot_mut(c).set_claimed();
                ready.push(c);
            } else {
                let (avail, t_mds) = w.mds.read(cursor, c as u64);
                w.metrics.breakdown.publish_s +=
                    to_secs(t_mds.saturating_sub(cursor));
                cursor = t_mds;
                if holdout_ready(avail, indeg) {
                    w.scratch.slot_mut(c).set_claimed();
                    ready.push(c);
                } else if w.knobs.use_delayed_io && should_hold(dag, t, c) {
                    watch.push(c);
                } else {
                    store_targets.push(c);
                }
            }
        }
        if !store_targets.is_empty() {
            if !w.scratch.slot(t).stored() {
                let end =
                    w.kvs_write(eid, cursor, TaskNode::obj_key(t), out_bytes);
                let slot = w.scratch.slot_mut(t);
                slot.avail_at = end;
                slot.set_stored();
                cursor = end;
            }
            for c in store_targets.drain(..) {
                if w.scratch.slot(c).claimed() {
                    continue;
                }
                let indeg = dag.indegree(c);
                let (new, t_mds) = w.mds.incr(cursor, c as u64);
                cursor = t_mds;
                if fanin_ready(new, indeg) {
                    w.scratch.slot_mut(c).set_claimed();
                    ready.push(c);
                }
            }
        }
    } else {
        // Normal path (§3.3 fan-in Cases 1–2): atomically increment each
        // fan-in child's counter first; claim the ones our increment
        // completed (they run here — Case 1, no store). Store only when a
        // child remains unready (its eventual executor reads us from the
        // KVS — Case 2) or when invoked executors cannot take the object
        // inline. Consumers' reads are floored at our write completion
        // (`avail_at`), modeling the real system's blocking poll reads.
        let mut any_unready = false;
        for &c in children.iter().chain(spawned) {
            if w.scratch.slot(c).claimed() {
                continue;
            }
            let indeg =
                if w.spawn.is_staged(c) { 1 } else { dag.indegree(c) };
            if indeg <= 1 {
                w.scratch.slot_mut(c).set_claimed();
                ready.push(c);
            } else {
                let (new, t_mds) = w.mds.incr(cursor, c as u64);
                w.metrics.breakdown.publish_s +=
                    to_secs(t_mds.saturating_sub(cursor));
                cursor = t_mds;
                if fanin_ready(new, indeg) && !w.scratch.slot(c).claimed() {
                    w.scratch.slot_mut(c).set_claimed();
                    ready.push(c);
                } else {
                    any_unready = true; // a later parent will claim it
                }
            }
        }
        let inline_ok = out_bytes <= w.knobs.arg_inline_max;
        if (any_unready || (ready.len() > 1 && !inline_ok))
            && !w.scratch.slot(t).stored()
        {
            let end = w.kvs_write(eid, cursor, TaskNode::obj_key(t), out_bytes);
            let slot = w.scratch.slot_mut(t);
            slot.avail_at = end;
            slot.set_stored();
            cursor = end;
        }
    }

    // Becomes + invokes / clustering.
    let becomes = ready.first().copied();
    let rest: Vec<TaskId> = ready.iter().skip(1).copied().collect();
    if let Some(b) = becomes {
        w.execs[eid].queue.push_front(b);
    }
    if big {
        // Task clustering: all other ready targets run locally too.
        for c in rest {
            w.execs[eid].queue.push_back(c);
        }
    } else if !rest.is_empty() {
        let inline_ok = out_bytes <= w.knobs.arg_inline_max;
        let inline: Vec<TaskId> = if inline_ok { vec![t] } else { vec![] };
        if !inline_ok && !w.scratch.slot(t).stored() {
            let end = w.kvs_write(eid, cursor, TaskNode::obj_key(t), out_bytes);
            let slot = w.scratch.slot_mut(t);
            slot.avail_at = end;
            slot.set_stored();
            cursor = end;
        }
        if rest.len() >= w.knobs.fanout_delegation_threshold.max(1) {
            // Delegate the wide fan-out to the proxy's invoker pool: one
            // published message, then parallel invocations.
            let (_, msg_end) = w.mds.incr(cursor, 0xDE1E_0000_0000_0000 | t as u64);
            w.metrics.breakdown.publish_s += to_secs(msg_end.saturating_sub(cursor));
            let per = w.lambda.sample_invoke_latency();
            // Inline-capable outputs ride the proxy message itself;
            // otherwise the argument travels via the KVS (0 inline).
            let payload = if inline_ok { out_bytes } else { 0 };
            let ends = w.pool.invoke_batch(msg_end, rest.len(), per, payload);
            for (c, end) in rest.into_iter().zip(ends) {
                let inv = w.lambda.admit(end);
                spawn(w, sim, c, inline.clone(), inv.start_at, 0);
            }
        } else {
            // Sequential self-invocation: each API call blocks the
            // executor for ~the invocation latency.
            for c in rest {
                let lat = w.lambda.sample_invoke_latency();
                w.metrics.breakdown.invoke_s += to_secs(lat);
                cursor += lat;
                let inv = w.lambda.admit(cursor);
                spawn(w, sim, c, inline.clone(), inv.start_at, 0);
            }
        }
    }

    // Delayed I/O watches (§3.3): recheck unready fan-ins later.
    for c in watch {
        w.execs[eid].pending_holds += 1;
        let retries = w.cfg.wukong.delayed_io_retries;
        let wait = secs(w.cfg.wukong.delayed_io_wait_s);
        sim.at(
            cursor + wait,
            Ev::Recheck {
                eid,
                task: t,
                child: c,
                retries_left: retries,
            },
        );
    }

    sim.at(cursor, Ev::Process(eid));
}

/// Delayed-I/O recheck: claim the fan-in the moment every *other* input is
/// available; on exhausted retries store the object and fall back to the
/// counter protocol (§3.3 "checking the unready objects one more time").
fn recheck(
    w: &mut World<'_>,
    sim: &mut Sim<Ev>,
    eid: ExecId,
    t: TaskId,
    c: TaskId,
    retries_left: u32,
) {
    if w.scratch.slot(c).claimed() {
        resolve_hold(w, sim, eid);
        return;
    }
    let indeg = w.dag.indegree(c);
    let (avail, t_mds) = w.mds.read(sim.now(), c as u64);
    w.metrics.breakdown.publish_s += to_secs(t_mds.saturating_sub(sim.now()));
    if holdout_ready(avail, indeg) {
        w.scratch.slot_mut(c).set_claimed();
        w.execs[eid].queue.push_back(c);
        resolve_hold(w, sim, eid);
    } else if retries_left > 0 {
        let wait = secs(w.cfg.wukong.delayed_io_wait_s);
        sim.at(
            t_mds + wait,
            Ev::Recheck {
                eid,
                task: t,
                child: c,
                retries_left: retries_left - 1,
            },
        );
    } else {
        // Give up: store the object, increment, maybe still claim.
        let mut cursor = t_mds;
        if !w.scratch.slot(t).stored() {
            let end = w.kvs_write(eid, cursor, TaskNode::obj_key(t), w.node(t).out_bytes);
            let slot = w.scratch.slot_mut(t);
            slot.avail_at = end;
            slot.set_stored();
            cursor = end;
        }
        let (new, t2) = w.mds.incr(cursor, c as u64);
        let final_claim = fanin_ready(new, indeg) && !w.scratch.slot(c).claimed();
        if final_claim {
            w.scratch.slot_mut(c).set_claimed();
            w.execs[eid].queue.push_back(c);
        }
        sim.at(t2, Ev::ResolveHold(eid));
    }
}

fn resolve_hold(w: &mut World<'_>, sim: &mut Sim<Ev>, eid: ExecId) {
    w.execs[eid].pending_holds -= 1;
    if w.execs[eid].idle {
        process(w, sim, eid);
    }
}

fn end_exec(w: &mut World<'_>, sim: &mut Sim<Ev>, eid: ExecId) {
    if std::mem::replace(&mut w.execs[eid].ended, true) {
        return;
    }
    let dur = to_secs(sim.now().saturating_sub(w.execs[eid].started));
    w.metrics.timeline.add(sim.now(), -1);
    w.metrics
        .billing
        .charge_lambda(w.cfg.lambda.memory_gb, dur.max(0.001));
    w.lambda.release();
}

/// Run a full Wukong job on the simulator, with `cfg.faults` as the
/// fault plan (the default plan injects nothing).
pub fn run_wukong(dag: &Dag, cfg: &Config, seed: u64) -> WukongReport {
    run_wukong_faulty(dag, cfg, seed, cfg.faults)
}

/// Run with fault injection (§3.6 retry contract).
pub fn run_wukong_faulty(
    dag: &Dag,
    cfg: &Config,
    seed: u64,
    faults: FaultPlan,
) -> WukongReport {
    let mut rng = Rng::new(seed);
    let knobs = PolicyKnobs {
        clustering_threshold: cfg.wukong.clustering_threshold,
        use_clustering: cfg.wukong.use_clustering,
        use_delayed_io: cfg.wukong.use_delayed_io,
        fanout_delegation_threshold: cfg.wukong.fanout_delegation_threshold,
        arg_inline_max: cfg.storage.arg_inline_max,
    };
    // Epoch open: freeze the run's spawn expansion (own salted stream —
    // inert plans draw nothing) and size every per-task structure to the
    // full expanded count, exactly what a pre-expanded run allocates.
    let spawn = SpawnState::for_run(dag, cfg.spawn, seed);
    let n = spawn.total_len();
    let n_sinks = spawn.sinks_after(dag);
    let mut scratch = TaskScratch::new(dag.len());
    scratch.grow_to(n);
    let mut w = World {
        knobs,
        dag,
        kvs: KvsModel::with_crashes(cfg.storage, cfg.crashes, seed),
        mds: MdsModel::new(&cfg.storage),
        lambda: LambdaService::new(cfg.lambda, rng.fork(1)),
        pool: InvokerPool::new(cfg.wukong.n_invokers),
        execs: Vec::new(),
        scratch,
        metrics: RunMetrics::default(),
        sinks_done: 0,
        n_sinks,
        finish: None,
        faults: FaultStream::for_run(faults, seed),
        direct_failed: Vec::new(),
        spawn,
        cfg,
    };
    let mut sim: Sim<Ev> = cfg.sim.build();
    sim.set_event_budget(cfg.event_budget);

    // Initial-Executor Invokers: the static scheduler's invoker pool
    // launches one executor per static schedule (leaf), in parallel.
    // Launch arguments are static-schedule slices, not data payloads:
    // no inline bytes.
    let schedules = generate_schedules(dag);
    let per = secs(cfg.lambda.invoke_latency_s);
    let ends = w.pool.invoke_batch(0, schedules.len(), per, 0);
    for (sched, end) in schedules.iter().zip(ends) {
        let leaf = sched.leaf;
        w.scratch.slot_mut(leaf).set_claimed();
        let inv = w.lambda.admit(end);
        spawn(&mut w, &mut sim, leaf, vec![], inv.start_at, 0);
    }
    sim.run(&mut w);

    // Assemble metrics.
    let makespan = to_secs(w.finish.unwrap_or(sim.now()));
    w.metrics.makespan_s = makespan;
    w.metrics.per_task_exec = w.scratch.executed_vec();
    // Terminal outcomes: directly-failed tasks plus their reachable sets
    // resolve to Failed; everything else completed (cross-checked against
    // per_task_exec by `wukong verify --faults`).
    let mut outcome = vec![TaskOutcome::Completed; n];
    w.metrics.failed_tasks =
        w.spawn.propagate_failures(dag, &w.direct_failed, &mut outcome);
    w.metrics.per_task_attempts = w.scratch.attempts_vec();
    w.metrics.per_task_outcome = outcome;
    w.metrics.kvs = w.kvs.metrics;
    w.metrics.durability = w.kvs.durability.merged(w.mds.durability());
    w.metrics.proxy_inline_bytes = w.pool.inline_bytes;
    w.metrics.invocations = w.lambda.total_invocations();
    w.metrics.peak_concurrency = w.lambda.peak_active();
    w.metrics.cpu_seconds =
        w.metrics.timeline.integral_s() * w.lambda.vcpus_per_fn();
    // Tenant-side non-Lambda costs for the job's duration.
    let hours = makespan / 3600.0;
    w.metrics.billing.charge_fargate(cfg.storage.n_shards, 4.0, 30.0, hours);
    w.metrics.billing.charge_scheduler_vm(hours);
    WukongReport {
        metrics: w.metrics,
        sim_events: sim.processed(),
        peak_pending: sim.peak_pending(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::{DagBuilder, OpKind};

    fn chain(n: usize) -> Dag {
        let mut b = DagBuilder::new("chain");
        let mut prev = b.task("t0", OpKind::Sleep, 0.0, 8);
        b.with_duration(prev, secs(0.01));
        for i in 1..n {
            let t = b.task(format!("t{i}"), OpKind::Sleep, 0.0, 8);
            b.with_duration(t, secs(0.01));
            b.edge(prev, t);
            prev = t;
        }
        b.build().unwrap()
    }

    fn diamond() -> Dag {
        let mut b = DagBuilder::new("diamond");
        let a = b.task("a", OpKind::Generic, 1e6, 100);
        let x = b.task("x", OpKind::Generic, 1e6, 100);
        let y = b.task("y", OpKind::Generic, 1e6, 100);
        let d = b.task("d", OpKind::Generic, 1e6, 100);
        b.edge(a, x).edge(a, y).edge(x, d).edge(y, d);
        b.build().unwrap()
    }

    #[test]
    fn chain_runs_on_one_executor() {
        let dag = chain(16);
        let r = run_wukong(&dag, &Config::default(), 1);
        assert_eq!(r.metrics.tasks_executed, 16);
        assert_eq!(r.metrics.executors_used, 1);
        // A chain never touches the KVS except the final publish.
        assert_eq!(r.metrics.kvs.writes, 1);
        assert_eq!(r.metrics.kvs.reads, 0);
    }

    #[test]
    fn diamond_executes_each_task_once() {
        let dag = diamond();
        let r = run_wukong(&dag, &Config::default(), 2);
        assert_eq!(r.metrics.tasks_executed, 4);
        // fan-out invokes exactly one extra executor
        assert_eq!(r.metrics.executors_used, 2);
    }

    #[test]
    fn determinism_same_seed_same_metrics() {
        let dag = diamond();
        let a = run_wukong(&dag, &Config::default(), 7);
        let b = run_wukong(&dag, &Config::default(), 7);
        assert_eq!(a.metrics.makespan_s, b.metrics.makespan_s);
        assert_eq!(a.metrics.kvs, b.metrics.kvs);
        assert_eq!(a.sim_events, b.sim_events);
        assert_eq!(a.peak_pending, b.peak_pending);
    }

    #[test]
    fn clustering_eliminates_kvs_traffic_for_large_outputs() {
        let mut b = DagBuilder::new("big-fanout");
        let root = b.task("root", OpKind::Generic, 1e6, 500 * 1024 * 1024);
        let kids: Vec<_> = (0..3)
            .map(|i| b.task(format!("k{i}"), OpKind::Generic, 1e6, 8))
            .collect();
        let sink = b.task("sink", OpKind::Generic, 1e6, 8);
        for &k in &kids {
            b.edge(root, k);
            b.edge(k, sink);
        }
        let dag = b.build().unwrap();

        let mut on = Config::default();
        on.wukong.use_clustering = true;
        let mut off = Config::default();
        off.wukong.use_clustering = false;
        let r_on = run_wukong(&dag, &on, 3);
        let r_off = run_wukong(&dag, &off, 3);
        assert!(r_on.metrics.kvs.bytes_written < r_off.metrics.kvs.bytes_written);
        assert_eq!(r_on.metrics.tasks_executed, 5);
        assert_eq!(r_off.metrics.tasks_executed, 5);
        // Clustering keeps everything on one executor.
        assert_eq!(r_on.metrics.executors_used, 1);
    }

    #[test]
    fn faults_are_retried_and_job_completes() {
        let dag = diamond();
        let r = run_wukong_faulty(
            &dag,
            &Config::default(),
            5,
            FaultPlan::with_failure_rate(0.3),
        );
        assert_eq!(r.metrics.tasks_executed, 4);
        assert_eq!(r.metrics.failed_tasks, 0);
        assert!(r
            .metrics
            .per_task_outcome
            .iter()
            .all(|&o| o == TaskOutcome::Completed));
        assert!(r.metrics.per_task_attempts.iter().all(|&a| (1..=3).contains(&a)));
    }

    #[test]
    fn zero_rate_plan_is_bit_identical_to_fault_free() {
        // The regression the dedicated fault stream exists for: enabling
        // a (zero-rate) fault plan must not shift the main RNG, so the
        // whole report — metrics, event counts — is byte-identical.
        let dag = diamond();
        let cfg = Config::default();
        let base = run_wukong(&dag, &cfg, 7);
        for &retries in &[0u32, 2] {
            let f = run_wukong_faulty(
                &dag,
                &cfg,
                7,
                FaultPlan::with_retries(0.0, retries),
            );
            assert_eq!(base.metrics, f.metrics);
            assert_eq!(base.sim_events, f.sim_events);
            assert_eq!(base.peak_pending, f.peak_pending);
        }
    }

    #[test]
    fn exhausted_budget_reports_the_whole_reachable_set_failed() {
        // p=1: the single leaf executor fails all 1+2 attempts; the job
        // is reported failed and the cascade covers the entire diamond.
        let dag = diamond();
        let r = run_wukong_faulty(
            &dag,
            &Config::default(),
            5,
            FaultPlan::with_retries(1.0, 2),
        );
        assert_eq!(r.metrics.tasks_executed, 0);
        assert_eq!(r.metrics.failed_tasks, 4);
        assert_eq!(r.metrics.failed_executors, 1);
        assert_eq!(r.metrics.per_task_attempts[0], 3);
        assert!(r
            .metrics
            .per_task_outcome
            .iter()
            .all(|&o| o == TaskOutcome::Failed));
    }

    #[test]
    fn zero_rate_crash_plan_is_bit_identical_to_crash_free() {
        // Same regression guard as the fault stream's: enabling a
        // zero-rate crash plan draws nothing, so the whole report is
        // byte-identical (including the durability meters).
        let dag = diamond();
        let cfg = Config::default();
        let base = run_wukong(&dag, &cfg, 7);
        let mut crashy_cfg = cfg.clone();
        crashy_cfg.crashes =
            crate::platform::faults::ShardCrashPlan::with_crashes(0.0, 8);
        let r = run_wukong(&dag, &crashy_cfg, 7);
        assert_eq!(base.metrics, r.metrics);
        assert_eq!(base.sim_events, r.sim_events);
        assert_eq!(base.peak_pending, r.peak_pending);
    }

    #[test]
    fn shard_crashes_perturb_only_the_recovery_meters() {
        // The tentpole's recovery gate at unit scale: crash shards on
        // every KVS op — task outcomes, byte meters, event counts and
        // makespan must match the crash-free run exactly; only the
        // recovery meters move (time-decoupled recovery).
        let dag = diamond();
        let cfg = Config::default();
        let base = run_wukong(&dag, &cfg, 9);
        let mut crashy_cfg = cfg.clone();
        crashy_cfg.crashes =
            crate::platform::faults::ShardCrashPlan::with_crashes(1.0, 2);
        let r = run_wukong(&dag, &crashy_cfg, 9);
        assert_eq!(r.metrics.durability.recoveries, 2);
        assert!(r.metrics.durability.stall_s > 0.0);
        assert_eq!(base.sim_events, r.sim_events);
        assert_eq!(base.metrics.makespan_s, r.metrics.makespan_s);
        assert_eq!(base.metrics.kvs, r.metrics.kvs);
        assert_eq!(base.metrics.per_task_outcome, r.metrics.per_task_outcome);
        let mut scrubbed = r.metrics.clone();
        scrubbed.durability.recoveries = 0;
        scrubbed.durability.replayed_ops = 0;
        scrubbed.durability.stall_s = 0.0;
        assert_eq!(base.metrics, scrubbed);
    }

    #[test]
    fn spawned_subtasks_run_and_match_the_pre_expanded_dag() {
        // p = 1, fanout 2, depth 2: every task emits 6 subtasks. The
        // dynamic run must be byte-identical to executing the statically
        // pre-expanded DAG under an inert plan.
        let dag = diamond();
        let mut cfg = Config::default();
        cfg.spawn = crate::dag::SpawnPlan::recursive(1.0, 2, 2);
        let dy = run_wukong(&dag, &cfg, 7);
        assert_eq!(dy.metrics.tasks_executed, 4 + 4 * 6);
        assert_eq!(dy.metrics.per_task_exec.len(), 28);
        let expanded = crate::dag::pre_expand(&dag, cfg.spawn, 7);
        let st = run_wukong(&expanded, &Config::default(), 7);
        assert_eq!(dy.metrics, st.metrics);
        assert_eq!(dy.sim_events, st.sim_events);
        assert_eq!(dy.peak_pending, st.peak_pending);
    }

    #[test]
    fn zero_rate_spawn_plan_is_bit_identical_to_plan_free() {
        // The spawn stream's bit-identity guard (same regression class
        // as the fault/crash streams): a zero-rate plan draws nothing.
        let dag = diamond();
        let base = run_wukong(&dag, &Config::default(), 7);
        let mut cfg = Config::default();
        cfg.spawn = crate::dag::SpawnPlan::with_rate(0.0, 8);
        let r = run_wukong(&dag, &cfg, 7);
        assert_eq!(base.metrics, r.metrics);
        assert_eq!(base.sim_events, r.sim_events);
        assert_eq!(base.peak_pending, r.peak_pending);
    }

    #[test]
    fn failed_spawner_dooms_its_unspawned_subtree() {
        // Every executor attempt fails: the diamond's leaf exhausts its
        // budget, so all 4 base tasks AND all 24 staged tasks (which
        // never spawn) must report Failed — matching the pre-expanded
        // run's cascade.
        let dag = diamond();
        let mut cfg = Config::default();
        cfg.spawn = crate::dag::SpawnPlan::recursive(1.0, 2, 2);
        cfg.faults = FaultPlan::with_retries(1.0, 2);
        let dy = run_wukong(&dag, &cfg, 5);
        assert_eq!(dy.metrics.tasks_executed, 0);
        assert_eq!(dy.metrics.failed_tasks, 28);
        let expanded = crate::dag::pre_expand(&dag, cfg.spawn, 5);
        let mut st_cfg = Config::default();
        st_cfg.faults = cfg.faults;
        let st = run_wukong(&expanded, &st_cfg, 5);
        assert_eq!(dy.metrics, st.metrics);
        assert_eq!(dy.sim_events, st.sim_events);
    }

    #[test]
    fn event_budget_watchdog_aborts_the_run() {
        let dag = chain(16);
        let mut cfg = Config::default();
        cfg.event_budget = 5; // far below what a 16-task chain needs
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_wukong(&dag, &cfg, 1)
        }))
        .unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("sim event budget exceeded"), "{msg}");
    }
}
