//! The multi-job serving session: a job-level DES that multiplexes a
//! stream of DAG jobs from many tenants onto one shared Lambda pool and
//! one shared KVS.
//!
//! Two-level simulation: each job's *inner* run (the wukong engine on
//! its DAG) is a pure function of `(dag, config, job_seed)`, so all
//! per-job engine reports are precomputed in parallel with
//! `ordered_map` — index-ordered and byte-identical to sequential,
//! which is what makes `--threads N` output bit-equal to `--threads 1`.
//! The *outer* session then replays arrivals sequentially over shared
//! state: per-tenant admission queues under a fairness policy, slot
//! accounting against one `LambdaService` (with warm-executor reuse
//! between a finishing job's slots and the next arrival), a shared
//! `KvsModel` metering every job's aggregate footprint under job-scoped
//! keys (`storage::kvs::job_scoped_key` — concurrent jobs can never
//! collide), and per-tenant `Billing` rollups.
//!
//! Conservation gate: every arrival is enqueued, every queued job is
//! eventually admitted (demands are clamped to the pool size and both
//! policies are head-of-line blocking, so completions always unblock
//! the queue), and every admitted job finishes as completed ⊕ failed —
//! never silently lost. `ServingReport::conserves_jobs` checks it.

use crate::config::Config;
use crate::engine::{Engine, SimWukong};
use crate::platform::billing::{Billing, Prices};
use crate::platform::lambda::LambdaService;
use crate::sim::{secs, to_secs, Handler, Sim, Time};
use crate::storage::kvs::{job_scoped_key, KvsModel};
use crate::util::stats::percentile;
use crate::util::threadpool::ordered_map;
use crate::util::Rng;
use crate::verify::corpus;

use super::arrival::ArrivalStream;
use super::report::{ServingReport, TenantStats};
use super::tenants::{QueuedJob, TenantScheduler};

/// Per-job seed split (same multiply-add shape as `verify::case_seed_of`
/// but a different odd constant, so serving jobs never alias verify
/// cases for the same base seed).
fn job_seed_of(base: u64, job: u64) -> u64 {
    base.wrapping_mul(0xD6E8_FEB8_6659_FD93).wrapping_add(job)
}

/// Everything the outer session needs to know about one job, extracted
/// from its precomputed engine run.
#[derive(Debug, Clone)]
struct JobSpec {
    tenant: usize,
    arrive_at: Time,
    /// Shared-pool slots occupied while running (peak concurrency of
    /// the inner run, clamped to the pool size so every job fits).
    demand: usize,
    makespan: Time,
    /// Executor-seconds (timeline integral) — the weighted-fair charge.
    exec_s: f64,
    tasks: u64,
    sim_events: u64,
    failed: bool,
    kvs_read: u64,
    kvs_written: u64,
    billing: Billing,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum ServeEv {
    Arrive(usize),
    Finish(usize),
}

#[derive(Debug, Default, Clone)]
struct TenantAcc {
    jobs: u64,
    completed: u64,
    failed: u64,
    latencies: Vec<f64>,
    queue_delays: Vec<f64>,
    exec_s: f64,
    billing: Billing,
}

struct ServeWorld {
    specs: Vec<JobSpec>,
    sched: TenantScheduler,
    lambda: LambdaService,
    kvs: KvsModel,
    limit: usize,
    invoke_latency: Time,
    cold_penalty: Time,
    admitted: u64,
    completed: u64,
    failed: u64,
    per_tenant: Vec<TenantAcc>,
    seq: u64,
}

impl ServeWorld {
    /// Admit queued jobs while the policy's next pick fits in the free
    /// slots (head-of-line blocking per policy).
    fn drain(&mut self, sim: &mut Sim<ServeEv>) {
        loop {
            let free = self.limit - self.lambda.active();
            let Some(q) = self.sched.pick(free) else { break };
            let now = sim.now();
            let j = q.job;
            self.admitted += 1;
            // Occupy the slots, reusing parked warm executors first.
            let mut cold_slots = 0usize;
            for _ in 0..q.demand {
                if self.lambda.reuse(now).cold {
                    cold_slots += 1;
                }
            }
            // Meter the job's aggregate KVS footprint on the shared
            // cluster under job-scoped keys. Timing already happened
            // inside the inner run against its private model; here the
            // shared model records contention-domain bytes/ops only
            // (time-decoupled, like durability recovery costs).
            let spec = &self.specs[j];
            if spec.kvs_written > 0 {
                self.kvs
                    .write(now, job_scoped_key(j as u64, 0), spec.kvs_written);
            }
            if spec.kvs_read > 0 {
                self.kvs
                    .read(now, job_scoped_key(j as u64, 1), spec.kvs_read);
            }
            // Deterministic start: flat invoke latency (batch invoke),
            // plus the cold penalty if any slot missed the warm pool.
            let mut start = now + self.invoke_latency;
            if cold_slots > 0 {
                start += self.cold_penalty;
            }
            let t = &mut self.per_tenant[q.tenant];
            t.queue_delays.push(to_secs(now - spec.arrive_at));
            sim.at(start + spec.makespan, ServeEv::Finish(j));
        }
    }
}

impl Handler for ServeWorld {
    type Ev = ServeEv;

    fn handle(&mut self, sim: &mut Sim<ServeEv>, ev: ServeEv) {
        match ev {
            ServeEv::Arrive(j) => {
                let spec = &self.specs[j];
                self.seq += 1;
                self.sched.enqueue(QueuedJob {
                    job: j,
                    tenant: spec.tenant,
                    demand: spec.demand,
                    exec_s: spec.exec_s,
                    seq: self.seq,
                    arrive_at: spec.arrive_at,
                });
                self.drain(sim);
            }
            ServeEv::Finish(j) => {
                let spec = self.specs[j].clone();
                // Free the slots and park them warm for the next job.
                for _ in 0..spec.demand {
                    self.lambda.release();
                }
                self.lambda.park_warm(spec.demand);
                if spec.failed {
                    self.failed += 1;
                } else {
                    self.completed += 1;
                }
                let t = &mut self.per_tenant[spec.tenant];
                t.jobs += 1;
                if spec.failed {
                    t.failed += 1;
                } else {
                    t.completed += 1;
                }
                t.latencies.push(to_secs(sim.now() - spec.arrive_at));
                t.exec_s += spec.exec_s;
                t.billing.absorb(&spec.billing);
                self.drain(sim);
            }
        }
    }
}

/// Percentile that treats an empty sample as 0 (keeps reports free of
/// NaN, which would break `PartialEq`-based determinism checks).
fn pctl(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        percentile(xs, p)
    }
}

/// Run one multi-tenant serving session over the wukong engine.
///
/// `cfg.arrival` shapes the job stream, `cfg.tenants` the population
/// and fairness policy. `threads` parallelizes only the per-job engine
/// precompute (index-ordered), so the returned report is byte-identical
/// for every thread count. An empty arrival plan returns an all-zero
/// report and consumes nothing.
pub fn run_serving(cfg: &Config, seed: u64, threads: usize) -> ServingReport {
    let tplan = cfg.tenants;
    let n_tenants = tplan.count.max(1);
    let arrivals =
        ArrivalStream::for_run(cfg.arrival, seed).arrival_times();
    let n = arrivals.len();
    let limit = cfg.lambda.concurrency_limit.max(1);

    // Precompute every job's inner engine run in parallel: pure per
    // index, so `ordered_map` yields the same Vec for any thread count.
    let job_cfg = cfg.clone();
    let specs_base = ordered_map(n, threads, move |j| {
        let jseed = job_seed_of(seed, j as u64);
        let mut rng = Rng::new(jseed);
        let dag = corpus::random_dag(&mut rng);
        let rep = SimWukong.run(&dag, &job_cfg, jseed);
        let m = rep.metrics;
        JobSpec {
            tenant: j % n_tenants,
            arrive_at: 0,
            demand: m.peak_concurrency.max(1).min(limit),
            makespan: secs(m.makespan_s),
            exec_s: m.timeline.integral_s(),
            tasks: m.per_task_outcome.len() as u64,
            sim_events: rep.sim_events.unwrap_or(0),
            failed: m.failed_tasks > 0,
            kvs_read: m.kvs.bytes_read,
            kvs_written: m.kvs.bytes_written,
            billing: m.billing,
        }
    });
    let mut specs = specs_base;
    for (j, &at) in arrivals.iter().enumerate() {
        specs[j].arrive_at = at;
    }

    let mut world = ServeWorld {
        sched: TenantScheduler::new(tplan),
        lambda: LambdaService::new(cfg.lambda, Rng::new(seed)),
        kvs: KvsModel::new(cfg.storage),
        limit,
        invoke_latency: secs(cfg.lambda.invoke_latency_s),
        cold_penalty: secs(cfg.lambda.cold_start_s),
        admitted: 0,
        completed: 0,
        failed: 0,
        per_tenant: vec![TenantAcc::default(); n_tenants],
        specs,
        seq: 0,
    };

    let mut sim: Sim<ServeEv> = cfg.sim.build();
    for (j, &at) in arrivals.iter().enumerate() {
        sim.at(at, ServeEv::Arrive(j));
    }
    let end = sim.run(&mut world);

    let prices = Prices::default();
    let horizon_s = to_secs(end);
    let engine_events: u64 =
        world.specs.iter().map(|s| s.sim_events).sum();
    let total_events = engine_events + sim.processed();
    let mut all_latencies: Vec<f64> = Vec::new();
    let mut total_billing = Billing::default();
    let tenants: Vec<TenantStats> = world
        .per_tenant
        .iter()
        .enumerate()
        .map(|(i, t)| {
            all_latencies.extend_from_slice(&t.latencies);
            total_billing.absorb(&t.billing);
            TenantStats {
                tenant: i,
                weight: tplan.weight(i),
                jobs: t.jobs,
                completed: t.completed,
                failed: t.failed,
                p50_latency_s: pctl(&t.latencies, 50.0),
                p99_latency_s: pctl(&t.latencies, 99.0),
                p50_queue_s: pctl(&t.queue_delays, 50.0),
                p99_queue_s: pctl(&t.queue_delays, 99.0),
                executor_hours: t.exec_s / 3600.0,
                dollars: t.billing.total(&prices),
            }
        })
        .collect();

    ServingReport {
        arrived: n as u64,
        admitted: world.admitted,
        completed: world.completed,
        failed: world.failed,
        total_tasks: world.specs.iter().map(|s| s.tasks).sum(),
        horizon_s,
        session_events: sim.processed(),
        total_events,
        events_per_s: if horizon_s > 0.0 {
            total_events as f64 / horizon_s
        } else {
            0.0
        },
        warm_hits: world.lambda.warm_hits(),
        cold_starts: world.lambda.cold_starts(),
        peak_slots: world.lambda.peak_active(),
        kvs_bytes: world.kvs.metrics.bytes_read
            + world.kvs.metrics.bytes_written,
        p50_latency_s: pctl(&all_latencies, 50.0),
        p99_latency_s: pctl(&all_latencies, 99.0),
        executor_hours: world.per_tenant.iter().map(|t| t.exec_s).sum::<f64>()
            / 3600.0,
        dollars: total_billing.total(&prices),
        tenants,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::arrival::ArrivalPlan;
    use crate::serving::tenants::{FairnessPolicy, TenantPlan};

    fn serving_cfg(plan: ArrivalPlan, tenants: TenantPlan) -> Config {
        let mut cfg = Config::default();
        cfg.arrival = plan;
        cfg.tenants = tenants;
        cfg
    }

    #[test]
    fn session_conserves_jobs_under_both_policies() {
        for policy in [FairnessPolicy::Fifo, FairnessPolicy::WeightedFair] {
            let cfg = serving_cfg(
                ArrivalPlan::poisson(20.0, 12),
                TenantPlan {
                    count: 3,
                    policy,
                    weight_skew: 0.5,
                },
            );
            let r = run_serving(&cfg, 11, 1);
            assert_eq!(r.arrived, 12);
            assert!(r.conserves_jobs(), "{policy:?}: {r:?}");
            assert!(r.total_events > r.session_events);
            assert!(r.horizon_s > 0.0);
            // Every occupied slot was classified warm xor cold.
            assert!(r.warm_hits + r.cold_starts > 0);
        }
    }

    #[test]
    fn report_is_byte_identical_across_reruns_and_threads() {
        let cfg = serving_cfg(
            ArrivalPlan::poisson(10.0, 10),
            TenantPlan::default(),
        );
        let a = run_serving(&cfg, 5, 1);
        let b = run_serving(&cfg, 5, 1);
        let c = run_serving(&cfg, 5, 3);
        assert_eq!(a, b);
        assert_eq!(a, c);
        assert_eq!(a.render(), c.render());
    }

    #[test]
    fn empty_stream_is_a_no_op_report() {
        let cfg = serving_cfg(
            ArrivalPlan::poisson(0.0, 500),
            TenantPlan::default(),
        );
        let r = run_serving(&cfg, 9, 2);
        assert_eq!(r.arrived, 0);
        assert_eq!(r.admitted, 0);
        assert_eq!(r.session_events, 0);
        assert_eq!(r.total_events, 0);
        assert_eq!(r.kvs_bytes, 0);
        assert_eq!(r.warm_hits + r.cold_starts, 0);
        assert!(r.conserves_jobs());
        assert_eq!(r.tenants.len(), 4);
        assert!(r.tenants.iter().all(|t| t.jobs == 0 && t.dollars == 0.0));
    }

    #[test]
    fn sequential_jobs_reuse_warm_executors() {
        // Trace gaps far larger than any job makespan: jobs never
        // overlap, so every job after the first finds parked warm
        // executors from its predecessors.
        let cfg = serving_cfg(
            ArrivalPlan::trace(100_000.0, 10),
            TenantPlan {
                count: 1,
                policy: FairnessPolicy::Fifo,
                weight_skew: 0.0,
            },
        );
        let r = run_serving(&cfg, 3, 1);
        assert!(r.conserves_jobs());
        assert!(
            r.warm_hits >= 9,
            "each of the 9 later jobs should hit the warm pool: {r:?}"
        );
        // No queueing when jobs never overlap.
        assert_eq!(r.tenants[0].p99_queue_s, 0.0);
    }

    #[test]
    fn job_seed_split_differs_from_the_base_seed() {
        assert_ne!(job_seed_of(42, 0), 42);
        assert_ne!(job_seed_of(42, 0), job_seed_of(42, 1));
        assert_ne!(job_seed_of(42, 1), job_seed_of(43, 1));
    }
}
