//! Serving-run results: per-tenant latency/queueing/cost rollups plus
//! aggregate throughput, with a deterministic renderer.
//!
//! Every field is derived from virtual time and exact counters — no
//! wall-clock values — so a rendered report (and the struct itself,
//! via `PartialEq`) is byte-identical across `--threads 1` and
//! `--threads N`. That is the serving determinism gate.

use crate::util::json::Json;
use crate::util::stats::human_bytes;

/// Per-tenant rollup: counts, latency/queueing percentiles (seconds),
/// executor-hours, and billed dollars.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantStats {
    pub tenant: usize,
    pub weight: f64,
    pub jobs: u64,
    pub completed: u64,
    pub failed: u64,
    /// End-to-end job latency (arrival → finish), p50/p99.
    pub p50_latency_s: f64,
    pub p99_latency_s: f64,
    /// Admission queueing delay (arrival → admission), p50/p99.
    pub p50_queue_s: f64,
    pub p99_queue_s: f64,
    pub executor_hours: f64,
    pub dollars: f64,
}

/// Aggregate result of one multi-tenant serving run.
#[derive(Debug, Clone, PartialEq)]
pub struct ServingReport {
    /// Jobs the arrival stream produced.
    pub arrived: u64,
    /// Jobs admitted to the shared pool (conservation: every arrival
    /// is eventually admitted; admitted = completed + failed).
    pub admitted: u64,
    pub completed: u64,
    pub failed: u64,
    /// Tasks across all job DAGs.
    pub total_tasks: u64,
    /// Virtual time from first arrival to last finish (s).
    pub horizon_s: f64,
    /// DES events processed by the job-level session calendar.
    pub session_events: u64,
    /// Session events + every per-job engine run's events.
    pub total_events: u64,
    /// `total_events / horizon_s` — virtual-time throughput (wall-clock
    /// rates live in the bench JSON, outside the determinism gate).
    pub events_per_s: f64,
    pub warm_hits: u64,
    pub cold_starts: u64,
    /// Peak simultaneous slots in the shared Lambda pool.
    pub peak_slots: usize,
    /// Shared-KVS footprint (bytes read + written under job-scoped keys).
    pub kvs_bytes: u64,
    pub p50_latency_s: f64,
    pub p99_latency_s: f64,
    pub executor_hours: f64,
    pub dollars: f64,
    pub tenants: Vec<TenantStats>,
}

impl ServingReport {
    /// The serving conservation gate: no job is silently lost. Every
    /// arrival was admitted, admitted = completed ⊕ failed, and the
    /// per-tenant rows partition the totals.
    pub fn conserves_jobs(&self) -> bool {
        self.arrived == self.admitted
            && self.admitted == self.completed + self.failed
            && self.tenants.iter().map(|t| t.jobs).sum::<u64>()
                == self.admitted
            && self.tenants.iter().all(|t| t.completed + t.failed == t.jobs)
    }

    /// Deterministic multi-line rendering (virtual-time fields only).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "serving: {} arrived, {} admitted = {} completed + {} failed \
             ({} tasks)\n",
            self.arrived, self.admitted, self.completed, self.failed,
            self.total_tasks
        ));
        out.push_str(&format!(
            "horizon {:.3} s · {} DES events ({} session) · \
             {:.0} events/s virtual\n",
            self.horizon_s, self.total_events, self.session_events,
            self.events_per_s
        ));
        out.push_str(&format!(
            "pool: peak {} slots · {} warm hits · {} cold starts · \
             shared KVS {}\n",
            self.peak_slots,
            self.warm_hits,
            self.cold_starts,
            human_bytes(self.kvs_bytes as f64)
        ));
        out.push_str(&format!(
            "{:>6} {:>7} {:>6} {:>6} {:>5} {:>9} {:>9} {:>9} {:>9} \
             {:>8} {:>10}\n",
            "tenant", "weight", "jobs", "done", "fail", "p50 lat",
            "p99 lat", "p50 que", "p99 que", "exec-h", "dollars"
        ));
        for t in &self.tenants {
            out.push_str(&format!(
                "{:>6} {:>7.2} {:>6} {:>6} {:>5} {:>9.3} {:>9.3} {:>9.3} \
                 {:>9.3} {:>8.3} {:>10.4}\n",
                t.tenant, t.weight, t.jobs, t.completed, t.failed,
                t.p50_latency_s, t.p99_latency_s, t.p50_queue_s,
                t.p99_queue_s, t.executor_hours, t.dollars
            ));
        }
        out.push_str(&format!(
            "{:>6} {:>7} {:>6} {:>6} {:>5} {:>9.3} {:>9.3} {:>9} {:>9} \
             {:>8.3} {:>10.4}\n",
            "all", "-", self.admitted, self.completed, self.failed,
            self.p50_latency_s, self.p99_latency_s, "-", "-",
            self.executor_hours, self.dollars
        ));
        out
    }

    /// JSON form (CI artifact; same deterministic fields as `render`).
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("arrived".into(), Json::Num(self.arrived as f64));
        m.insert("admitted".into(), Json::Num(self.admitted as f64));
        m.insert("completed".into(), Json::Num(self.completed as f64));
        m.insert("failed".into(), Json::Num(self.failed as f64));
        m.insert("total_tasks".into(), Json::Num(self.total_tasks as f64));
        m.insert("horizon_s".into(), Json::Num(self.horizon_s));
        m.insert(
            "session_events".into(),
            Json::Num(self.session_events as f64),
        );
        m.insert("total_events".into(), Json::Num(self.total_events as f64));
        m.insert("events_per_s".into(), Json::Num(self.events_per_s));
        m.insert("warm_hits".into(), Json::Num(self.warm_hits as f64));
        m.insert("cold_starts".into(), Json::Num(self.cold_starts as f64));
        m.insert("peak_slots".into(), Json::Num(self.peak_slots as f64));
        m.insert("kvs_bytes".into(), Json::Num(self.kvs_bytes as f64));
        m.insert("p50_latency_s".into(), Json::Num(self.p50_latency_s));
        m.insert("p99_latency_s".into(), Json::Num(self.p99_latency_s));
        m.insert("executor_hours".into(), Json::Num(self.executor_hours));
        m.insert("dollars".into(), Json::Num(self.dollars));
        m.insert(
            "tenants".into(),
            Json::Arr(
                self.tenants
                    .iter()
                    .map(|t| {
                        let mut tm = std::collections::BTreeMap::new();
                        tm.insert(
                            "tenant".into(),
                            Json::Num(t.tenant as f64),
                        );
                        tm.insert("weight".into(), Json::Num(t.weight));
                        tm.insert("jobs".into(), Json::Num(t.jobs as f64));
                        tm.insert(
                            "completed".into(),
                            Json::Num(t.completed as f64),
                        );
                        tm.insert(
                            "failed".into(),
                            Json::Num(t.failed as f64),
                        );
                        tm.insert(
                            "p50_latency_s".into(),
                            Json::Num(t.p50_latency_s),
                        );
                        tm.insert(
                            "p99_latency_s".into(),
                            Json::Num(t.p99_latency_s),
                        );
                        tm.insert(
                            "p50_queue_s".into(),
                            Json::Num(t.p50_queue_s),
                        );
                        tm.insert(
                            "p99_queue_s".into(),
                            Json::Num(t.p99_queue_s),
                        );
                        tm.insert(
                            "executor_hours".into(),
                            Json::Num(t.executor_hours),
                        );
                        tm.insert("dollars".into(), Json::Num(t.dollars));
                        Json::Obj(tm)
                    })
                    .collect(),
            ),
        );
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> ServingReport {
        ServingReport {
            arrived: 4,
            admitted: 4,
            completed: 3,
            failed: 1,
            total_tasks: 40,
            horizon_s: 10.0,
            session_events: 8,
            total_events: 108,
            events_per_s: 10.8,
            warm_hits: 2,
            cold_starts: 6,
            peak_slots: 12,
            kvs_bytes: 4096,
            p50_latency_s: 1.5,
            p99_latency_s: 3.0,
            executor_hours: 0.01,
            dollars: 0.02,
            tenants: vec![
                TenantStats {
                    tenant: 0,
                    weight: 1.0,
                    jobs: 2,
                    completed: 2,
                    failed: 0,
                    p50_latency_s: 1.0,
                    p99_latency_s: 2.0,
                    p50_queue_s: 0.0,
                    p99_queue_s: 0.1,
                    executor_hours: 0.005,
                    dollars: 0.01,
                },
                TenantStats {
                    tenant: 1,
                    weight: 1.0,
                    jobs: 2,
                    completed: 1,
                    failed: 1,
                    p50_latency_s: 2.0,
                    p99_latency_s: 3.0,
                    p50_queue_s: 0.2,
                    p99_queue_s: 0.4,
                    executor_hours: 0.005,
                    dollars: 0.01,
                },
            ],
        }
    }

    #[test]
    fn conservation_holds_for_partitioned_totals() {
        assert!(report().conserves_jobs());
    }

    #[test]
    fn conservation_catches_silent_loss() {
        let mut r = report();
        r.completed = 2; // one job vanished
        assert!(!r.conserves_jobs());
        let mut r = report();
        r.admitted = 3; // an arrival was never admitted
        assert!(!r.conserves_jobs());
        let mut r = report();
        r.tenants[0].jobs = 3; // tenant rows no longer partition
        assert!(!r.conserves_jobs());
    }

    #[test]
    fn render_is_deterministic_and_covers_the_headline() {
        let a = report().render();
        let b = report().render();
        assert_eq!(a, b);
        assert!(a.contains("4 admitted = 3 completed + 1 failed"));
        assert!(a.contains("2 warm hits"));
        assert!(a.contains("tenant"));
        assert!(a.lines().count() >= 6);
    }

    #[test]
    fn json_round_trips() {
        let j = report().to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("admitted").unwrap().as_u64(), Some(4));
        assert_eq!(
            parsed.get("tenants").unwrap().as_arr().unwrap().len(),
            2
        );
        assert_eq!(
            parsed.get("tenants").unwrap().as_arr().unwrap()[1]
                .get("failed")
                .unwrap()
                .as_u64(),
            Some(1)
        );
    }
}
