//! Per-tenant admission control for the serving layer.
//!
//! Every arriving job belongs to a tenant; jobs queue per tenant and a
//! pluggable [`FairnessPolicy`] decides which queued job is admitted
//! when invoker slots free up:
//!
//! - **FIFO** — global arrival order, head-of-line blocking: the oldest
//!   queued job is admitted as soon as its slot demand fits.
//! - **Weighted fair** — the tenant with the smallest weight-normalized
//!   served executor-seconds goes next (min `served_s / weight`), FIFO
//!   within a tenant. Weights grow linearly with `weight_skew`
//!   (`weight(i) = 1 + skew·i`), so a skew of 0 degrades to equal-share
//!   fair queueing.
//!
//! Both policies admit strictly head-of-line once a candidate tenant is
//! chosen: a job that does not fit blocks admission until running jobs
//! release slots. Demands are clamped to the pool size upstream, so the
//! head always fits eventually and no job can be starved forever —
//! that is what makes the serving conservation gate (admitted =
//! completed ⊕ failed) provable.

use std::collections::VecDeque;

use crate::sim::Time;

/// Which job goes next when slots free up.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FairnessPolicy {
    Fifo,
    WeightedFair,
}

/// Tenant-population shape: how many tenants share the pool, the
/// admission policy, and the weight skew across tenants.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TenantPlan {
    /// Number of tenants (arrivals are assigned round-robin).
    pub count: usize,
    pub policy: FairnessPolicy,
    /// Linear weight skew: `weight(i) = 1 + weight_skew * i`.
    pub weight_skew: f64,
}

impl Default for TenantPlan {
    fn default() -> Self {
        TenantPlan {
            count: 4,
            policy: FairnessPolicy::Fifo,
            weight_skew: 0.0,
        }
    }
}

impl TenantPlan {
    /// Fair-share weight of tenant `i` (≥ 1 for non-negative skew).
    pub fn weight(&self, tenant: usize) -> f64 {
        1.0 + self.weight_skew * tenant as f64
    }
}

/// One queued job awaiting admission.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QueuedJob {
    /// Index into the session's job list.
    pub job: usize,
    pub tenant: usize,
    /// Shared-pool slots the job occupies while running.
    pub demand: usize,
    /// Executor-seconds the job will consume (weighted-fair charge).
    pub exec_s: f64,
    /// Global arrival ticket (FIFO order across tenants).
    pub seq: u64,
    pub arrive_at: Time,
}

/// Admission scheduler over per-tenant FIFO queues.
#[derive(Debug)]
pub struct TenantScheduler {
    plan: TenantPlan,
    queues: Vec<VecDeque<QueuedJob>>,
    /// Executor-seconds admitted per tenant (weighted-fair bookkeeping).
    served_s: Vec<f64>,
}

impl TenantScheduler {
    pub fn new(plan: TenantPlan) -> TenantScheduler {
        let n = plan.count.max(1);
        TenantScheduler {
            plan,
            queues: (0..n).map(|_| VecDeque::new()).collect(),
            served_s: vec![0.0; n],
        }
    }

    pub fn plan(&self) -> TenantPlan {
        self.plan
    }

    /// Total jobs currently queued across all tenants.
    pub fn queued(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Executor-seconds admitted so far, per tenant.
    pub fn served_s(&self) -> &[f64] {
        &self.served_s
    }

    pub fn enqueue(&mut self, job: QueuedJob) {
        self.queues[job.tenant].push_back(job);
    }

    /// Which tenant's head-of-line job should be admitted next, per the
    /// policy. `None` when every queue is empty.
    fn next_tenant(&self) -> Option<usize> {
        match self.plan.policy {
            FairnessPolicy::Fifo => self
                .queues
                .iter()
                .enumerate()
                .filter_map(|(t, q)| q.front().map(|j| (j.seq, t)))
                .min()
                .map(|(_, t)| t),
            FairnessPolicy::WeightedFair => self
                .queues
                .iter()
                .enumerate()
                .filter(|(_, q)| !q.is_empty())
                .map(|(t, _)| t)
                .min_by(|&a, &b| {
                    let ka = self.served_s[a] / self.plan.weight(a);
                    let kb = self.served_s[b] / self.plan.weight(b);
                    // Total order: served_s is finite and weights ≥ 1
                    // for non-negative skew; ties go to the lower index.
                    ka.partial_cmp(&kb)
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(a.cmp(&b))
                }),
        }
    }

    /// Pop the next job to admit if its demand fits in `free_slots`;
    /// head-of-line blocking otherwise. Charges the tenant's served
    /// meter on admission.
    pub fn pick(&mut self, free_slots: usize) -> Option<QueuedJob> {
        let t = self.next_tenant()?;
        if self.queues[t].front()?.demand > free_slots {
            return None;
        }
        let job = self.queues[t].pop_front()?;
        self.served_s[t] += job.exec_s;
        Some(job)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(j: usize, tenant: usize, demand: usize, exec_s: f64) -> QueuedJob {
        QueuedJob {
            job: j,
            tenant,
            demand,
            exec_s,
            seq: j as u64,
            arrive_at: 0,
        }
    }

    fn sched(policy: FairnessPolicy, count: usize, skew: f64) -> TenantScheduler {
        TenantScheduler::new(TenantPlan {
            count,
            policy,
            weight_skew: skew,
        })
    }

    #[test]
    fn fifo_admits_in_global_arrival_order() {
        let mut s = sched(FairnessPolicy::Fifo, 3, 0.0);
        s.enqueue(job(2, 2, 1, 1.0));
        s.enqueue(job(0, 1, 1, 1.0));
        s.enqueue(job(1, 0, 1, 1.0));
        let order: Vec<usize> =
            (0..3).map(|_| s.pick(10).unwrap().job).collect();
        assert_eq!(order, vec![0, 1, 2]);
        assert!(s.pick(10).is_none());
    }

    #[test]
    fn head_of_line_blocks_until_slots_fit() {
        let mut s = sched(FairnessPolicy::Fifo, 1, 0.0);
        s.enqueue(job(0, 0, 8, 1.0));
        s.enqueue(job(1, 0, 1, 1.0));
        // The wide head blocks even though the second job would fit.
        assert!(s.pick(4).is_none());
        assert_eq!(s.queued(), 2);
        assert_eq!(s.pick(8).unwrap().job, 0);
        assert_eq!(s.pick(1).unwrap().job, 1);
    }

    #[test]
    fn weighted_fair_prefers_the_underserved_tenant() {
        let mut s = sched(FairnessPolicy::WeightedFair, 2, 0.0);
        s.enqueue(job(0, 0, 1, 100.0));
        s.enqueue(job(1, 0, 1, 100.0));
        s.enqueue(job(2, 1, 1, 1.0));
        // Equal weights, nothing served: tie goes to tenant 0; its 100
        // exec-s charge then pushes tenant 1 ahead of tenant 0's second
        // job.
        assert_eq!(s.pick(10).unwrap().job, 0);
        assert_eq!(s.pick(10).unwrap().job, 2);
        assert_eq!(s.pick(10).unwrap().job, 1);
    }

    #[test]
    fn weights_buy_a_larger_share() {
        // Tenant 1 has weight 3 (skew 2): after both serve one unit,
        // tenant 1's normalized share (1/3) is below tenant 0's (1/1),
        // so tenant 1 goes next.
        let mut s = sched(FairnessPolicy::WeightedFair, 2, 2.0);
        assert_eq!(s.plan().weight(0), 1.0);
        assert_eq!(s.plan().weight(1), 3.0);
        s.enqueue(job(0, 0, 1, 1.0));
        s.enqueue(job(1, 1, 1, 1.0));
        s.enqueue(job(2, 0, 1, 1.0));
        s.enqueue(job(3, 1, 1, 1.0));
        assert_eq!(s.pick(10).unwrap().job, 0);
        assert_eq!(s.pick(10).unwrap().job, 1);
        // served: t0=1/1=1.0, t1=1/3≈0.33 → tenant 1 again.
        assert_eq!(s.pick(10).unwrap().job, 3);
        assert_eq!(s.pick(10).unwrap().job, 2);
    }

    #[test]
    fn served_meter_accumulates_on_admission() {
        let mut s = sched(FairnessPolicy::Fifo, 2, 0.0);
        s.enqueue(job(0, 0, 1, 2.5));
        s.enqueue(job(1, 1, 1, 4.0));
        s.pick(10).unwrap();
        s.pick(10).unwrap();
        assert_eq!(s.served_s(), &[2.5, 4.0]);
    }
}
