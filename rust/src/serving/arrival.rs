//! Job-arrival generators for the multi-tenant serving layer.
//!
//! A serving run replays a *stream* of DAG jobs instead of a single DAG.
//! Arrival times come from an [`ArrivalStream`] — a dedicated RNG stream
//! derived from a salted split of the run seed, exactly like
//! `FaultStream`/`CrashStream` — so enabling the serving layer can never
//! shift the main simulation RNG, and a plan that produces no arrivals
//! (zero jobs, or a zero-rate Poisson process) consumes nothing: it is
//! bit-identical to having no serving layer at all.
//!
//! Two generators are provided: **Poisson** (exponential inter-arrival
//! gaps at `rate_per_s`, the open-loop production model) and **trace**
//! (a deterministic fixed gap, for replayable load shapes; it draws
//! nothing from the stream).

use crate::sim::{secs, Time};
use crate::util::Rng;

/// Salt XORed into the run seed to derive the dedicated arrival stream.
/// Any fixed constant works; it only has to be distinct from the plain
/// run seed and the fault/crash salts so the streams never alias.
const ARRIVAL_STREAM_SALT: u64 = 0xA441_7A1E_0B5E_55ED;

/// How inter-arrival gaps are produced.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalMode {
    /// Exponential gaps with mean `1 / rate_per_s` (open-loop Poisson).
    Poisson,
    /// Deterministic fixed gap of `trace_gap_s` (replayed trace).
    Trace,
}

/// One job-stream shape: generator mode, rate, and stream length.
/// `Copy`: three scalars + a mode, passed by value like `FaultPlan`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ArrivalPlan {
    pub mode: ArrivalMode,
    /// Poisson mean arrival rate (jobs/s); ignored in trace mode.
    pub rate_per_s: f64,
    /// Number of jobs in the stream.
    pub jobs: u64,
    /// Trace inter-arrival gap (s); ignored in Poisson mode.
    pub trace_gap_s: f64,
}

impl Default for ArrivalPlan {
    fn default() -> Self {
        ArrivalPlan {
            mode: ArrivalMode::Poisson,
            rate_per_s: 2.0,
            jobs: 1000,
            trace_gap_s: 0.5,
        }
    }
}

impl ArrivalPlan {
    pub fn poisson(rate_per_s: f64, jobs: u64) -> ArrivalPlan {
        ArrivalPlan {
            mode: ArrivalMode::Poisson,
            rate_per_s,
            jobs,
            ..ArrivalPlan::default()
        }
    }

    pub fn trace(trace_gap_s: f64, jobs: u64) -> ArrivalPlan {
        ArrivalPlan {
            mode: ArrivalMode::Trace,
            trace_gap_s,
            jobs,
            ..ArrivalPlan::default()
        }
    }

    /// Whether this plan produces no arrivals at all. Empty plans draw
    /// nothing from the arrival stream and run no jobs — the serving
    /// layer degenerates to a no-op.
    pub fn is_empty(&self) -> bool {
        self.jobs == 0
            || (self.mode == ArrivalMode::Poisson && self.rate_per_s <= 0.0)
    }
}

/// The dedicated arrival RNG stream for one run: inter-arrival draws
/// come from here and *only* from here (salted split of the run seed,
/// distinct from the fault and crash salts), so toggling the serving
/// layer can never perturb engine-internal streams.
#[derive(Debug, Clone)]
pub struct ArrivalStream {
    plan: ArrivalPlan,
    rng: Rng,
}

impl ArrivalStream {
    /// Derive the arrival stream for a run from its seed (salted split —
    /// independent of `Rng::new(seed)`, the fault stream, and the crash
    /// stream).
    pub fn for_run(plan: ArrivalPlan, seed: u64) -> ArrivalStream {
        ArrivalStream {
            plan,
            rng: Rng::new(seed ^ ARRIVAL_STREAM_SALT),
        }
    }

    pub fn plan(&self) -> ArrivalPlan {
        self.plan
    }

    /// Next inter-arrival gap. Poisson mode draws one uniform from the
    /// stream; trace mode draws nothing (deterministic gap).
    fn next_gap(&mut self) -> Time {
        match self.plan.mode {
            ArrivalMode::Trace => secs(self.plan.trace_gap_s),
            ArrivalMode::Poisson => {
                // Inverse-CDF exponential: u ∈ [0, 1) keeps 1-u ∈ (0, 1],
                // so the gap is finite and non-negative.
                let u = self.rng.f64();
                secs(-(1.0 - u).ln() / self.plan.rate_per_s)
            }
        }
    }

    /// All arrival times of the stream (cumulative gaps from t=0).
    /// Empty plans return no arrivals and consume nothing.
    pub fn arrival_times(&mut self) -> Vec<Time> {
        if self.plan.is_empty() {
            return Vec::new();
        }
        let mut t: Time = 0;
        (0..self.plan.jobs)
            .map(|_| {
                t += self.next_gap();
                t
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::faults::{
        CrashStream, FaultPlan, FaultStream, ShardCrashPlan,
    };
    use crate::sim::to_secs;

    #[test]
    fn zero_rate_poisson_plan_is_empty_and_never_draws() {
        let mut s = ArrivalStream::for_run(ArrivalPlan::poisson(0.0, 1000), 1);
        assert!(s.plan().is_empty());
        assert!(s.arrival_times().is_empty());
        // The stream was never consumed: it still equals a fresh one.
        let mut fresh =
            ArrivalStream::for_run(ArrivalPlan::poisson(0.0, 1000), 1);
        assert_eq!(s.rng.next_u64(), fresh.rng.next_u64());
    }

    #[test]
    fn zero_jobs_plan_is_empty() {
        let mut s = ArrivalStream::for_run(ArrivalPlan::poisson(4.0, 0), 2);
        assert!(s.arrival_times().is_empty());
        let mut fresh = ArrivalStream::for_run(ArrivalPlan::poisson(4.0, 0), 2);
        assert_eq!(s.rng.next_u64(), fresh.rng.next_u64());
    }

    #[test]
    fn trace_mode_is_deterministic_and_never_draws() {
        let mut s = ArrivalStream::for_run(ArrivalPlan::trace(0.25, 4), 3);
        assert_eq!(
            s.arrival_times(),
            vec![secs(0.25), secs(0.5), secs(0.75), secs(1.0)]
        );
        let mut fresh = ArrivalStream::for_run(ArrivalPlan::trace(0.25, 4), 3);
        assert_eq!(s.rng.next_u64(), fresh.rng.next_u64());
    }

    #[test]
    fn stream_is_deterministic_per_seed() {
        let plan = ArrivalPlan::poisson(3.0, 64);
        let mut a = ArrivalStream::for_run(plan, 7);
        let mut b = ArrivalStream::for_run(plan, 7);
        assert_eq!(a.arrival_times(), b.arrival_times());
        let mut c = ArrivalStream::for_run(plan, 8);
        assert_ne!(a.arrival_times(), c.arrival_times());
    }

    #[test]
    fn arrivals_are_monotone_nondecreasing() {
        let mut s = ArrivalStream::for_run(ArrivalPlan::poisson(50.0, 500), 5);
        let ts = s.arrival_times();
        assert_eq!(ts.len(), 500);
        assert!(ts.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn poisson_rate_is_roughly_respected() {
        // 10k arrivals at 4 jobs/s should span ~2500 s of virtual time.
        let mut s = ArrivalStream::for_run(ArrivalPlan::poisson(4.0, 10_000), 6);
        let span = to_secs(*s.arrival_times().last().unwrap());
        assert!((2_250.0..2_750.0).contains(&span), "span={span}");
    }

    #[test]
    fn stream_differs_from_the_main_seed_stream() {
        // The salted derivation must not alias the plain run stream.
        let mut main = Rng::new(7);
        let mut arr = ArrivalStream::for_run(ArrivalPlan::poisson(1.0, 8), 7);
        let main_draws: Vec<u64> = (0..8).map(|_| main.next_u64()).collect();
        let arr_draws: Vec<u64> = (0..8).map(|_| arr.rng.next_u64()).collect();
        assert_ne!(main_draws, arr_draws);
    }

    #[test]
    fn stream_is_distinct_from_fault_and_crash_streams() {
        // Behavioral aliasing check (the other salts are private): if
        // the arrival stream shared a salt with either, the first 64
        // p=0.5 coin flips would be identical.
        let seed = 7;
        let mut arr =
            ArrivalStream::for_run(ArrivalPlan::poisson(1.0, 64), seed);
        let arr_bits: Vec<bool> =
            (0..64).map(|_| arr.rng.f64() < 0.5).collect();
        let mut fault =
            FaultStream::for_run(FaultPlan::with_failure_rate(0.5), seed);
        let fault_bits: Vec<bool> =
            (0..64).map(|_| fault.attempt_fails()).collect();
        let mut crash = CrashStream::for_run(
            ShardCrashPlan::with_crashes(0.5, u32::MAX),
            seed,
        );
        let crash_bits: Vec<bool> =
            (0..64).map(|_| crash.op_crashes()).collect();
        assert_ne!(arr_bits, fault_bits);
        assert_ne!(arr_bits, crash_bits);
    }
}
