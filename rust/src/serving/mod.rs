//! Multi-tenant job-stream serving over the wukong engine.
//!
//! Everything before this subsystem ran one DAG for one implicit
//! tenant. `wukong serve` instead replays a continuous stream of DAG
//! jobs from many tenants — Poisson or trace arrivals
//! ([`ArrivalStream`], a salted split of the run seed like
//! `FaultStream`/`CrashStream`) — multiplexed onto one shared Lambda
//! pool and one shared KVS with job-scoped keys, per-tenant admission
//! under a pluggable fairness policy ([`FairnessPolicy`]), warm-executor
//! reuse between jobs, and per-tenant billing rollups. The result is a
//! [`ServingReport`] whose every field is virtual-time-derived, so it
//! is byte-identical across `--threads` and reruns — the `verify
//! --serving` axis gates job conservation (admitted = completed ⊕
//! failed) and that determinism.

pub mod arrival;
pub mod report;
pub mod session;
pub mod tenants;

pub use arrival::{ArrivalMode, ArrivalPlan, ArrivalStream};
pub use report::{ServingReport, TenantStats};
pub use session::run_serving;
pub use tenants::{FairnessPolicy, QueuedJob, TenantPlan, TenantScheduler};
