//! Durability tier for the storage model: per-shard write-ahead log +
//! periodic snapshots, crash recovery by replay, and lossless
//! checkpoint/restore of the durable state.
//!
//! Modeled on the classic WAL/snapshot design (strata-core style): every
//! acknowledged write is appended to the shard's WAL *before* it is
//! acknowledged (synchronous logging — the simulated fsync cost is
//! `StorageConfig::wal_fsync_s` on the write path), and once the WAL
//! reaches `StorageConfig::snapshot_every_ops` records the shard takes a
//! snapshot of its live object table and truncates the WAL. A crash
//! drops the shard's live state; recovery rebuilds it by loading the
//! snapshot and replaying the WAL suffix in order (last-write-wins).
//!
//! **The recovery gate.** Because the WAL is synchronous, a crash never
//! loses an acknowledged op — a recovered shard serves exactly the bytes
//! the crash-free run would have served. That is the property `wukong
//! verify --crashes` checks differentially: a run interrupted and
//! recovered at *any* crash point must be byte-identical to the
//! uninterrupted run (same task outcomes, same KVS byte meters) modulo
//! the recovery counters in [`DurabilityMetrics`]. To keep that gate
//! checkable, recovery is *time-decoupled*: the replay cost
//! (`recovery_base_s + replayed_ops * replay_op_s`) is metered as
//! `stall_s` instead of being injected into the event calendar. A real
//! stall would shift op completion times, which on the wukong engine
//! reorders MDS fan-in claims and changes which executor wins a child —
//! legitimately different bytes, and no differential gate could hold.
//! The modeling stance: crashes cost recovery work (visible in the
//! meters), never data (checked byte-for-byte, run against run).

use std::collections::HashMap;

/// Per-run durability meters, surfaced in `RunMetrics::durability`.
/// The WAL/snapshot meters are part of the data plane (identical
/// between a crashed and a crash-free run over the same ops); the
/// recovery meters (`recoveries`, `replayed_ops`, `stall_s`) are the
/// only fields a crash may perturb.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct DurabilityMetrics {
    /// WAL records appended (one per acknowledged mutation).
    pub wal_appends: u64,
    /// Bytes appended to WALs (16-byte record header + payload).
    pub wal_bytes: u64,
    /// Snapshots taken (WAL truncations).
    pub snapshots: u64,
    /// Bytes written into snapshots (16 bytes + payload per live key).
    pub snapshot_bytes: u64,
    /// Shard crash-recoveries performed.
    pub recoveries: u64,
    /// Snapshot entries + WAL records replayed across all recoveries.
    pub replayed_ops: u64,
    /// Total simulated recovery time (metered, not injected into the
    /// event calendar — see the module docs).
    pub stall_s: f64,
}

impl DurabilityMetrics {
    /// Sum two meter sets (e.g. the KVS tier + the MDS tier).
    pub fn merged(self, other: DurabilityMetrics) -> DurabilityMetrics {
        DurabilityMetrics {
            wal_appends: self.wal_appends + other.wal_appends,
            wal_bytes: self.wal_bytes + other.wal_bytes,
            snapshots: self.snapshots + other.snapshots,
            snapshot_bytes: self.snapshot_bytes + other.snapshot_bytes,
            recoveries: self.recoveries + other.recoveries,
            replayed_ops: self.replayed_ops + other.replayed_ops,
            stall_s: self.stall_s + other.stall_s,
        }
    }
}

/// One replayable WAL record: a completed write of `bytes` under `key`.
/// Fixed 16-byte header (two u64s) + the payload it describes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpRecord {
    pub key: u64,
    pub bytes: u64,
}

/// Serialized size of one record header.
pub const RECORD_HEADER_BYTES: u64 = 16;

/// One shard's durable state: the live object table (authoritative
/// in-memory state), the last snapshot, and the WAL suffix since it.
/// Invariant: `live == replay(snapshot, wal)` — recovery asserts it.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ShardDurability {
    live: HashMap<u64, u64>,
    snapshot: Vec<(u64, u64)>,
    wal: Vec<OpRecord>,
}

impl ShardDurability {
    /// Append a write to the WAL and apply it to the live table.
    /// Returns the bytes appended to the WAL (header + payload).
    pub fn apply_write(&mut self, key: u64, bytes: u64) -> u64 {
        self.wal.push(OpRecord { key, bytes });
        self.live.insert(key, bytes);
        RECORD_HEADER_BYTES + bytes
    }

    /// Take a snapshot if the WAL has reached `every` records
    /// (`every == 0` disables snapshotting). Returns the serialized
    /// snapshot size in bytes if one was taken.
    pub fn maybe_snapshot(&mut self, every: u64) -> Option<u64> {
        if every == 0 || (self.wal.len() as u64) < every {
            return None;
        }
        let mut entries: Vec<(u64, u64)> = self.live.iter().map(|(&k, &v)| (k, v)).collect();
        entries.sort_unstable();
        let size: u64 = entries
            .iter()
            .map(|&(_, v)| RECORD_HEADER_BYTES + v)
            .sum();
        self.snapshot = entries;
        self.wal.clear();
        Some(size)
    }

    /// Rebuild the live table from snapshot + WAL replay, exactly as
    /// recovery would (last-write-wins over the snapshot image).
    fn replayed(&self) -> HashMap<u64, u64> {
        let mut live: HashMap<u64, u64> = self.snapshot.iter().copied().collect();
        for rec in &self.wal {
            live.insert(rec.key, rec.bytes);
        }
        live
    }

    /// Crash this shard and recover it: drop the live table, replay
    /// snapshot + WAL, and install the rebuilt state. Returns the
    /// number of replayed records (snapshot entries + WAL suffix).
    /// Panics if the rebuilt state differs from the pre-crash live
    /// table — that would mean an acknowledged op was never logged,
    /// i.e. the WAL invariant is broken and the recovery gate with it.
    pub fn crash_and_recover(&mut self) -> u64 {
        let rebuilt = self.replayed();
        let pre_crash = std::mem::take(&mut self.live);
        assert_eq!(
            rebuilt, pre_crash,
            "WAL replay diverged from the acknowledged state"
        );
        self.live = rebuilt;
        (self.snapshot.len() + self.wal.len()) as u64
    }

    /// Number of live keys on this shard.
    pub fn live_len(&self) -> usize {
        self.live.len()
    }

    /// Stored size of `key`, if present.
    pub fn get(&self, key: u64) -> Option<u64> {
        self.live.get(&key).copied()
    }

    /// WAL suffix length (records since the last snapshot).
    pub fn wal_len(&self) -> usize {
        self.wal.len()
    }

    /// Serialize this shard's durable state (checkpoint). Hand-rolled
    /// little-endian layout so the round-trip is exact and
    /// dependency-free:
    /// `[n_live][(key,bytes)*n_live sorted][n_snap][(key,bytes)*][n_wal][(key,bytes)*]`.
    pub fn checkpoint(&self, out: &mut Vec<u8>) {
        let mut live: Vec<(u64, u64)> = self.live.iter().map(|(&k, &v)| (k, v)).collect();
        live.sort_unstable();
        put_u64(out, live.len() as u64);
        for (k, v) in live {
            put_u64(out, k);
            put_u64(out, v);
        }
        put_u64(out, self.snapshot.len() as u64);
        for &(k, v) in &self.snapshot {
            put_u64(out, k);
            put_u64(out, v);
        }
        put_u64(out, self.wal.len() as u64);
        for rec in &self.wal {
            put_u64(out, rec.key);
            put_u64(out, rec.bytes);
        }
    }

    /// Deserialize a shard checkpoint written by [`checkpoint`]
    /// (consumes from `at`, advancing it).
    ///
    /// [`checkpoint`]: ShardDurability::checkpoint
    pub fn restore(buf: &[u8], at: &mut usize) -> Result<ShardDurability, String> {
        let n_live = take_u64(buf, at)?;
        let mut live = HashMap::with_capacity(n_live as usize);
        for _ in 0..n_live {
            let k = take_u64(buf, at)?;
            let v = take_u64(buf, at)?;
            live.insert(k, v);
        }
        let n_snap = take_u64(buf, at)?;
        let mut snapshot = Vec::with_capacity(n_snap as usize);
        for _ in 0..n_snap {
            let k = take_u64(buf, at)?;
            let v = take_u64(buf, at)?;
            snapshot.push((k, v));
        }
        let n_wal = take_u64(buf, at)?;
        let mut wal = Vec::with_capacity(n_wal as usize);
        for _ in 0..n_wal {
            let key = take_u64(buf, at)?;
            let bytes = take_u64(buf, at)?;
            wal.push(OpRecord { key, bytes });
        }
        Ok(ShardDurability {
            live,
            snapshot,
            wal,
        })
    }
}

pub(crate) fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn take_u64(buf: &[u8], at: &mut usize) -> Result<u64, String> {
    let end = at
        .checked_add(8)
        .filter(|&e| e <= buf.len())
        .ok_or_else(|| format!("truncated checkpoint at byte {at}"))?;
    let mut raw = [0u8; 8];
    raw.copy_from_slice(&buf[*at..end]);
    *at = end;
    Ok(u64::from_le_bytes(raw))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled(ops: &[(u64, u64)], snapshot_every: u64) -> ShardDurability {
        let mut s = ShardDurability::default();
        for &(k, v) in ops {
            s.apply_write(k, v);
            s.maybe_snapshot(snapshot_every);
        }
        s
    }

    #[test]
    fn wal_replay_reconstructs_live_state_at_every_crash_point() {
        let ops: Vec<(u64, u64)> = (0..64u64).map(|i| (i % 7, 100 + i)).collect();
        for cut in 0..=ops.len() {
            for every in [0u64, 1, 4, 16] {
                let mut s = filled(&ops[..cut], every);
                let expected: HashMap<u64, u64> = s.live.clone();
                let replayed = s.crash_and_recover();
                assert_eq!(s.live, expected, "cut={cut} every={every}");
                assert_eq!(
                    replayed as usize,
                    s.snapshot.len() + s.wal.len(),
                    "cut={cut} every={every}"
                );
            }
        }
    }

    #[test]
    fn snapshot_compacts_the_wal_and_preserves_recovery() {
        let mut s = ShardDurability::default();
        for i in 0..10u64 {
            s.apply_write(i % 3, i);
            s.maybe_snapshot(4);
        }
        // 10 appends with a 4-record snapshot cadence: the WAL was
        // truncated twice, leaving a 2-record suffix over 3 live keys.
        assert_eq!(s.wal_len(), 2);
        assert_eq!(s.snapshot.len(), 3);
        assert_eq!(s.live_len(), 3);
        let pre = s.live.clone();
        s.crash_and_recover();
        assert_eq!(s.live, pre);
    }

    #[test]
    fn snapshot_size_meters_header_plus_payload() {
        let mut s = ShardDurability::default();
        assert_eq!(s.apply_write(1, 100), 116);
        assert_eq!(s.apply_write(2, 50), 66);
        assert_eq!(s.maybe_snapshot(0), None, "every=0 disables snapshots");
        assert_eq!(s.maybe_snapshot(2), Some(16 + 100 + 16 + 50));
        assert_eq!(s.wal_len(), 0);
    }

    #[test]
    fn last_write_wins_on_replay() {
        let mut s = ShardDurability::default();
        s.apply_write(7, 10);
        s.apply_write(7, 20);
        s.apply_write(7, 30);
        s.crash_and_recover();
        assert_eq!(s.get(7), Some(30));
        assert_eq!(s.live_len(), 1);
    }

    #[test]
    fn checkpoint_round_trips_losslessly() {
        let ops: Vec<(u64, u64)> = (0..50u64).map(|i| (i * 31 % 11, i + 1)).collect();
        for cut in [0, 1, 7, 25, 50] {
            let s = filled(&ops[..cut], 8);
            let mut buf = Vec::new();
            s.checkpoint(&mut buf);
            let mut at = 0;
            let restored = ShardDurability::restore(&buf, &mut at).unwrap();
            assert_eq!(at, buf.len(), "cut={cut}: trailing bytes");
            assert_eq!(restored, s, "cut={cut}");
            // Re-checkpointing the restored state is byte-identical.
            let mut buf2 = Vec::new();
            restored.checkpoint(&mut buf2);
            assert_eq!(buf2, buf, "cut={cut}");
        }
    }

    #[test]
    fn restore_rejects_truncated_input() {
        let s = filled(&[(1, 10), (2, 20)], 0);
        let mut buf = Vec::new();
        s.checkpoint(&mut buf);
        for cut in [0, 3, 8, buf.len() - 1] {
            let mut at = 0;
            assert!(
                ShardDurability::restore(&buf[..cut], &mut at).is_err(),
                "cut={cut} should fail"
            );
        }
    }

    #[test]
    fn merged_metrics_sum_fieldwise() {
        let a = DurabilityMetrics {
            wal_appends: 1,
            wal_bytes: 2,
            snapshots: 3,
            snapshot_bytes: 4,
            recoveries: 5,
            replayed_ops: 6,
            stall_s: 0.5,
        };
        let b = DurabilityMetrics {
            wal_appends: 10,
            wal_bytes: 20,
            snapshots: 30,
            snapshot_bytes: 40,
            recoveries: 50,
            replayed_ops: 60,
            stall_s: 1.5,
        };
        let m = a.merged(b);
        assert_eq!(m.wal_appends, 11);
        assert_eq!(m.wal_bytes, 22);
        assert_eq!(m.snapshots, 33);
        assert_eq!(m.snapshot_bytes, 44);
        assert_eq!(m.recoveries, 55);
        assert_eq!(m.replayed_ops, 66);
        assert_eq!(m.stall_s, 2.0);
    }
}
