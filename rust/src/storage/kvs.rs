//! Sharded key-value store model (the paper's Fargate Redis cluster / S3).
//!
//! Each shard is a FIFO wire: an op occupies its shard for
//! `op_latency + bytes / shard_bw`, so concurrent large transfers to the
//! same shard queue behind each other — the contention that Figs. 13–16
//! measure. S3 mode adds an IOPS gate (request throttling) in front of
//! the transfer. Keys map to shards by multiplicative hash, matching the
//! consistent-hash spread of the real system.
//!
//! Since the durable-KVS PR every shard also carries a durability tier
//! ([`ShardDurability`]): acknowledged writes are WAL-logged
//! synchronously (`wal_fsync_s` on the write path), snapshots truncate
//! the WAL every `snapshot_every_ops` records, and a [`CrashStream`]
//! (salted split of the run seed, like fault draws) may crash the shard
//! an op is being served by. A crashed shard recovers by replaying
//! snapshot + WAL — the replay really runs and is asserted equal to the
//! pre-crash state — while the recovery *cost* is metered in
//! [`DurabilityMetrics`] rather than injected into the event calendar
//! (time-decoupled recovery; see `storage::durability` for why that is
//! what makes the `verify --crashes` byte-identity gate checkable).

use super::durability::{self, DurabilityMetrics, ShardDurability};
use crate::config::StorageConfig;
use crate::platform::faults::{CrashStream, ShardCrashPlan};
use crate::sim::{secs, FifoResource, Time};

/// Compose a job-scoped key for the multi-tenant serving layer: the job
/// id is folded into the key through an odd-multiplier mix before shard
/// routing. The multiplier is a bijection on `u64`, so distinct jobs get
/// distinct salts — two concurrent jobs using identical task-level keys
/// (same task names, same per-task key derivation) can never collide on
/// an intermediate-object key, and their traffic spreads over shards
/// independently.
pub fn job_scoped_key(job: u64, key: u64) -> u64 {
    key ^ job.wrapping_add(1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Byte-exact I/O counters (Figs. 3, 4, 15, 16).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct KvsMetrics {
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub reads: u64,
    pub writes: u64,
}

/// The simulated KVS cluster.
#[derive(Debug)]
pub struct KvsModel {
    cfg: StorageConfig,
    shards: Vec<FifoResource>,
    iops_gates: Vec<FifoResource>,
    durable: Vec<ShardDurability>,
    crashes: CrashStream,
    pub metrics: KvsMetrics,
    pub durability: DurabilityMetrics,
}

impl KvsModel {
    /// Crash-free model (the zero-rate plan draws nothing, so this is
    /// bit-identical to a `with_crashes` model whose plan never fires).
    pub fn new(cfg: StorageConfig) -> KvsModel {
        KvsModel::with_crashes(cfg, ShardCrashPlan::with_crashes(0.0, 0), 0)
    }

    /// Model with a shard-crash plan; `seed` is the run seed (the crash
    /// stream is a salted split of it — see `platform::faults`).
    pub fn with_crashes(
        cfg: StorageConfig,
        plan: ShardCrashPlan,
        seed: u64,
    ) -> KvsModel {
        let n = cfg.n_shards.max(1);
        KvsModel {
            shards: (0..n).map(|_| FifoResource::new()).collect(),
            iops_gates: (0..n).map(|_| FifoResource::new()).collect(),
            durable: (0..n).map(|_| ShardDurability::default()).collect(),
            crashes: CrashStream::for_run(plan, seed),
            cfg,
            metrics: KvsMetrics::default(),
            durability: DurabilityMetrics::default(),
        }
    }

    /// Which shard serves `key` (multiplicative hash; public so tests
    /// can pin routing stability).
    pub fn shard_of(&self, key: u64) -> usize {
        (key.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32) as usize
            % self.shards.len()
    }

    /// Queue the op on its shard (plus the optional IOPS gate), then
    /// draw a crash point: each served op may crash its shard per the
    /// plan, forcing a snapshot + WAL replay. The recovery is real
    /// (state dropped and rebuilt, asserted byte-identical) but its
    /// cost is metered, not injected into the calendar — see the
    /// module docs.
    fn transfer(
        &mut self,
        now: Time,
        key: u64,
        bytes: u64,
        extra_service_s: f64,
    ) -> Time {
        let s = self.shard_of(key);
        let mut t = now;
        if self.cfg.iops_limit > 0.0 {
            let gate = secs(1.0 / self.cfg.iops_limit);
            let (_, end) = self.iops_gates[s].acquire(t, gate);
            t = end;
        }
        let service = secs(
            self.cfg.op_latency_s
                + extra_service_s
                + bytes as f64 / self.cfg.shard_bw,
        );
        let (_, end) = self.shards[s].acquire(t, service);
        if self.crashes.op_crashes() {
            self.recover(s);
        }
        end
    }

    /// Crash-recover shard `s`: replay snapshot + WAL (asserted equal
    /// to the acknowledged pre-crash state) and meter the cost.
    fn recover(&mut self, s: usize) {
        let replayed = self.durable[s].crash_and_recover();
        self.durability.recoveries += 1;
        self.durability.replayed_ops += replayed;
        self.durability.stall_s += self.cfg.recovery_base_s
            + replayed as f64 * self.cfg.replay_op_s;
    }

    /// Read `bytes` under `key`; returns completion time.
    pub fn read(&mut self, now: Time, key: u64, bytes: u64) -> Time {
        self.metrics.bytes_read += bytes;
        self.metrics.reads += 1;
        self.transfer(now, key, bytes, 0.0)
    }

    /// Write `bytes` under `key`; returns completion time. The write
    /// is WAL-logged before it is acknowledged (synchronous logging:
    /// `wal_fsync_s` rides on the service time), so no acknowledged
    /// write can be lost to a crash.
    pub fn write(&mut self, now: Time, key: u64, bytes: u64) -> Time {
        self.metrics.bytes_written += bytes;
        self.metrics.writes += 1;
        let s = self.shard_of(key);
        let appended = self.durable[s].apply_write(key, bytes);
        self.durability.wal_appends += 1;
        self.durability.wal_bytes += appended;
        if let Some(size) =
            self.durable[s].maybe_snapshot(self.cfg.snapshot_every_ops)
        {
            self.durability.snapshots += 1;
            self.durability.snapshot_bytes += size;
        }
        self.transfer(now, key, bytes, self.cfg.wal_fsync_s)
    }

    /// Aggregate busy time across shards (utilization metric).
    pub fn busy_total(&self) -> Time {
        self.shards.iter().map(|s| s.busy_total()).sum()
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// The durable state of every shard (tests pin recovery and
    /// checkpoint semantics against it).
    pub fn durable_state(&self) -> &[ShardDurability] {
        &self.durable
    }

    /// Serialize the durable tier of the whole cluster (checkpoint):
    /// shard count + every shard's live table, snapshot, and WAL. This
    /// is what survives a process restart — queues and meters are
    /// runtime state and restart empty, exactly as a real failover
    /// would.
    pub fn checkpoint(&self) -> Vec<u8> {
        let mut out = Vec::new();
        durability::put_u64(&mut out, self.durable.len() as u64);
        for d in &self.durable {
            d.checkpoint(&mut out);
        }
        out
    }

    /// Restore a checkpoint written by [`KvsModel::checkpoint`] into
    /// this model (must have the same shard count). Lossless: restoring
    /// and re-checkpointing yields byte-identical output.
    pub fn restore(&mut self, buf: &[u8]) -> Result<(), String> {
        let mut at = 0;
        let n = durability::take_u64(buf, &mut at)? as usize;
        if n != self.durable.len() {
            return Err(format!(
                "checkpoint has {n} shards, model has {}",
                self.durable.len()
            ));
        }
        let mut durable = Vec::with_capacity(n);
        for _ in 0..n {
            durable.push(ShardDurability::restore(buf, &mut at)?);
        }
        if at != buf.len() {
            return Err(format!(
                "checkpoint has {} trailing bytes",
                buf.len() - at
            ));
        }
        self.durable = durable;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StorageConfig;
    use crate::sim::MICROS_PER_SEC;

    fn model(n_shards: usize) -> KvsModel {
        KvsModel::new(StorageConfig {
            n_shards,
            shard_bw: 100e6,
            op_latency_s: 0.001,
            iops_limit: 0.0,
            ..StorageConfig::default()
        })
    }

    #[test]
    fn read_time_is_latency_plus_transfer() {
        let mut k = model(4);
        let end = k.read(0, 1, 100_000_000); // 1 s transfer at 100 MB/s
        assert_eq!(end, secs(1.001));
    }

    #[test]
    fn same_shard_ops_queue() {
        let mut k = model(1);
        let a = k.write(0, 1, 100_000_000);
        let b = k.write(0, 2, 100_000_000);
        assert_eq!(a, secs(1.001));
        assert_eq!(b, secs(2.002));
    }

    #[test]
    fn different_shards_overlap() {
        let mut k = model(64);
        // find two keys on different shards
        let (mut k1, mut k2) = (1u64, 2u64);
        while k.shard_of(k1) == k.shard_of(k2) {
            k2 += 1;
        }
        let a = k.write(0, k1, 100_000_000);
        let b = k.write(0, k2, 100_000_000);
        assert_eq!(a, b);
    }

    #[test]
    fn metrics_are_byte_exact() {
        let mut k = model(4);
        k.write(0, 1, 1000);
        k.write(0, 2, 500);
        k.read(0, 1, 1000);
        assert_eq!(k.metrics.bytes_written, 1500);
        assert_eq!(k.metrics.bytes_read, 1000);
        assert_eq!(k.metrics.writes, 2);
        assert_eq!(k.metrics.reads, 1);
    }

    #[test]
    fn s3_iops_gate_throttles_small_ops() {
        let mut k = KvsModel::new(StorageConfig::default().s3());
        // Many tiny ops to one key: gated at iops_limit ops/sec.
        let key = 7;
        let mut last = 0;
        for _ in 0..100 {
            last = k.write(0, key, 1);
        }
        // 100 ops at 3500 IOPS ≈ 28.6 ms of gating (plus latency).
        assert!(last > 28 * MICROS_PER_SEC / 1000);
    }

    #[test]
    fn concurrent_large_writes_same_shard_queue_fifo() {
        // Two large writes *issued at the same instant* to keys on the
        // same shard must serialize (FIFO contention — the effect behind
        // Figs. 13–16), regardless of issue order.
        let mut k = model(8);
        let (k1, mut k2) = (1u64, 2u64);
        while k.shard_of(k1) != k.shard_of(k2) {
            k2 += 1;
        }
        let a = k.write(0, k1, 100_000_000); // 1 s at 100 MB/s
        let b = k.write(0, k2, 100_000_000);
        assert_eq!(a, secs(1.001));
        assert_eq!(b, secs(2.002), "same-shard writes must not overlap");
    }

    #[test]
    fn concurrent_large_writes_different_shards_proceed_in_parallel() {
        let mut k = model(64);
        let (k1, mut k2) = (1u64, 2u64);
        while k.shard_of(k1) == k.shard_of(k2) {
            k2 += 1;
        }
        let a = k.write(0, k1, 100_000_000);
        let b = k.write(0, k2, 100_000_000);
        let r = k.read(0, k2, 100_000_000); // queues behind b's shard only
        assert_eq!(a, secs(1.001));
        assert_eq!(b, secs(1.001), "different shards must overlap");
        assert_eq!(r, secs(2.002));
    }

    #[test]
    fn s3_iops_gate_delays_small_ops_beyond_latency() {
        // Isolate the IOPS gate from latency/bandwidth: with op_latency=0
        // and huge shard bandwidth, 50 tiny ops at 100 IOPS must take
        // ~0.5 s; ungated they are instantaneous.
        let gated_cfg = StorageConfig {
            mode: crate::config::KvsMode::S3,
            n_shards: 1,
            shard_bw: 1e15,
            op_latency_s: 0.0,
            iops_limit: 100.0,
            ..StorageConfig::default()
        };
        let mut gated = KvsModel::new(gated_cfg.clone());
        let mut ungated = KvsModel::new(StorageConfig {
            iops_limit: 0.0,
            ..gated_cfg
        });
        let mut last_gated = 0;
        let mut last_ungated = 0;
        for _ in 0..50 {
            last_gated = gated.write(0, 7, 1);
            last_ungated = ungated.write(0, 7, 1);
        }
        assert!(
            last_gated >= secs(0.49),
            "gated 50 ops at 100 IOPS ended at {last_gated}"
        );
        assert_eq!(last_ungated, 0, "ungated tiny ops must be instant");
    }

    #[test]
    fn more_shards_reduce_contention() {
        // 8 same-instant large writes: one shard serializes all of them;
        // many shards spread them out (strictly earlier completion).
        let finish = |n_shards: usize| {
            let mut k = model(n_shards);
            (0..8u64).map(|key| k.write(0, key, 100_000_000)).max().unwrap()
        };
        assert_eq!(finish(1), secs(8.008));
        assert!(finish(64) < secs(8.008));
    }

    #[test]
    fn keys_spread_across_shards() {
        let k = model(75);
        let mut counts = vec![0usize; 75];
        for key in 0..10_000u64 {
            counts[k.shard_of(key)] += 1;
        }
        let max = *counts.iter().max().unwrap();
        let min = *counts.iter().min().unwrap();
        assert!(min > 60 && max < 260, "imbalanced: {min}..{max}");
    }

    #[test]
    fn job_scoped_keys_never_collide_for_identical_task_keys() {
        // Two concurrent jobs running DAGs with identical task names
        // derive identical task-level keys; the job salt must keep
        // their intermediate-object namespaces fully disjoint.
        use std::collections::BTreeSet;
        let task_keys: Vec<u64> = (0..512).collect();
        let job_a: BTreeSet<u64> =
            task_keys.iter().map(|&k| job_scoped_key(0, k)).collect();
        let job_b: BTreeSet<u64> =
            task_keys.iter().map(|&k| job_scoped_key(1, k)).collect();
        assert_eq!(job_a.len(), 512, "scoping must stay injective per job");
        assert_eq!(job_b.len(), 512);
        assert!(job_a.is_disjoint(&job_b), "jobs share an object key");
        // And the scoped keys still route across shards, not to one.
        let k = model(75);
        let shards: BTreeSet<usize> =
            job_a.iter().map(|&key| k.shard_of(key)).collect();
        assert!(shards.len() > 30, "only {} shards used", shards.len());
    }

    #[test]
    fn job_scoping_is_deterministic_and_salts_differ_per_job() {
        assert_eq!(job_scoped_key(3, 77), job_scoped_key(3, 77));
        assert_ne!(job_scoped_key(3, 77), job_scoped_key(4, 77));
        // job ids are salted through a u64 bijection: same key, 1 000
        // different jobs, 1 000 different scoped keys.
        let scoped: std::collections::BTreeSet<u64> =
            (0..1000).map(|j| job_scoped_key(j, 42)).collect();
        assert_eq!(scoped.len(), 1000);
    }

    fn crash_model(n_shards: usize, p: f64, max: u32, seed: u64) -> KvsModel {
        KvsModel::with_crashes(
            StorageConfig {
                n_shards,
                shard_bw: 100e6,
                op_latency_s: 0.001,
                iops_limit: 0.0,
                ..StorageConfig::default()
            },
            crate::platform::faults::ShardCrashPlan::with_crashes(p, max),
            seed,
        )
    }

    #[test]
    fn wal_fsync_adds_to_write_service_time_only() {
        let mut k = KvsModel::new(StorageConfig {
            n_shards: 1,
            shard_bw: 100e6,
            op_latency_s: 0.001,
            iops_limit: 0.0,
            wal_fsync_s: 0.5,
            ..StorageConfig::default()
        });
        assert_eq!(k.read(0, 1, 100_000_000), secs(1.001));
        assert_eq!(k.write(secs(2.0), 1, 100_000_000), secs(3.501));
    }

    #[test]
    fn wal_and_snapshot_meters_follow_the_cadence() {
        let mut k = KvsModel::new(StorageConfig {
            n_shards: 1,
            snapshot_every_ops: 2,
            ..StorageConfig::default()
        });
        for key in 0..4u64 {
            k.write(0, key, 100);
        }
        assert_eq!(k.durability.wal_appends, 4);
        assert_eq!(k.durability.wal_bytes, 4 * (16 + 100));
        // WAL hits 2 records twice on the single shard: two snapshots,
        // each of the full (growing) live table.
        assert_eq!(k.durability.snapshots, 2);
        assert_eq!(k.durability.snapshot_bytes, 2 * 116 + 4 * 116);
        assert_eq!(k.durable_state()[0].wal_len(), 0);
        assert_eq!(k.durable_state()[0].live_len(), 4);
    }

    #[test]
    fn crashes_are_time_decoupled_and_metered() {
        let mut plain = model(4);
        let mut crashy = crash_model(4, 1.0, 2, 9);
        let mut ends = (Vec::new(), Vec::new());
        for key in 0..6u64 {
            ends.0.push(plain.write(0, key, 1000));
            ends.1.push(crashy.write(0, key, 1000));
        }
        // Completion times and data-plane meters are untouched by the
        // two crashes; only the recovery meters move.
        assert_eq!(ends.0, ends.1);
        assert_eq!(plain.metrics, crashy.metrics);
        assert_eq!(crashy.durability.recoveries, 2);
        assert!(crashy.durability.replayed_ops >= 1);
        let expected_stall = 2.0 * crashy.cfg.recovery_base_s
            + crashy.durability.replayed_ops as f64 * crashy.cfg.replay_op_s;
        assert!(
            (crashy.durability.stall_s - expected_stall).abs() < 1e-12,
            "stall={} expected={expected_stall}",
            crashy.durability.stall_s
        );
        assert_eq!(plain.durability.recoveries, 0);
        // The WAL-side meters match exactly: same ops, same appends.
        assert_eq!(plain.durability.wal_appends, crashy.durability.wal_appends);
        assert_eq!(plain.durability.wal_bytes, crashy.durability.wal_bytes);
    }

    #[test]
    fn zero_rate_crash_plan_is_bit_identical_to_crash_free() {
        let mut plain = KvsModel::new(StorageConfig::default());
        let mut zero = KvsModel::with_crashes(
            StorageConfig::default(),
            crate::platform::faults::ShardCrashPlan::with_crash_rate(0.0),
            0xDEAD_BEEF,
        );
        for key in 0..100u64 {
            assert_eq!(
                plain.write(0, key, key * 10),
                zero.write(0, key, key * 10)
            );
            assert_eq!(plain.read(0, key, key * 10), zero.read(0, key, key * 10));
        }
        assert_eq!(plain.metrics, zero.metrics);
        assert_eq!(plain.durability, zero.durability);
        assert_eq!(zero.durability.recoveries, 0);
    }

    #[test]
    fn recovery_preserves_durable_state_under_interleaved_ops() {
        // Crash every op (budget permitting) while writing and
        // rewriting keys: the recovered live tables must equal a
        // crash-free model's at every point (crash_and_recover asserts
        // the replay internally; this pins the external view too).
        let mut plain = model(8);
        let mut crashy = crash_model(8, 1.0, u32::MAX, 3);
        for i in 0..50u64 {
            let key = i % 11;
            plain.write(0, key, 100 + i);
            crashy.write(0, key, 100 + i);
            assert_eq!(plain.durable_state(), crashy.durable_state(), "op {i}");
        }
        assert_eq!(crashy.durability.recoveries, 50);
    }

    #[test]
    fn checkpoint_restores_into_a_fresh_model_losslessly() {
        let mut k = KvsModel::new(StorageConfig {
            n_shards: 8,
            snapshot_every_ops: 4,
            ..StorageConfig::default()
        });
        for i in 0..100u64 {
            k.write(0, i % 23, i);
        }
        let ckpt = k.checkpoint();
        let mut fresh = KvsModel::new(StorageConfig {
            n_shards: 8,
            snapshot_every_ops: 4,
            ..StorageConfig::default()
        });
        fresh.restore(&ckpt).unwrap();
        assert_eq!(fresh.durable_state(), k.durable_state());
        assert_eq!(fresh.checkpoint(), ckpt, "re-checkpoint must be identical");
        // The resumed model's durable tier evolves identically under
        // the same continued op sequence (queues restart empty, like a
        // real failover — only durable state survives).
        for i in 100..120u64 {
            k.write(0, i % 23, i);
            fresh.write(0, i % 23, i);
        }
        assert_eq!(fresh.durable_state(), k.durable_state());
    }

    #[test]
    fn restore_rejects_mismatched_or_corrupt_checkpoints() {
        let mut k = model(4);
        k.write(0, 1, 10);
        let ckpt = k.checkpoint();
        let mut wrong_shards = model(8);
        assert!(wrong_shards.restore(&ckpt).is_err());
        let mut truncated = model(4);
        assert!(truncated.restore(&ckpt[..ckpt.len() - 1]).is_err());
        let mut trailing = model(4);
        let mut padded = ckpt.clone();
        padded.extend_from_slice(&[0u8; 8]);
        assert!(trailing.restore(&padded).is_err());
    }
}
