//! Sharded key-value store model (the paper's Fargate Redis cluster / S3).
//!
//! Each shard is a FIFO wire: an op occupies its shard for
//! `op_latency + bytes / shard_bw`, so concurrent large transfers to the
//! same shard queue behind each other — the contention that Figs. 13–16
//! measure. S3 mode adds an IOPS gate (request throttling) in front of
//! the transfer. Keys map to shards by multiplicative hash, matching the
//! consistent-hash spread of the real system.

use crate::config::StorageConfig;
use crate::sim::{secs, FifoResource, Time};

/// Byte-exact I/O counters (Figs. 3, 4, 15, 16).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct KvsMetrics {
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub reads: u64,
    pub writes: u64,
}

/// The simulated KVS cluster.
#[derive(Debug)]
pub struct KvsModel {
    cfg: StorageConfig,
    shards: Vec<FifoResource>,
    iops_gates: Vec<FifoResource>,
    pub metrics: KvsMetrics,
}

impl KvsModel {
    pub fn new(cfg: StorageConfig) -> KvsModel {
        let n = cfg.n_shards.max(1);
        KvsModel {
            shards: (0..n).map(|_| FifoResource::new()).collect(),
            iops_gates: (0..n).map(|_| FifoResource::new()).collect(),
            cfg,
            metrics: KvsMetrics::default(),
        }
    }

    fn shard_of(&self, key: u64) -> usize {
        (key.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32) as usize
            % self.shards.len()
    }

    fn transfer(&mut self, now: Time, key: u64, bytes: u64) -> Time {
        let s = self.shard_of(key);
        let mut t = now;
        if self.cfg.iops_limit > 0.0 {
            let gate = secs(1.0 / self.cfg.iops_limit);
            let (_, end) = self.iops_gates[s].acquire(t, gate);
            t = end;
        }
        let service =
            secs(self.cfg.op_latency_s + bytes as f64 / self.cfg.shard_bw);
        let (_, end) = self.shards[s].acquire(t, service);
        end
    }

    /// Read `bytes` under `key`; returns completion time.
    pub fn read(&mut self, now: Time, key: u64, bytes: u64) -> Time {
        self.metrics.bytes_read += bytes;
        self.metrics.reads += 1;
        self.transfer(now, key, bytes)
    }

    /// Write `bytes` under `key`; returns completion time.
    pub fn write(&mut self, now: Time, key: u64, bytes: u64) -> Time {
        self.metrics.bytes_written += bytes;
        self.metrics.writes += 1;
        self.transfer(now, key, bytes)
    }

    /// Aggregate busy time across shards (utilization metric).
    pub fn busy_total(&self) -> Time {
        self.shards.iter().map(|s| s.busy_total()).sum()
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StorageConfig;
    use crate::sim::MICROS_PER_SEC;

    fn model(n_shards: usize) -> KvsModel {
        KvsModel::new(StorageConfig {
            n_shards,
            shard_bw: 100e6,
            op_latency_s: 0.001,
            iops_limit: 0.0,
            ..StorageConfig::default()
        })
    }

    #[test]
    fn read_time_is_latency_plus_transfer() {
        let mut k = model(4);
        let end = k.read(0, 1, 100_000_000); // 1 s transfer at 100 MB/s
        assert_eq!(end, secs(1.001));
    }

    #[test]
    fn same_shard_ops_queue() {
        let mut k = model(1);
        let a = k.write(0, 1, 100_000_000);
        let b = k.write(0, 2, 100_000_000);
        assert_eq!(a, secs(1.001));
        assert_eq!(b, secs(2.002));
    }

    #[test]
    fn different_shards_overlap() {
        let mut k = model(64);
        // find two keys on different shards
        let (mut k1, mut k2) = (1u64, 2u64);
        while k.shard_of(k1) == k.shard_of(k2) {
            k2 += 1;
        }
        let a = k.write(0, k1, 100_000_000);
        let b = k.write(0, k2, 100_000_000);
        assert_eq!(a, b);
    }

    #[test]
    fn metrics_are_byte_exact() {
        let mut k = model(4);
        k.write(0, 1, 1000);
        k.write(0, 2, 500);
        k.read(0, 1, 1000);
        assert_eq!(k.metrics.bytes_written, 1500);
        assert_eq!(k.metrics.bytes_read, 1000);
        assert_eq!(k.metrics.writes, 2);
        assert_eq!(k.metrics.reads, 1);
    }

    #[test]
    fn s3_iops_gate_throttles_small_ops() {
        let mut k = KvsModel::new(StorageConfig::default().s3());
        // Many tiny ops to one key: gated at iops_limit ops/sec.
        let key = 7;
        let mut last = 0;
        for _ in 0..100 {
            last = k.write(0, key, 1);
        }
        // 100 ops at 3500 IOPS ≈ 28.6 ms of gating (plus latency).
        assert!(last > 28 * MICROS_PER_SEC / 1000);
    }

    #[test]
    fn concurrent_large_writes_same_shard_queue_fifo() {
        // Two large writes *issued at the same instant* to keys on the
        // same shard must serialize (FIFO contention — the effect behind
        // Figs. 13–16), regardless of issue order.
        let mut k = model(8);
        let (k1, mut k2) = (1u64, 2u64);
        while k.shard_of(k1) != k.shard_of(k2) {
            k2 += 1;
        }
        let a = k.write(0, k1, 100_000_000); // 1 s at 100 MB/s
        let b = k.write(0, k2, 100_000_000);
        assert_eq!(a, secs(1.001));
        assert_eq!(b, secs(2.002), "same-shard writes must not overlap");
    }

    #[test]
    fn concurrent_large_writes_different_shards_proceed_in_parallel() {
        let mut k = model(64);
        let (k1, mut k2) = (1u64, 2u64);
        while k.shard_of(k1) == k.shard_of(k2) {
            k2 += 1;
        }
        let a = k.write(0, k1, 100_000_000);
        let b = k.write(0, k2, 100_000_000);
        let r = k.read(0, k2, 100_000_000); // queues behind b's shard only
        assert_eq!(a, secs(1.001));
        assert_eq!(b, secs(1.001), "different shards must overlap");
        assert_eq!(r, secs(2.002));
    }

    #[test]
    fn s3_iops_gate_delays_small_ops_beyond_latency() {
        // Isolate the IOPS gate from latency/bandwidth: with op_latency=0
        // and huge shard bandwidth, 50 tiny ops at 100 IOPS must take
        // ~0.5 s; ungated they are instantaneous.
        let gated_cfg = StorageConfig {
            mode: crate::config::KvsMode::S3,
            n_shards: 1,
            shard_bw: 1e15,
            op_latency_s: 0.0,
            iops_limit: 100.0,
            ..StorageConfig::default()
        };
        let mut gated = KvsModel::new(gated_cfg.clone());
        let mut ungated = KvsModel::new(StorageConfig {
            iops_limit: 0.0,
            ..gated_cfg
        });
        let mut last_gated = 0;
        let mut last_ungated = 0;
        for _ in 0..50 {
            last_gated = gated.write(0, 7, 1);
            last_ungated = ungated.write(0, 7, 1);
        }
        assert!(
            last_gated >= secs(0.49),
            "gated 50 ops at 100 IOPS ended at {last_gated}"
        );
        assert_eq!(last_ungated, 0, "ungated tiny ops must be instant");
    }

    #[test]
    fn more_shards_reduce_contention() {
        // 8 same-instant large writes: one shard serializes all of them;
        // many shards spread them out (strictly earlier completion).
        let finish = |n_shards: usize| {
            let mut k = model(n_shards);
            (0..8u64).map(|key| k.write(0, key, 100_000_000)).max().unwrap()
        };
        assert_eq!(finish(1), secs(8.008));
        assert!(finish(64) < secs(8.008));
    }

    #[test]
    fn keys_spread_across_shards() {
        let k = model(75);
        let mut counts = vec![0usize; 75];
        for key in 0..10_000u64 {
            counts[k.shard_of(key)] += 1;
        }
        let max = *counts.iter().max().unwrap();
        let min = *counts.iter().min().unwrap();
        assert!(min > 60 && max < 260, "imbalanced: {min}..{max}");
    }
}
