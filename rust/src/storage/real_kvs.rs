//! Real in-memory KVS for the real engine: sharded maps + injected wire
//! latency, standing in for the Fargate Redis cluster.
//!
//! Values are `Arc<Vec<u8>>` blobs (the real engine serializes f32
//! tensors). Each shard has its own lock so concurrent executors contend
//! only when they hash to the same shard — mirroring the simulator's
//! per-shard FIFO wires. The injected latency reproduces the network cost
//! on a single machine; set `latency_scale = 0` for pure-throughput runs.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Thread-safe sharded blob store with modeled latency.
pub struct RealKvs {
    shards: Vec<Mutex<HashMap<String, Arc<Vec<u8>>>>>,
    op_latency: Duration,
    bytes_per_sec: f64,
    pub bytes_read: AtomicU64,
    pub bytes_written: AtomicU64,
    pub reads: AtomicU64,
    pub writes: AtomicU64,
}

impl RealKvs {
    /// `latency_scale` scales the injected per-op latency + transfer time
    /// (1.0 = model a real Redis wire; 0.0 = no injected delay).
    pub fn new(n_shards: usize, op_latency_s: f64, bytes_per_sec: f64) -> RealKvs {
        RealKvs {
            shards: (0..n_shards.max(1))
                .map(|_| Mutex::new(HashMap::new()))
                .collect(),
            op_latency: Duration::from_secs_f64(op_latency_s.max(0.0)),
            bytes_per_sec,
            bytes_read: AtomicU64::new(0),
            bytes_written: AtomicU64::new(0),
            reads: AtomicU64::new(0),
            writes: AtomicU64::new(0),
        }
    }

    fn shard_of(&self, key: &str) -> usize {
        // FNV-1a
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in key.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
        (h >> 32) as usize % self.shards.len()
    }

    fn wire_delay(&self, bytes: usize) {
        let mut d = self.op_latency;
        if self.bytes_per_sec > 0.0 {
            d += Duration::from_secs_f64(bytes as f64 / self.bytes_per_sec);
        }
        if d > Duration::ZERO {
            std::thread::sleep(d);
        }
    }

    /// Store a blob (charges write latency + transfer time).
    pub fn put(&self, key: &str, value: Vec<u8>) {
        self.bytes_written
            .fetch_add(value.len() as u64, Ordering::Relaxed);
        self.writes.fetch_add(1, Ordering::Relaxed);
        self.wire_delay(value.len());
        let s = self.shard_of(key);
        self.shards[s]
            .lock()
            .unwrap()
            .insert(key.to_string(), Arc::new(value));
    }

    /// Fetch a blob (charges read latency + transfer time).
    pub fn get(&self, key: &str) -> Option<Arc<Vec<u8>>> {
        let s = self.shard_of(key);
        let v = self.shards[s].lock().unwrap().get(key).cloned();
        if let Some(ref blob) = v {
            self.bytes_read
                .fetch_add(blob.len() as u64, Ordering::Relaxed);
            self.reads.fetch_add(1, Ordering::Relaxed);
            self.wire_delay(blob.len());
        }
        v
    }

    /// Blocking fetch: spin (with backoff) until the key appears. Used by
    /// stateless baseline executors waiting on upstream outputs.
    pub fn get_blocking(&self, key: &str, timeout: Duration) -> Option<Arc<Vec<u8>>> {
        let start = std::time::Instant::now();
        loop {
            if let Some(v) = self.get(key) {
                return Some(v);
            }
            if start.elapsed() > timeout {
                return None;
            }
            std::thread::sleep(Duration::from_micros(200));
        }
    }

    pub fn contains(&self, key: &str) -> bool {
        let s = self.shard_of(key);
        self.shards[s].lock().unwrap().contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Serialize an f32 slice to little-endian bytes.
pub fn f32s_to_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Deserialize little-endian bytes back to f32s.
pub fn bytes_to_f32s(b: &[u8]) -> Vec<f32> {
    b.chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip() {
        let kvs = RealKvs::new(8, 0.0, 0.0);
        kvs.put("a", vec![1, 2, 3]);
        assert_eq!(*kvs.get("a").unwrap(), vec![1, 2, 3]);
        assert!(kvs.get("missing").is_none());
    }

    #[test]
    fn metrics_count_bytes() {
        let kvs = RealKvs::new(2, 0.0, 0.0);
        kvs.put("k", vec![0; 100]);
        kvs.get("k");
        assert_eq!(kvs.bytes_written.load(Ordering::Relaxed), 100);
        assert_eq!(kvs.bytes_read.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn f32_serde_roundtrip() {
        let xs = vec![1.5f32, -2.25, 0.0, f32::MAX];
        assert_eq!(bytes_to_f32s(&f32s_to_bytes(&xs)), xs);
    }

    #[test]
    fn blocking_get_waits_for_writer() {
        let kvs = Arc::new(RealKvs::new(4, 0.0, 0.0));
        let k2 = Arc::clone(&kvs);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            k2.put("later", vec![9]);
        });
        let v = kvs.get_blocking("later", Duration::from_secs(2));
        assert_eq!(*v.unwrap(), vec![9]);
        h.join().unwrap();
    }

    #[test]
    fn concurrent_puts_do_not_lose_data() {
        let kvs = Arc::new(RealKvs::new(8, 0.0, 0.0));
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let kvs = Arc::clone(&kvs);
                std::thread::spawn(move || {
                    for j in 0..100 {
                        kvs.put(&format!("k{i}_{j}"), vec![i as u8]);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(kvs.len(), 800);
    }
}
