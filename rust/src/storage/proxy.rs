//! Storage-manager proxy: the parallel fan-out invoker pool (§3.4).
//!
//! When a Task Executor hits a fan-out wider than the delegation
//! threshold, it publishes one message; the proxy (with the Storage
//! Manager's Fan-out Invokers) performs the N invocations in parallel
//! across `n_invokers` processes — the paper's mechanism for (near-)linear
//! invocation speedup over a single executor invoking sequentially.

use crate::sim::{MultiResource, Time};

/// Pool of invoker processes, each performing invocations serially.
#[derive(Debug)]
pub struct InvokerPool {
    pool: MultiResource,
    pub delegated_fanouts: u64,
    pub invocations: u64,
    /// Bytes of inline task payload passed through the proxy (each of
    /// a batch's invocations carries the same serialized argument) —
    /// the proxy half of the inline-vs-KVS byte accounting.
    pub inline_bytes: u64,
}

impl InvokerPool {
    pub fn new(n_invokers: usize) -> InvokerPool {
        InvokerPool {
            pool: MultiResource::new(n_invokers.max(1)),
            delegated_fanouts: 0,
            invocations: 0,
            inline_bytes: 0,
        }
    }

    /// Schedule `n` invocations arriving at `now`, each costing
    /// `per_invoke` of an invoker process and carrying `payload_bytes`
    /// of inline argument (0 when the argument travels via the KVS).
    /// Returns each invocation's completion (executor start) time.
    pub fn invoke_batch(
        &mut self,
        now: Time,
        n: usize,
        per_invoke: Time,
        payload_bytes: u64,
    ) -> Vec<Time> {
        self.delegated_fanouts += 1;
        self.invocations += n as u64;
        self.inline_bytes += n as u64 * payload_bytes;
        (0..n)
            .map(|_| self.pool.acquire(now, per_invoke).1)
            .collect()
    }

    pub fn n_invokers(&self) -> usize {
        self.pool.k()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_parallelizes_across_invokers() {
        let mut p = InvokerPool::new(4);
        let ends = p.invoke_batch(0, 8, 50_000, 0);
        // 8 invokes on 4 procs: first 4 at 50 ms, next 4 at 100 ms.
        assert_eq!(ends.iter().filter(|&&t| t == 50_000).count(), 4);
        assert_eq!(ends.iter().filter(|&&t| t == 100_000).count(), 4);
    }

    #[test]
    fn single_invoker_serializes() {
        let mut p = InvokerPool::new(1);
        let ends = p.invoke_batch(0, 3, 10, 0);
        assert_eq!(ends, vec![10, 20, 30]);
    }

    #[test]
    fn near_linear_speedup() {
        // The paper's claim: N invokers give ~N× faster fan-out launches.
        let mut p1 = InvokerPool::new(1);
        let mut p64 = InvokerPool::new(64);
        let slow = *p1.invoke_batch(0, 640, 50_000, 0).iter().max().unwrap();
        let fast = *p64.invoke_batch(0, 640, 50_000, 0).iter().max().unwrap();
        assert_eq!(slow / fast, 64);
    }

    #[test]
    fn inline_payload_bytes_pass_through_exactly() {
        let mut p = InvokerPool::new(4);
        p.invoke_batch(0, 8, 10, 1000); // 8 invocations × 1000 B inline
        p.invoke_batch(0, 3, 10, 0); // KVS-carried args: no inline bytes
        p.invoke_batch(0, 2, 10, 256); // 2 × 256 B
        assert_eq!(p.inline_bytes, 8 * 1000 + 2 * 256);
        assert_eq!(p.invocations, 13);
        assert_eq!(p.delegated_fanouts, 3);
    }
}
