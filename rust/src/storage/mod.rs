//! Storage substrate: the intermediate-data KVS (Fargate Redis / S3 /
//! ElastiCache models), the metadata store (dependency counters), the
//! storage-manager proxy with its fan-out invoker pool, and the real
//! in-memory KVS used by the real engine.
//!
//! All simulated byte counts are *exact* (the figures 3/4/15/16 I/O
//! numbers are metered, not modeled); only *time* is modeled via the
//! queueing resources.

pub mod durability;
pub mod kvs;
pub mod mds;
pub mod proxy;
pub mod real_kvs;

pub use durability::{DurabilityMetrics, OpRecord, ShardDurability};
pub use kvs::{KvsMetrics, KvsModel};
pub use mds::MdsModel;
pub use proxy::InvokerPool;
