//! Metadata store (MDS): dependency counters + static-schedule storage.
//!
//! The paper co-locates a Redis instance with the static scheduler for
//! job metadata: per-fan-in atomic counters and the serialized static
//! schedules. Counter updates are the *coordination backbone* of dynamic
//! scheduling — `incr` is atomic get-and-update (§3.3), which in the
//! simulator is exact because events are processed one at a time.

use std::collections::HashMap;

use super::durability::{DurabilityMetrics, RECORD_HEADER_BYTES};
use crate::config::StorageConfig;
use crate::sim::{secs, Time};

/// Simulated metadata store.
///
/// Timing model: fixed per-op latency plus the op's service time, with no
/// queueing — a Redis instance sustains >150k ops/s, far above any
/// counter-update rate these DAGs generate, and a FIFO server would be
/// *incorrectly* pessimistic here because engine dispatch chains issue
/// ops with future-dated cursors (a FIFO's horizon would make
/// early-arriving rechecks queue behind far-future ops).
#[derive(Debug)]
pub struct MdsModel {
    latency: Time,
    per_op: Time,
    wal_fsync: Time,
    counters: HashMap<u64, u32>,
    durability: DurabilityMetrics,
    pub ops: u64,
}

impl MdsModel {
    pub fn new(cfg: &StorageConfig) -> MdsModel {
        MdsModel {
            latency: secs(cfg.mds_latency_s),
            per_op: secs(1.0 / cfg.mds_ops_per_sec.max(1.0)),
            wal_fsync: secs(cfg.wal_fsync_s),
            counters: HashMap::new(),
            durability: DurabilityMetrics::default(),
            ops: 0,
        }
    }

    fn op(&mut self, now: Time) -> Time {
        self.ops += 1;
        now + self.per_op + self.latency
    }

    /// Atomic increment; returns `(new_value, completion_time)`.
    /// Mutations are WAL-logged like KVS writes: a fixed-size counter
    /// record per `incr` (metered; `wal_fsync_s` rides on the op time)
    /// — counter replay is what makes a coordinator restart lossless.
    pub fn incr(&mut self, now: Time, key: u64) -> (u32, Time) {
        let t = self.op(now) + self.wal_fsync;
        self.durability.wal_appends += 1;
        self.durability.wal_bytes += RECORD_HEADER_BYTES;
        let v = self.counters.entry(key).or_insert(0);
        *v += 1;
        (*v, t)
    }

    /// Durability meters for this store (WAL appends/bytes; the MDS
    /// tier never crashes in the current model, so the recovery
    /// counters stay zero).
    pub fn durability(&self) -> DurabilityMetrics {
        self.durability
    }

    /// Read a counter; returns `(value, completion_time)`.
    pub fn read(&mut self, now: Time, key: u64) -> (u32, Time) {
        let t = self.op(now);
        (self.counters.get(&key).copied().unwrap_or(0), t)
    }

    /// Counter value without timing (assertions/tests).
    pub fn peek(&self, key: u64) -> u32 {
        self.counters.get(&key).copied().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StorageConfig;

    fn mds() -> MdsModel {
        MdsModel::new(&StorageConfig::default())
    }

    #[test]
    fn incr_is_atomic_and_ordered() {
        let mut m = mds();
        let (a, _) = m.incr(0, 1);
        let (b, _) = m.incr(0, 1);
        let (c, _) = m.incr(0, 1);
        assert_eq!((a, b, c), (1, 2, 3));
    }

    #[test]
    fn independent_keys() {
        let mut m = mds();
        m.incr(0, 1);
        let (v, _) = m.incr(0, 2);
        assert_eq!(v, 1);
        assert_eq!(m.peek(1), 1);
    }

    #[test]
    fn ops_have_latency() {
        let mut m = mds();
        let (_, t) = m.incr(0, 1);
        assert!(t >= secs(0.0008));
    }

    #[test]
    fn out_of_order_issue_times_do_not_interfere() {
        // A far-future op must not delay an earlier-issued one.
        let mut m = mds();
        let (_, far) = m.incr(secs(100.0), 1);
        let (_, near) = m.read(secs(1.0), 1);
        assert!(near < far);
        assert!(near < secs(1.01));
    }

    #[test]
    fn ops_counter_tracks_load() {
        let mut m = mds();
        for _ in 0..100 {
            m.incr(0, 9);
        }
        assert_eq!(m.ops, 100);
    }

    #[test]
    fn incr_is_wal_metered_but_reads_are_not() {
        let mut m = mds();
        m.incr(0, 1);
        m.incr(0, 1);
        m.read(0, 1);
        assert_eq!(m.durability().wal_appends, 2);
        assert_eq!(m.durability().wal_bytes, 2 * 16);
        assert_eq!(m.durability().recoveries, 0);
    }

    #[test]
    fn wal_fsync_rides_on_incr_not_read() {
        let cfg = StorageConfig {
            wal_fsync_s: 0.5,
            ..StorageConfig::default()
        };
        let mut m = MdsModel::new(&cfg);
        let (_, ti) = m.incr(0, 1);
        let (_, tr) = m.read(0, 1);
        let mut free = mds();
        let (_, ti0) = free.incr(0, 1);
        let (_, tr0) = free.read(0, 1);
        assert_eq!(ti, ti0 + secs(0.5));
        assert_eq!(tr, tr0);
    }
}
