//! Metadata store (MDS): dependency counters + static-schedule storage.
//!
//! The paper co-locates a Redis instance with the static scheduler for
//! job metadata: per-fan-in atomic counters and the serialized static
//! schedules. Counter updates are the *coordination backbone* of dynamic
//! scheduling — `incr` is atomic get-and-update (§3.3), which in the
//! simulator is exact because events are processed one at a time.

use std::collections::HashMap;

use crate::config::StorageConfig;
use crate::sim::{secs, Time};

/// Simulated metadata store.
///
/// Timing model: fixed per-op latency plus the op's service time, with no
/// queueing — a Redis instance sustains >150k ops/s, far above any
/// counter-update rate these DAGs generate, and a FIFO server would be
/// *incorrectly* pessimistic here because engine dispatch chains issue
/// ops with future-dated cursors (a FIFO's horizon would make
/// early-arriving rechecks queue behind far-future ops).
#[derive(Debug)]
pub struct MdsModel {
    latency: Time,
    per_op: Time,
    counters: HashMap<u64, u32>,
    pub ops: u64,
}

impl MdsModel {
    pub fn new(cfg: &StorageConfig) -> MdsModel {
        MdsModel {
            latency: secs(cfg.mds_latency_s),
            per_op: secs(1.0 / cfg.mds_ops_per_sec.max(1.0)),
            counters: HashMap::new(),
            ops: 0,
        }
    }

    fn op(&mut self, now: Time) -> Time {
        self.ops += 1;
        now + self.per_op + self.latency
    }

    /// Atomic increment; returns `(new_value, completion_time)`.
    pub fn incr(&mut self, now: Time, key: u64) -> (u32, Time) {
        let t = self.op(now);
        let v = self.counters.entry(key).or_insert(0);
        *v += 1;
        (*v, t)
    }

    /// Read a counter; returns `(value, completion_time)`.
    pub fn read(&mut self, now: Time, key: u64) -> (u32, Time) {
        let t = self.op(now);
        (self.counters.get(&key).copied().unwrap_or(0), t)
    }

    /// Counter value without timing (assertions/tests).
    pub fn peek(&self, key: u64) -> u32 {
        self.counters.get(&key).copied().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StorageConfig;

    fn mds() -> MdsModel {
        MdsModel::new(&StorageConfig::default())
    }

    #[test]
    fn incr_is_atomic_and_ordered() {
        let mut m = mds();
        let (a, _) = m.incr(0, 1);
        let (b, _) = m.incr(0, 1);
        let (c, _) = m.incr(0, 1);
        assert_eq!((a, b, c), (1, 2, 3));
    }

    #[test]
    fn independent_keys() {
        let mut m = mds();
        m.incr(0, 1);
        let (v, _) = m.incr(0, 2);
        assert_eq!(v, 1);
        assert_eq!(m.peek(1), 1);
    }

    #[test]
    fn ops_have_latency() {
        let mut m = mds();
        let (_, t) = m.incr(0, 1);
        assert!(t >= secs(0.0008));
    }

    #[test]
    fn out_of_order_issue_times_do_not_interfere() {
        // A far-future op must not delay an earlier-issued one.
        let mut m = mds();
        let (_, far) = m.incr(secs(100.0), 1);
        let (_, near) = m.read(secs(1.0), 1);
        assert!(near < far);
        assert!(near < secs(1.01));
    }

    #[test]
    fn ops_counter_tracks_load() {
        let mut m = mds();
        for _ in 0..100 {
            m.incr(0, 9);
        }
        assert_eq!(m.ops, 100);
    }
}
